//! Checkpoint/resume over the real artifacts: the deployment story of
//! saving trained pruning state and restoring it after a power cycle (a
//! core embedded requirement), through `Session::save` / `Session::restore`.
//!
//! The artifact-free round-trip suite (all three methods, synthetic
//! backbone) lives in `rust/tests/session.rs`; these tests add the
//! real-artifact paths and skip when `make artifacts` has not run.

use std::path::PathBuf;

use priot::config::{Config, ExperimentConfig};
use priot::data;
use priot::session::Session;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !p.join("tinycnn.weights.bin").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    Some(p)
}

fn cfg(dir: &std::path::Path, method: &str) -> ExperimentConfig {
    let mut c = Config::default();
    c.set("artifacts", dir.to_str().unwrap());
    c.set("method", method);
    c.set("seed", "11");
    c.set("frac_scored", "0.1");
    ExperimentConfig::from_config(&c).unwrap()
}

fn train_steps(s: &mut Session, ds: &priot::serial::Dataset, n: usize) {
    let mut img = vec![0i32; ds.image_len()];
    for i in 0..n {
        ds.image_i32(i % ds.n, &mut img);
        s.train_step(&img, ds.label(i % ds.n));
    }
}

#[test]
fn priot_checkpoint_roundtrip_resumes_identically() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot");
    let pair = data::load_pair(&c).unwrap();
    let tmp = std::env::temp_dir().join("priot_ckpt_test");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt = tmp.join("scores.bin");

    // run A: 10 steps, checkpoint, 10 more steps
    let mut a = Session::from_experiment(&c).unwrap();
    train_steps(&mut a, &pair.train, 10);
    a.save(&ckpt).unwrap();
    train_steps(&mut a, &pair.train, 10);

    // run B: fresh session with a different seed (scores differ until the
    // checkpoint overwrites them), restore, same 10 steps
    let mut c2 = c.clone();
    c2.seed = 99;
    let mut b = Session::from_experiment(&c2).unwrap();
    b.restore(&ckpt).unwrap();
    train_steps(&mut b, &pair.train, 10);
    let (sa, sb) = (a.scores().unwrap(), b.scores().unwrap());
    // B replayed samples 0..10 again, A continued 10..20 — so equality is
    // only expected for the checkpoint itself; assert restore exactness:
    let mut b2 = Session::from_experiment(&c2).unwrap();
    b2.restore(&ckpt).unwrap();
    let mut a2 = Session::from_experiment(&c).unwrap();
    train_steps(&mut a2, &pair.train, 10);
    assert_eq!(b2.scores().unwrap(), a2.scores().unwrap(),
               "restored state must equal the state that was saved");
    // sanity: training continued to evolve in both
    assert_ne!(sa, b2.scores().unwrap());
    assert_ne!(sb, b2.scores().unwrap());
}

#[test]
fn niti_checkpoint_saves_weights() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "static-niti");
    let pair = data::load_pair(&c).unwrap();
    let tmp = std::env::temp_dir().join("priot_ckpt_test");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt = tmp.join("weights.bin");
    let mut a = Session::from_experiment(&c).unwrap();
    train_steps(&mut a, &pair.train, 5);
    a.save(&ckpt).unwrap();
    let mut b = Session::from_experiment(&c).unwrap();
    b.restore(&ckpt).unwrap();
    // restored weights must reproduce A's predictions exactly
    let mut img = vec![0i32; pair.test.image_len()];
    for i in 0..32.min(pair.test.n) {
        pair.test.image_i32(i, &mut img);
        assert_eq!(a.predict(&img), b.predict(&img), "sample {i}");
    }
    assert_eq!(a.engine_mut().unwrap().weights,
               b.engine_mut().unwrap().weights);
}

#[test]
fn checkpoint_shape_mismatch_rejected() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot");
    let mut a = Session::from_experiment(&c).unwrap();
    let tmp = std::env::temp_dir().join("priot_ckpt_test");
    std::fs::create_dir_all(&tmp).unwrap();
    let bad = tmp.join("bad.bin");
    // save a NITI-shaped checkpoint (4 tensors) and try to load as PRIOT (8)
    let c2 = cfg(&dir, "static-niti");
    let b = Session::from_experiment(&c2).unwrap();
    b.save(&bad).unwrap();
    assert!(a.restore(&bad).is_err());
}
