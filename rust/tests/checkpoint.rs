//! Checkpoint/resume: the deployment story of saving trained pruning state
//! and restoring it after a power cycle (a core embedded requirement).
//! Requires `make artifacts`.

use std::path::PathBuf;

use priot::config::{Config, ExperimentConfig};
use priot::data;
use priot::methods::{EngineBackend, StepBackend};

fn artifacts() -> PathBuf {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(p.join("tinycnn.weights.bin").exists(), "run `make artifacts`");
    p
}

fn cfg(method: &str) -> ExperimentConfig {
    let mut c = Config::default();
    c.set("artifacts", artifacts().to_str().unwrap());
    c.set("method", method);
    c.set("seed", "11");
    c.set("frac_scored", "0.1");
    ExperimentConfig::from_config(&c).unwrap()
}

fn train_steps(b: &mut EngineBackend, ds: &priot::serial::Dataset, n: usize) {
    let mut img = vec![0i32; ds.image_len()];
    for i in 0..n {
        ds.image_i32(i % ds.n, &mut img);
        b.train_step(&img, ds.label(i % ds.n));
    }
}

#[test]
fn priot_checkpoint_roundtrip_resumes_identically() {
    let c = cfg("priot");
    let pair = data::load_pair(&c).unwrap();
    let dir = std::env::temp_dir().join("priot_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("scores.bin");

    // run A: 10 steps, checkpoint, 10 more steps
    let mut a = EngineBackend::from_config(&c).unwrap();
    train_steps(&mut a, &pair.train, 10);
    a.save_state(&ckpt).unwrap();
    train_steps(&mut a, &pair.train, 10);

    // run B: fresh backend (different seed state!), restore, same 10 steps
    let mut c2 = c.clone();
    c2.seed = 99; // init scores differ until the checkpoint overwrites them
    let mut b = EngineBackend::from_config(&c2).unwrap();
    b.load_state(&ckpt).unwrap();
    // replay the same post-checkpoint data; step counters differ (10 vs 0)
    // but PRIOT's deterministic score path does not consume them.
    train_steps(&mut b, &pair.train, 10);
    // skip the first 10 samples for A's continuation alignment
    let (sa, sb) = (a.scores().unwrap(), b.scores().unwrap());
    // B replayed samples 0..10 again, A continued 10..20 — so equality is
    // only expected for the checkpoint itself; assert restore exactness:
    let mut b2 = EngineBackend::from_config(&c2).unwrap();
    b2.load_state(&ckpt).unwrap();
    let mut a2 = EngineBackend::from_config(&c).unwrap();
    train_steps(&mut a2, &pair.train, 10);
    assert_eq!(b2.scores().unwrap(), a2.scores().unwrap(),
               "restored state must equal the state that was saved");
    // sanity: training continued to evolve in both
    assert_ne!(sa, b2.scores().unwrap());
    assert_ne!(sb, b2.scores().unwrap());
}

#[test]
fn niti_checkpoint_saves_weights() {
    let c = cfg("static-niti");
    let pair = data::load_pair(&c).unwrap();
    let dir = std::env::temp_dir().join("priot_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("weights.bin");
    let mut a = EngineBackend::from_config(&c).unwrap();
    train_steps(&mut a, &pair.train, 5);
    a.save_state(&ckpt).unwrap();
    let mut b = EngineBackend::from_config(&c).unwrap();
    b.load_state(&ckpt).unwrap();
    assert_eq!(a.engine.weights, b.engine.weights);
}

#[test]
fn checkpoint_shape_mismatch_rejected() {
    let c = cfg("priot");
    let mut a = EngineBackend::from_config(&c).unwrap();
    let dir = std::env::temp_dir().join("priot_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.bin");
    // save a NITI-shaped checkpoint (4 tensors) and try to load as PRIOT (8)
    let c2 = cfg("static-niti");
    let b = EngineBackend::from_config(&c2).unwrap();
    b.save_state(&bad).unwrap();
    assert!(a.load_state(&bad).is_err());
}
