//! Serve-subsystem tests over a synthetic in-memory backbone — no
//! artifacts required, so these run on any checkout:
//!
//! * register/train/predict/evaluate round-trip through the request
//!   channel, with results bit-identical to a standalone session;
//! * drift mid-stream swaps a device's data in submission order;
//! * error paths (unknown device, duplicate register, geometry mismatch)
//!   come back as `Response::Error`, never a panic;
//! * batched evaluation is bit-identical to per-sample evaluation for all
//!   three method plugins (the `evaluate_batch` acceptance criterion).

use std::sync::Arc;

use priot::config::Selection;
use priot::methods::{MethodPlugin, Niti, Priot, PriotS};
use priot::ptest::gen::{self, synthetic_backbone};
use priot::serial::Dataset;
use priot::session::{Backbone, FleetServer, Request, Response, Session};

fn synthetic_dataset(seed: u64, n: usize) -> Arc<Dataset> {
    Arc::new(gen::synthetic_dataset(seed, n))
}

fn solo_session(bb: &Arc<Backbone>, plugin: Box<dyn MethodPlugin>, seed: u32)
                -> Session {
    Session::builder()
        .backbone(Arc::clone(bb))
        .method_boxed(plugin)
        .seed(seed)
        .eval_batch(8) // the serve default
        .track_pruning(false)
        .build()
        .unwrap()
}

#[test]
fn serve_roundtrip_matches_standalone_session() {
    let bb = synthetic_backbone(1);
    let train = synthetic_dataset(2, 48);
    let test = synthetic_dataset(3, 32);

    let server = FleetServer::builder(Arc::clone(&bb)).threads(2).build();
    server
        .submit(Request::Register {
            device: "dev-a".into(),
            seed: 7,
            plugin: Box::new(Priot::new()),
            train: Arc::clone(&train),
            test: Arc::clone(&test),
        })
        .unwrap();
    server
        .submit(Request::Train { device: "dev-a".into(), epochs: 2 })
        .unwrap();
    let probe = test.image(0).to_vec();
    server
        .submit(Request::Predict { device: "dev-a".into(), image: probe })
        .unwrap();
    server.submit(Request::Evaluate { device: "dev-a".into() }).unwrap();
    let report = server.join().unwrap();

    assert_eq!(report.requests, 4);
    assert_eq!(report.errors(), 0, "{:?}", report.responses);
    let dev = report.for_device("dev-a");
    assert_eq!(dev.len(), 4, "one response per request");
    assert_eq!(*dev[0], Response::Registered { device: "dev-a".into() });

    // Reference: an identical standalone session (same seed, same stream).
    let mut solo = solo_session(&bb, Box::new(Priot::new()), 7);
    let mut steps = 0u64;
    for _ in 0..2 {
        steps += solo.train_epoch(&train).unwrap().steps as u64;
    }
    match dev[1] {
        Response::TrainDone { epochs, steps: s, .. } => {
            assert_eq!(*epochs, 2);
            assert_eq!(*s, steps, "executed steps, 2 epochs × 48 samples");
            assert_eq!(*s, 2 * 48);
        }
        other => panic!("expected TrainDone, got {other:?}"),
    }
    let mut img = vec![0i32; test.image_len()];
    test.image_i32(0, &mut img);
    let want_class = solo.predict(&img);
    assert_eq!(*dev[2],
               Response::Prediction { device: "dev-a".into(), class: want_class },
               "raw-image predict matches the dataset pixel mapping");
    let want_acc = solo.evaluate_batch(&test, 8).unwrap();
    match dev[3] {
        Response::Evaluation { accuracy, n, .. } => {
            assert_eq!(*accuracy, want_acc, "served evaluation bit-identical");
            assert_eq!(*n, test.n);
        }
        other => panic!("expected Evaluation, got {other:?}"),
    }
    assert!(report.requests_per_sec() > 0.0);
    assert!(report.summary().contains("4 requests"));
}

#[test]
fn serve_drift_mid_stream_changes_device_data() {
    let bb = synthetic_backbone(4);
    let train_a = synthetic_dataset(5, 24);
    let test_a = synthetic_dataset(6, 16);
    let train_b = synthetic_dataset(7, 40);
    let test_b = synthetic_dataset(8, 20);

    let server = FleetServer::builder(Arc::clone(&bb)).threads(3).build();
    server
        .submit(Request::Register {
            device: "dev-d".into(),
            seed: 11,
            plugin: Box::new(PriotS::new(0.2, Selection::WeightBased)),
            train: Arc::clone(&train_a),
            test: Arc::clone(&test_a),
        })
        .unwrap();
    server.submit(Request::Train { device: "dev-d".into(), epochs: 1 }).unwrap();
    server
        .submit(Request::Drift {
            device: "dev-d".into(),
            train: Arc::clone(&train_b),
            test: Arc::clone(&test_b),
        })
        .unwrap();
    server.submit(Request::Train { device: "dev-d".into(), epochs: 1 }).unwrap();
    server.submit(Request::Evaluate { device: "dev-d".into() }).unwrap();
    let report = server.join().unwrap();
    assert_eq!(report.errors(), 0, "{:?}", report.responses);

    // Reference continuation: epoch on A, then epoch on B, evaluate on B.
    let mut solo =
        solo_session(&bb, Box::new(PriotS::new(0.2, Selection::WeightBased)), 11);
    let steps_a = solo.train_epoch(&train_a).unwrap().steps as u64;
    let steps_b = solo.train_epoch(&train_b).unwrap().steps as u64;
    let want_acc = solo.evaluate_batch(&test_b, 8).unwrap();

    let dev = report.for_device("dev-d");
    assert_eq!(dev.len(), 5);
    match (dev[1], dev[3]) {
        (Response::TrainDone { steps: s1, .. },
         Response::TrainDone { steps: s2, .. }) => {
            assert_eq!((*s1, *s2), (steps_a, steps_b),
                       "post-drift epoch runs on the drifted train set");
        }
        other => panic!("expected two TrainDones, got {other:?}"),
    }
    assert_eq!(*dev[2], Response::Drifted { device: "dev-d".into() });
    match dev[4] {
        Response::Evaluation { accuracy, n, .. } => {
            assert_eq!(*accuracy, want_acc, "evaluates the drifted test set");
            assert_eq!(*n, test_b.n);
        }
        other => panic!("expected Evaluation, got {other:?}"),
    }
}

#[test]
fn serve_error_paths_are_responses_not_panics() {
    let bb = synthetic_backbone(9);
    let train = synthetic_dataset(10, 8);
    let test = synthetic_dataset(11, 8);
    let wrong_geometry = Arc::new(Dataset {
        n: 2,
        c: 3,
        h: 32,
        w: 32,
        images: vec![0; 2 * 3 * 32 * 32],
        labels: vec![0, 1],
    });

    let server = FleetServer::builder(Arc::clone(&bb)).threads(1).build();
    // 1: op for a device that was never registered
    server.submit(Request::Train { device: "ghost".into(), epochs: 1 }).unwrap();
    // 2: register with geometry-mismatched data → validated at Register
    server
        .submit(Request::Register {
            device: "dev-g".into(),
            seed: 1,
            plugin: Box::new(Priot::new()),
            train: Arc::clone(&wrong_geometry),
            test: Arc::clone(&test),
        })
        .unwrap();
    // 3 + 4: a good register, then a duplicate of it
    for _ in 0..2 {
        server
            .submit(Request::Register {
                device: "dev-e".into(),
                seed: 1,
                plugin: Box::new(Niti::static_scale()),
                train: Arc::clone(&train),
                test: Arc::clone(&test),
            })
            .unwrap();
    }
    // 5: predict with a wrong-sized raw image
    server
        .submit(Request::Predict { device: "dev-e".into(), image: vec![1, 2, 3] })
        .unwrap();
    // 6: drift to mismatched data is rejected up front
    server
        .submit(Request::Drift {
            device: "dev-e".into(),
            train: Arc::clone(&wrong_geometry),
            test: Arc::clone(&test),
        })
        .unwrap();
    let report = server.join().unwrap();

    assert_eq!(report.requests, 6);
    assert_eq!(report.errors(), 5, "{:?}", report.responses);
    let ghost = report.for_device("ghost");
    assert!(matches!(ghost[0], Response::Error { message, .. }
                     if message.contains("register first")),
            "{ghost:?}");
    let dev_g = report.for_device("dev-g");
    assert!(matches!(dev_g[0], Response::Error { message, .. }
                     if message.contains("geometry")),
            "{dev_g:?}");
    let dev_e = report.for_device("dev-e");
    assert_eq!(dev_e.len(), 4, "registered + duplicate + predict + drift");
    assert!(!dev_e[0].is_error(), "first register succeeds");
    // Dispatcher-side validation errors (duplicate register, bad drift)
    // may overtake worker-side op errors (bad predict) in arrival order,
    // so assert on the set of messages, not their order.
    let messages: Vec<&str> = dev_e[1..]
        .iter()
        .map(|r| match r {
            Response::Error { message, .. } => message.as_str(),
            other => panic!("expected Error, got {other:?}"),
        })
        .collect();
    for want in ["already registered", "pixels", "geometry"] {
        assert!(messages.iter().any(|m| m.contains(want)),
                "no error mentioning {want:?} in {messages:?}");
    }
}

#[test]
fn serve_interleaves_many_devices_deterministically_per_device() {
    // Several devices with different methods, all mid-adaptation at once:
    // per-device responses must be bit-identical to standalone sessions
    // regardless of how the pool interleaves their epochs.
    let bb = synthetic_backbone(12);
    let train = synthetic_dataset(13, 32);
    let test = synthetic_dataset(14, 24);
    let mk: Vec<(&str, fn() -> Box<dyn MethodPlugin>)> = vec![
        ("dev-niti", || Box::new(Niti::static_scale())),
        ("dev-priot", || Box::new(Priot::new())),
        ("dev-priot-s", || Box::new(PriotS::new(0.1, Selection::Random))),
    ];
    let server = FleetServer::builder(Arc::clone(&bb)).threads(3).build();
    for (i, (name, make)) in mk.iter().enumerate() {
        server
            .submit(Request::Register {
                device: (*name).into(),
                seed: (i + 1) as u32,
                plugin: make(),
                train: Arc::clone(&train),
                test: Arc::clone(&test),
            })
            .unwrap();
    }
    for (name, _) in &mk {
        server
            .submit(Request::Train { device: (*name).into(), epochs: 3 })
            .unwrap();
        server.submit(Request::Evaluate { device: (*name).into() }).unwrap();
    }
    let report = server.join().unwrap();
    assert_eq!(report.errors(), 0, "{:?}", report.responses);

    for (i, (name, make)) in mk.iter().enumerate() {
        let mut solo = solo_session(&bb, make(), (i + 1) as u32);
        for _ in 0..3 {
            solo.train_epoch(&train).unwrap();
        }
        let want = solo.evaluate_batch(&test, 8).unwrap();
        let dev = report.for_device(name);
        match dev.last().unwrap() {
            Response::Evaluation { accuracy, .. } => {
                assert_eq!(*accuracy, want, "{name}: diverged under interleaving");
            }
            other => panic!("{name}: expected Evaluation, got {other:?}"),
        }
    }
}

#[test]
fn batched_evaluation_bit_identical_for_all_method_plugins() {
    // The acceptance criterion: `Session::evaluate_batch` (and the batched
    // engine forward underneath) must be bit-identical to per-sample
    // evaluation for NITI, PRIOT, and PRIOT-S — including odd batch sizes
    // with a remainder chunk and batches larger than the dataset.
    let bb = synthetic_backbone(15);
    let train = synthetic_dataset(16, 40);
    let test = synthetic_dataset(17, 37); // prime-ish: exercises remainders
    let mk: Vec<(&str, fn() -> Box<dyn MethodPlugin>)> = vec![
        ("static-niti", || Box::new(Niti::static_scale())),
        ("dynamic-niti", || Box::new(Niti::dynamic())),
        ("priot", || Box::new(Priot::new())),
        ("priot-s", || Box::new(PriotS::new(0.15, Selection::WeightBased))),
    ];
    for (name, make) in &mk {
        let mut s = Session::builder()
            .backbone(Arc::clone(&bb))
            .method_boxed(make())
            .seed(5)
            .build()
            .unwrap();
        // Move the method state off its init point first.
        let mut img = vec![0i32; train.image_len()];
        for i in 0..12 {
            train.image_i32(i, &mut img);
            s.train_step(&img, train.label(i));
        }
        // Element-wise: batched predictions == per-sample predictions.
        let per_sample: Vec<usize> = (0..test.n)
            .map(|i| {
                test.image_i32(i, &mut img);
                s.predict(&img)
            })
            .collect();
        let reference = s.evaluate_batch(&test, 1).unwrap();
        for batch in [2usize, 7, 16, 37, 64] {
            let acc = s.evaluate_batch(&test, batch).unwrap();
            assert_eq!(acc, reference, "{name}: accuracy diverged at batch={batch}");
        }
        let mut s_batched = Session::builder()
            .backbone(Arc::clone(&bb))
            .method_boxed(make())
            .seed(5)
            .eval_batch(7)
            .build()
            .unwrap();
        for i in 0..12 {
            train.image_i32(i, &mut img);
            s_batched.train_step(&img, train.label(i));
        }
        let batched = s_batched.predict_batch(&test, 0).unwrap();
        assert_eq!(batched, per_sample,
                   "{name}: batched predictions diverged element-wise");
    }
}
