//! Cross-implementation bit-parity: the pure-Rust engine and the AOT
//! (JAX+Pallas → HLO → PJRT) path must produce *identical* integers —
//! logits, overflow counts, and evolving training state — over multi-step
//! runs of every method.  Combined with the pytest suite (oracle == JAX
//! graphs), this pins all three implementations to one semantics.
//!
//! Requires `make artifacts`.

use std::path::{Path, PathBuf};

use priot::config::{Config, ExperimentConfig};
use priot::data;
use priot::methods::{EngineBackend, StepBackend};
use priot::runtime::{PjrtBackend, Runtime};

fn artifacts() -> PathBuf {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        p.join("tinycnn_priot_step.hlo.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    p
}

fn cfg(method: &str, extra: &[(&str, &str)]) -> ExperimentConfig {
    let mut c = Config::default();
    c.set("artifacts", artifacts().to_str().unwrap());
    c.set("method", method);
    c.set("angle", "30");
    for (k, v) in extra {
        c.set(k, v);
    }
    ExperimentConfig::from_config(&c).unwrap()
}

fn parity_run(cfg: &ExperimentConfig, rt: &Runtime, steps: usize,
              eval_every: usize) {
    let pair = data::load_pair(cfg).unwrap();
    let mut eng = EngineBackend::from_config(cfg).unwrap();
    let mut pj = PjrtBackend::from_config(cfg, rt).unwrap();
    let mut img = vec![0i32; pair.train.image_len()];
    for i in 0..steps {
        pair.train.image_i32(i % pair.train.n, &mut img);
        let label = pair.train.label(i % pair.train.n);
        let a = eng.train_step(&img, label);
        let b = pj.train_step(&img, label);
        assert_eq!(a.logits, b.logits, "{}: logits diverged at step {i}",
                   cfg.method.name());
        assert_eq!(a.overflow, b.overflow,
                   "{}: overflow diverged at step {i}", cfg.method.name());
        if i % eval_every == 0 {
            pair.test.image_i32(i % pair.test.n, &mut img);
            assert_eq!(eng.predict(&img), pj.predict(&img),
                       "{}: prediction diverged at step {i}",
                       cfg.method.name());
        }
    }
    // trained state must be identical too
    match (eng.scores(), pj.scores()) {
        (Some(a), Some(b)) => assert_eq!(a, b, "scores diverged"),
        (None, None) => {}
        _ => panic!("one backend has scores, the other does not"),
    }
}

#[test]
fn parity_priot_20_steps() {
    let rt = Runtime::new(&artifacts()).unwrap();
    parity_run(&cfg("priot", &[("seed", "3")]), &rt, 20, 5);
}

#[test]
fn parity_priot_s_random_20_steps() {
    let rt = Runtime::new(&artifacts()).unwrap();
    parity_run(
        &cfg("priot-s", &[("selection", "random"), ("frac_scored", "0.1"),
                          ("seed", "4")]),
        &rt, 20, 5,
    );
}

#[test]
fn parity_priot_s_weight_20_steps() {
    let rt = Runtime::new(&artifacts()).unwrap();
    parity_run(
        &cfg("priot-s", &[("selection", "weight"), ("frac_scored", "0.2"),
                          ("seed", "5")]),
        &rt, 20, 5,
    );
}

#[test]
fn parity_static_niti_20_steps() {
    // Exercises the stochastic-rounding path: the counter-based hash must
    // agree between jnp uint32 arithmetic and Rust wrapping_mul.
    let rt = Runtime::new(&artifacts()).unwrap();
    parity_run(&cfg("static-niti", &[]), &rt, 20, 5);
}

#[test]
fn parity_eval_over_test_set_sample() {
    // Pure inference parity across 32 samples (fwd_eval artifact).
    let rt = Runtime::new(&artifacts()).unwrap();
    let c = cfg("priot", &[("seed", "9")]);
    let pair = data::load_pair(&c).unwrap();
    let mut eng = EngineBackend::from_config(&c).unwrap();
    let mut pj = PjrtBackend::from_config(&c, &rt).unwrap();
    let mut img = vec![0i32; pair.test.image_len()];
    for i in 0..32.min(pair.test.n) {
        pair.test.image_i32(i, &mut img);
        assert_eq!(eng.predict(&img), pj.predict(&img), "sample {i}");
    }
}

#[test]
fn artifacts_manifest_is_consistent() {
    let dir = artifacts();
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    for line in manifest.lines().filter(|l| !l.starts_with('#')) {
        let mut parts = line.split_whitespace();
        let _name = parts.next().unwrap();
        let file = parts.next().unwrap();
        assert!(
            Path::new(&dir).join(file).exists(),
            "manifest entry {file} missing on disk"
        );
    }
}
