//! End-to-end integration over the real artifacts: the paper's headline
//! behaviours must reproduce on the engine backend, driven through the
//! Session API.
//!
//! Requires `make artifacts` — each test skips (with a note) when the
//! artifacts are absent so `cargo test` stays useful on a fresh checkout.

use std::path::PathBuf;

use priot::config::{Config, ExperimentConfig};
use priot::data;
use priot::quant::Scales;
use priot::session::Session;
use priot::spec::NetSpec;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !p.join("tinycnn.weights.bin").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    Some(p)
}

fn cfg(dir: &std::path::Path, method: &str, extra: &[(&str, &str)])
       -> ExperimentConfig {
    let mut c = Config::default();
    c.set("artifacts", dir.to_str().unwrap());
    c.set("method", method);
    c.set("angle", "30");
    for (k, v) in extra {
        c.set(k, v);
    }
    ExperimentConfig::from_config(&c).unwrap()
}

/// Session from a config with quick epoch/limit overrides.
fn session(c: &ExperimentConfig, epochs: usize, limit: usize) -> Session {
    let mut c = c.clone();
    c.epochs = epochs;
    c.limit = limit;
    Session::from_experiment(&c).unwrap()
}

#[test]
fn artifacts_load_and_validate() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot", &[]);
    let pair = data::load_pair(&c).unwrap();
    let spec = NetSpec::tinycnn();
    data::validate(&pair.train, &spec).unwrap();
    data::validate(&pair.test, &spec).unwrap();
    let tensors = priot::serial::load_weights(&c.weights_path()).unwrap();
    assert_eq!(tensors.len(), spec.layers.len());
    for (t, l) in tensors.iter().zip(spec.layers.iter()) {
        let (r, cdim) = l.weight_shape();
        assert_eq!(t.dims, vec![r, cdim]);
    }
    let scales = Scales::load(&c.scales_path()).unwrap();
    assert_eq!(scales.layers.len(), spec.layers.len());
}

#[test]
fn backbone_beats_chance_before_transfer() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "static-niti", &[]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 0, 512);
    let acc = s.evaluate(&pair.test).unwrap();
    assert!(acc > 0.35, "pre-trained backbone @30° should beat chance: {acc}");
}

#[test]
fn priot_improves_over_backbone() {
    // The paper's headline: PRIOT trains effectively with static scales.
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot", &[("seed", "1")]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 5, 512);
    let m = s.train(&pair.train, &pair.test).unwrap();
    let gain = m.best_accuracy() - m.accuracy[0];
    assert!(
        gain >= 0.04,
        "PRIOT should gain ≥4 p.p. in 5 quick epochs: before {:.3} best {:.3}",
        m.accuracy[0],
        m.best_accuracy()
    );
    // weights frozen ⇒ no overflow growth
    assert_eq!(m.overflow.iter().sum::<u64>(), 0,
               "PRIOT must not overflow the static scales");
}

#[test]
fn static_niti_collapses() {
    // The paper's motivation (Fig. 2/3): static-scale NITI training
    // collapses — the run ends far below where it started, accompanied by
    // output-overflow bursts.  (In our setup a brief transient gain
    // precedes the collapse; the paper's curve is flat-then-collapse.
    // EXPERIMENTS.md §Deviations discusses this.)
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "static-niti", &[]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 8, 512);
    let m = s.train(&pair.train, &pair.test).unwrap();
    assert!(
        m.final_accuracy() < m.best_accuracy() - 0.15,
        "static-NITI should collapse from its peak: best {:.3} final {:.3}",
        m.best_accuracy(),
        m.final_accuracy()
    );
    assert!(
        m.final_accuracy() < m.accuracy[0],
        "static-NITI should end below the backbone: start {:.3} final {:.3}",
        m.accuracy[0],
        m.final_accuracy()
    );
    assert!(m.overflow.iter().sum::<u64>() > 0,
            "collapse should come with overflow events");
}

#[test]
fn dynamic_niti_improves() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "dynamic-niti", &[]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 3, 512);
    let m = s.train(&pair.train, &pair.test).unwrap();
    let gain = m.best_accuracy() - m.accuracy[0];
    assert!(gain >= 0.04, "dynamic-NITI reference should learn: gain {gain:.3}");
}

#[test]
fn priot_s_weight_based_learns_with_sparse_scores() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot-s", &[("selection", "weight"),
                                   ("frac_scored", "0.2"), ("seed", "2")]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 5, 512);
    let m = s.train(&pair.train, &pair.test).unwrap();
    let gain = m.best_accuracy() - m.accuracy[0];
    assert!(gain >= 0.02, "PRIOT-S should still learn: gain {gain:.3}");
}

#[test]
fn priot_prunes_gradually_and_stably() {
    // §IV-B analysis: ~10% of edges pruned by the end, few oscillations.
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot", &[("seed", "3")]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 5, 512);
    let m = s.train(&pair.train, &pair.test).unwrap();
    let last = m.pruned_frac.last().unwrap();
    let avg: f64 = last.iter().sum::<f64>() / last.len() as f64;
    assert!(
        (0.005..0.35).contains(&avg),
        "pruned fraction should be moderate, got {avg:.3}"
    );
    // flips settle: late-epoch flips should not exceed early flips by 3×
    if m.mask_flips.len() >= 3 {
        let first = m.mask_flips[0].max(1);
        let last_f = *m.mask_flips.last().unwrap();
        assert!(
            last_f < first * 3,
            "mask oscillation should not grow: first {first} last {last_f}"
        );
    }
}

#[test]
fn track_pruning_off_skips_pruning_metrics() {
    let Some(dir) = artifacts() else { return };
    let c = cfg(&dir, "priot", &[("track_pruning", "false")]);
    let pair = data::load_pair(&c).unwrap();
    let mut s = session(&c, 2, 128);
    let m = s.train(&pair.train, &pair.test).unwrap();
    assert!(m.pruned_frac.is_empty(), "tracking disabled via config");
    assert!(m.mask_flips.is_empty());
}

#[test]
fn seed_sweep_aggregates() {
    let Some(dir) = artifacts() else { return };
    let mut c = cfg(&dir, "priot", &[]);
    c.epochs = 2;
    c.limit = 128;
    let pair = data::load_pair(&c).unwrap();
    let opts = priot::coordinator::RunOptions::from_config(&c);
    let sweep = priot::coordinator::sweep_seeds(
        &c, &pair.train, &pair.test, &opts, &[1, 2, 3]).unwrap();
    assert_eq!(sweep.runs.len(), 3);
    assert_eq!(sweep.best.n, 3);
    assert!(sweep.best.mean > 0.3);
}

#[test]
fn vgg_engine_runs_a_step() {
    // The CIFAR-10 stand-in at width 0.25: one training step.
    let Some(dir) = artifacts() else { return };
    let mut c = cfg(&dir, "priot", &[("model", "vgg11w0.25"),
                                     ("dataset", "patterns")]);
    c.epochs = 1;
    let pair = data::load_pair(&c).unwrap();
    let spec = NetSpec::vgg11(0.25);
    data::validate(&pair.train, &spec).unwrap();
    let mut s = Session::from_experiment(&c).unwrap();
    let mut img = vec![0i32; pair.train.image_len()];
    pair.train.image_i32(0, &mut img);
    let out = s.train_step(&img, pair.train.label(0));
    assert_eq!(out.logits.len(), 10);
}

#[test]
fn table2_orderings_hold_on_host_measurements() {
    use priot::report::experiments;
    let Some(dir) = artifacts() else { return };
    let md = experiments::table2(&dir, "tinycnn", 30).unwrap();
    // parse host ms column ordering: PRIOT-S < static < PRIOT
    let get = |needle: &str| -> f64 {
        let line = md.lines().find(|l| l.contains(needle)).unwrap();
        let cell = line.split('|').nth(2).unwrap().trim();
        cell.split_whitespace().next().unwrap().parse().unwrap()
    };
    let t_static = get("Static-Scale NITI");
    let t_priot = get("PRIOT |");
    let t_p90 = get("p=90%");
    // The paper's Table II ordering is asserted on the Pico cycle model
    // (pico::tests); host timings on a superscalar x86 only sanity-bound:
    // PRIOT-S must not be dramatically slower than the dense variants.
    assert!(t_p90 < t_priot * 1.5, "host: PRIOT-S {t_p90} ≲ PRIOT {t_priot}");
    assert!(t_priot < t_static * 3.0, "host: PRIOT {t_priot} ≲ 3×static {t_static}");
}
