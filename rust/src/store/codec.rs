//! The versioned binary codec for [`DeviceSnapshot`]s.
//!
//! One snapshot is one self-contained blob (the unit a [`StateStore`]
//! persists).  Layout, all integers little-endian:
//!
//! ```text
//! u32 magic   "PRST" (0x50525354)
//! u8  version (= SNAPSHOT_VERSION)
//! str device, str model            (u32 len + utf8 bytes each)
//! u32 seed
//! method spec                      (the proto wire encoding)
//! u32 step                         (executed training steps)
//! u64 eval_batch, u64 limit
//! u64 epochs_done
//! opt u32 angle                    (u8 presence flag + value)
//! u8  state tag (0 = scores+masks, 1 = weights)
//!   tag 0: u32 layers, layers × (u32 len + len·i32 scores),
//!          layers × (u32 len + len·i32 masks)
//!   tag 1: u32 layers, layers × (u32 len + len·i32 weights)
//! dataset train, dataset test      (u32 n,c,h,w + pixels + labels)
//! u64 FNV-1a of everything above
//! ```
//!
//! Values are exact i32 — unlike the int8 checkpoint files
//! ([`crate::serial::save_weights`]), a snapshot never narrows state, so
//! rehydration is provably lossless.  Decoding follows the
//! `serial`/`proto` checked discipline (every read names what it reads;
//! truncation and trailing bytes are contextful errors at the failing
//! offset), and the trailing FNV-1a checksum rejects corruption that
//! would otherwise still parse.
//!
//! [`StateStore`]: super::StateStore

use anyhow::{bail, Context, Result};

use crate::datagen::fnv1a64;
use crate::proto::codec::{
    put_dataset, put_method, put_opt_u32, put_str, put_u32, put_u64, Reader,
};

use super::{DeviceSnapshot, PluginState, SessionSnapshot};

/// "PRST" — the snapshot file magic (sibling of serial's PRWT/PRDS).
pub const SNAPSHOT_MAGIC: u32 = 0x5052_5354;

/// Snapshot layout revision.  Bump on any layout change; decoders reject
/// other versions with a clean error.
pub const SNAPSHOT_VERSION: u8 = 1;

const STATE_SCORES: u8 = 0;
const STATE_WEIGHTS: u8 = 1;

fn put_vec_i32(buf: &mut Vec<u8>, v: &[i32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_layers(buf: &mut Vec<u8>, layers: &[Vec<i32>]) {
    for l in layers {
        put_vec_i32(buf, l);
    }
}

/// Encode one snapshot (including the trailing checksum).
pub fn encode_snapshot(snap: &DeviceSnapshot) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u32(&mut buf, SNAPSHOT_MAGIC);
    buf.push(SNAPSHOT_VERSION);
    put_str(&mut buf, &snap.device);
    let s = &snap.session;
    put_str(&mut buf, &s.model);
    put_u32(&mut buf, s.seed);
    put_method(&mut buf, &s.method);
    put_u32(&mut buf, s.step);
    put_u64(&mut buf, s.eval_batch as u64);
    put_u64(&mut buf, s.limit as u64);
    put_u64(&mut buf, snap.epochs_done);
    put_opt_u32(&mut buf, snap.angle);
    match &s.state {
        PluginState::Scores { scores, masks } => {
            debug_assert_eq!(scores.len(), masks.len());
            buf.push(STATE_SCORES);
            put_u32(&mut buf, scores.len() as u32);
            put_layers(&mut buf, scores);
            put_layers(&mut buf, masks);
        }
        PluginState::Weights(weights) => {
            buf.push(STATE_WEIGHTS);
            put_u32(&mut buf, weights.len() as u32);
            put_layers(&mut buf, weights);
        }
    }
    put_dataset(&mut buf, &snap.train);
    put_dataset(&mut buf, &snap.test);
    let hash = fnv1a64(&buf);
    put_u64(&mut buf, hash);
    buf
}

/// Per-layer count bound, mirroring `serial::load_weights`' "implausible
/// tensor count" guard — a corrupt header must not size huge allocations.
const MAX_LAYERS: usize = 1024;
/// Per-layer value bound (i32 count): 256 MiB of i32s.
const MAX_LAYER_LEN: usize = 64 << 20;

fn read_vec_i32(r: &mut Reader<'_>, what: &str) -> Result<Vec<i32>> {
    let len = r.u32(what)? as usize;
    if len > MAX_LAYER_LEN {
        bail!("{what}: implausible length {len}");
    }
    let raw = r.take(len * 4, what)?;
    Ok(raw
        .chunks_exact(4)
        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_layers(r: &mut Reader<'_>, n: usize, what: &str)
               -> Result<Vec<Vec<i32>>> {
    (0..n)
        .map(|li| read_vec_i32(r, &format!("{what} layer {li}")))
        .collect()
}

/// Decode one snapshot, verifying structure *and* the trailing checksum.
pub fn decode_snapshot(bytes: &[u8]) -> Result<DeviceSnapshot> {
    if bytes.len() < 8 {
        bail!("snapshot truncated: {} bytes is too short to carry a \
               checksum", bytes.len());
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let mut r = Reader::new(body);
    let magic = r.u32("snapshot magic")?;
    if magic != SNAPSHOT_MAGIC {
        bail!("bad snapshot magic {magic:#x} (want PRST)");
    }
    let version = r.u8("snapshot version")?;
    if version != SNAPSHOT_VERSION {
        bail!("unsupported snapshot version {version} \
               (this build reads version {SNAPSHOT_VERSION})");
    }
    let device = r.str("snapshot device")?;
    let model = r.str("snapshot model")?;
    let seed = r.u32("snapshot seed")?;
    let method = r.method()?;
    let step = r.u32("snapshot step")?;
    let eval_batch = r.u64("snapshot eval_batch")? as usize;
    let limit = r.u64("snapshot limit")? as usize;
    let epochs_done = r.u64("snapshot epochs_done")?;
    let angle = r.opt_u32("snapshot angle")?;
    let state = match r.u8("snapshot state tag")? {
        STATE_SCORES => {
            let n = r.u32("snapshot layer count")? as usize;
            if n > MAX_LAYERS {
                bail!("snapshot has an implausible layer count {n}");
            }
            let scores = read_layers(&mut r, n, "snapshot scores")?;
            let masks = read_layers(&mut r, n, "snapshot masks")?;
            PluginState::Scores { scores, masks }
        }
        STATE_WEIGHTS => {
            let n = r.u32("snapshot layer count")? as usize;
            if n > MAX_LAYERS {
                bail!("snapshot has an implausible layer count {n}");
            }
            PluginState::Weights(read_layers(&mut r, n, "snapshot weights")?)
        }
        other => bail!("unknown snapshot state tag {other}"),
    };
    let train = r.dataset("snapshot train set")?;
    let test = r.dataset("snapshot test set")?;
    r.finish("the snapshot body")?;
    let want = u64::from_le_bytes([
        tail[0], tail[1], tail[2], tail[3], tail[4], tail[5], tail[6], tail[7],
    ]);
    let got = fnv1a64(body);
    if got != want {
        bail!("snapshot checksum mismatch (stored {want:#018x}, computed \
               {got:#018x}) — the file is corrupt");
    }
    Ok(DeviceSnapshot {
        device,
        session: SessionSnapshot {
            model,
            seed,
            method,
            step,
            eval_batch,
            limit,
            state,
        },
        train,
        test,
        epochs_done,
        angle,
    })
}

// Decode context helper shared by the stores: name the device so a bad
// snapshot error says whose state failed.
pub(super) fn decode_for(device: &str, bytes: &[u8]) -> Result<DeviceSnapshot> {
    let snap = decode_snapshot(bytes)
        .with_context(|| format!("decoding the snapshot of device {device}"))?;
    if snap.device != device {
        bail!(
            "snapshot stored under device {device} names device {} — \
             store layout corrupt",
            snap.device
        );
    }
    Ok(snap)
}
