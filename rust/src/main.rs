//! `priot` — the on-device-learning CLI.
//!
//! ```text
//! priot train   --method priot --angle 30 --epochs 30 [--backend pjrt]
//! priot eval    --model tinycnn --dataset digits --angle 30
//! priot compare [--epochs 8] [--limit 384]        all methods, one seed
//! priot table1  [--full]                          Table I
//! priot table2  [--iters 100]                     Table II
//! priot fig2    [--epochs 12]                     Fig. 2 CSV
//! priot fig3    [--full]                          Fig. 3 CSV
//! priot ablation                                  design-choice sweeps
//! priot pico-report [--model tinycnn]             memory/cycle breakdown
//! priot selftest                                  engine ⇄ PJRT parity
//! ```
//!
//! Common flags: `--artifacts DIR` (default `artifacts`), `--config FILE`,
//! any `ExperimentConfig` key as `--key value`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use priot::cli::Args;
use priot::config::{ExperimentConfig, Method, Selection};
use priot::coordinator::{run_training, RunOptions};
use priot::data;
use priot::methods::EngineBackend;
use priot::pico;
use priot::quant::Scales;
use priot::report::experiments::{self, Scale};
use priot::report::sparkline;
use priot::spec::NetSpec;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn scale_from(args: &Args) -> Result<Scale> {
    let mut s = if args.has_flag("full") { Scale::full() } else { Scale::quick() };
    if let Some(e) = args.option("epochs") {
        s.epochs = e.parse()?;
    }
    if let Some(l) = args.option("limit") {
        s.limit = l.parse()?;
    }
    if let Some(n) = args.option("seeds") {
        s.seeds = n.parse()?;
    }
    if args.has_flag("with-vgg") {
        s.include_vgg = true;
    }
    if args.has_flag("no-vgg") {
        s.include_vgg = false;
    }
    Ok(s)
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.option("artifacts").unwrap_or("artifacts"))
}

fn write_or_print(args: &Args, default_name: &str, content: &str) -> Result<()> {
    match args.option("out") {
        Some(path) => {
            std::fs::write(path, content)?;
            eprintln!("wrote {path}");
        }
        None => {
            let dir = Path::new("results");
            std::fs::create_dir_all(dir)?;
            let path = dir.join(default_name);
            std::fs::write(&path, content)?;
            println!("{content}");
            eprintln!("(also wrote {})", path.display());
        }
    }
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::parse_env()?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "compare" => cmd_compare(&args),
        "table1" => {
            let md = experiments::table1(&artifacts_dir(&args), scale_from(&args)?)?;
            write_or_print(&args, "table1.md", &md)
        }
        "table2" => {
            let iters = args.option("iters").unwrap_or("100").parse()?;
            let model = args.option("model").unwrap_or("tinycnn");
            let md = experiments::table2(&artifacts_dir(&args), model, iters)?;
            write_or_print(&args, "table2.md", &md)
        }
        "fig2" => {
            let epochs = args.option("epochs").unwrap_or("12").parse()?;
            let limit = args.option("limit").unwrap_or("512").parse()?;
            let csv = experiments::fig2(&artifacts_dir(&args), epochs, limit)?;
            write_or_print(&args, "fig2.csv", &csv)
        }
        "fig3" => {
            let (csv, _) = experiments::fig3(&artifacts_dir(&args), scale_from(&args)?)?;
            write_or_print(&args, "fig3.csv", &csv)
        }
        "ablation" => {
            let csv = experiments::ablation(&artifacts_dir(&args), scale_from(&args)?)?;
            write_or_print(&args, "ablation.csv", &csv)
        }
        "pico-report" => cmd_pico_report(&args),
        "calibrate" => cmd_calibrate(&args),
        "selftest" => {
            let report = experiments::selftest(&artifacts_dir(&args))?;
            println!("{report}");
            Ok(())
        }
        "" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (run `priot` for help)"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_config(&args.to_config()?)?;
    let pair = data::load_pair(&cfg)?;
    let spec = NetSpec::by_name(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {}", cfg.model))?;
    data::validate(&pair.train, &spec)?;
    let mut opts = RunOptions::from_config(&cfg);
    opts.verbose = true;
    let metrics = match cfg.backend.as_str() {
        "engine" => {
            let mut b = EngineBackend::from_config(&cfg)?;
            if let Some(resume) = args.option("resume") {
                b.load_state(Path::new(resume))?;
                eprintln!("resumed training state from {resume}");
            }
            let m = run_training(&mut b, &pair.train, &pair.test, &opts);
            if let Some(save) = args.option("checkpoint") {
                b.save_state(Path::new(save))?;
                eprintln!("saved training state to {save}");
            }
            m
        }
        "pjrt" => {
            let rt = priot::runtime::Runtime::new(&cfg.artifacts_dir)?;
            eprintln!("PJRT platform: {}", rt.platform());
            let mut b = priot::runtime::PjrtBackend::from_config(&cfg, &rt)?;
            run_training(&mut b, &pair.train, &pair.test, &opts)
        }
        other => bail!("unknown backend {other} (engine|pjrt)"),
    };
    println!("method:   {} ({} @ {}°)", cfg.method.name(), cfg.dataset, cfg.angle);
    println!("backend:  {}", cfg.backend);
    println!("history:  {}", sparkline(&metrics.accuracy));
    println!(
        "accuracy: before {:.2}%  best {:.2}%  final {:.2}%",
        metrics.accuracy[0] * 100.0,
        metrics.best_accuracy() * 100.0,
        metrics.final_accuracy() * 100.0
    );
    if !metrics.pruned_frac.is_empty() {
        let last = metrics.pruned_frac.last().unwrap();
        let fr: Vec<String> = last.iter().map(|f| format!("{:.1}%", f * 100.0)).collect();
        println!("pruned:   [{}]", fr.join(", "));
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_config(&args.to_config()?)?;
    let pair = data::load_pair(&cfg)?;
    let mut b = EngineBackend::from_config(&cfg)?;
    let acc = priot::coordinator::evaluate(&mut b, &pair.test, cfg.limit);
    println!(
        "{} on {}_test_a{}: top-1 {:.2}% (n={})",
        cfg.model,
        cfg.dataset,
        cfg.angle,
        acc * 100.0,
        if cfg.limit == 0 { pair.test.n } else { pair.test.n.min(cfg.limit) }
    );
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    let artifacts = artifacts_dir(args);
    println!("| Method | Best top-1 | Final | History |");
    println!("|---|---|---|---|");
    for (label, method, frac, sel) in [
        ("Static-Scale NITI", Method::StaticNiti, 0.0, Selection::Random),
        ("Dynamic-Scale NITI", Method::DynamicNiti, 0.0, Selection::Random),
        ("PRIOT", Method::Priot, 1.0, Selection::Random),
        ("PRIOT-S (p=90%, weight)", Method::PriotS, 0.1, Selection::WeightBased),
        ("PRIOT-S (p=80%, weight)", Method::PriotS, 0.2, Selection::WeightBased),
    ] {
        let mut c = priot::config::Config::default();
        c.set("artifacts", artifacts.to_str().unwrap_or("artifacts"));
        c.set("method", method.name());
        let mut cfg = ExperimentConfig::from_config(&c)?;
        cfg.epochs = scale.epochs;
        cfg.limit = scale.limit;
        cfg.frac_scored = frac;
        cfg.selection = sel;
        let pair = data::load_pair(&cfg)?;
        let mut b = EngineBackend::from_config(&cfg)?;
        let opts = RunOptions::from_config(&cfg);
        let m = run_training(&mut b, &pair.train, &pair.test, &opts);
        println!(
            "| {} | {:.2}% | {:.2}% | {} |",
            label,
            m.best_accuracy() * 100.0,
            m.final_accuracy() * 100.0,
            sparkline(&m.accuracy)
        );
    }
    Ok(())
}

/// On-device recalibration: re-derive the static scale table from local
/// data using the engine's dynamic-shift calibrator (paper §IV-A run on the
/// device side — useful when the deployment distribution drifts so far that
/// the shipped scales saturate).
fn cmd_calibrate(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_config(&args.to_config()?)?;
    let pair = data::load_pair(&cfg)?;
    let n: usize = args.option("samples").unwrap_or("64").parse()?;
    let mut b = EngineBackend::from_config(&cfg)?;
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for i in 0..n.min(pair.train.n) {
        let mut img = vec![0i32; pair.train.image_len()];
        pair.train.image_i32(i, &mut img);
        images.push(img);
        labels.push(pair.train.label(i));
    }
    let scales = b.engine.calibrate(&images, &labels);
    let text = scales.to_text();
    match args.option("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            eprintln!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_pico_report(args: &Args) -> Result<()> {
    let model = args.option("model").unwrap_or("tinycnn");
    let artifacts = artifacts_dir(args);
    let spec = NetSpec::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let scales = Scales::load(&artifacts.join(format!("{model}.scales.txt")))
        .unwrap_or_else(|_| Scales::default_for(spec.layers.len()));
    println!("# RP2040 cost model: {model}");
    println!("params: {}  fwd MACs: {}", spec.num_params(), spec.fwd_macs());
    println!();
    println!("| Method | Pico time [ms] | fwd | bwd | upd | mask | dyn | Memory [B] | Fits 264KB |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for (label, p) in [
        ("static-niti", pico::MethodParams::new(Method::StaticNiti)),
        ("dynamic-niti", pico::MethodParams::new(Method::DynamicNiti)),
        ("priot", pico::MethodParams::new(Method::Priot)),
        ("priot-s p=90%", pico::MethodParams::priot_s(0.1, Selection::Random)),
        ("priot-s p=80%", pico::MethodParams::priot_s(0.2, Selection::Random)),
    ] {
        let c = pico::step_cost(&spec, &scales, p);
        let m = pico::memory_footprint(&spec, p);
        println!(
            "| {} | {:.2} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | {} | {} |",
            label,
            c.total_ms(),
            c.fwd_cycles / pico::CLOCK_HZ * 1e3,
            c.bwd_cycles / pico::CLOCK_HZ * 1e3,
            c.update_cycles / pico::CLOCK_HZ * 1e3,
            c.mask_cycles / pico::CLOCK_HZ * 1e3,
            c.dynamic_cycles / pico::CLOCK_HZ * 1e3,
            m.total(),
            if pico::fits_pico(&m) { "yes" } else { "NO" }
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "priot — pruning-based integer-only transfer learning (PRIOT, IEEE ESL 2025)\n\n\
         subcommands:\n\
         \x20 train        run one on-device training session\n\
         \x20 eval         evaluate the backbone on a dataset\n\
         \x20 compare      all methods side-by-side (one seed)\n\
         \x20 table1       regenerate Table I  (accuracy per method)\n\
         \x20 table2       regenerate Table II (time + memory on the Pico model)\n\
         \x20 fig2         regenerate Fig. 2   (overflow collapse trace)\n\
         \x20 fig3         regenerate Fig. 3   (accuracy history)\n\
         \x20 ablation     threshold / rounding-mode sweeps\n\
         \x20 pico-report  RP2040 cycle + SRAM breakdown\n\
         \x20 calibrate    re-derive static scales from local data\n\
         \x20 selftest     engine ⇄ PJRT bit-parity check\n\n\
         common flags: --artifacts DIR  --config FILE  --full  --epochs N\n\
         \x20             --limit N  --seeds N  --method M  --angle A  --out FILE"
    );
}
