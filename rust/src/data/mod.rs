//! Dataset access layer: resolves experiment configs to the artifact
//! datasets and provides the streaming view the coordinator consumes.
//!
//! Dataset *generation* is build-time Python (`python/compile/dataset.py`,
//! the RotDigits / RotPatterns procedural generators standing in for
//! rotated MNIST / CIFAR-10 — DESIGN.md §2); this module only loads the
//! exported binary files.

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::serial::{load_dataset, Dataset};

/// The train/test pair for one on-device adaptation session.
pub struct DataPair {
    pub train: Dataset,
    pub test: Dataset,
}

pub fn load_pair(cfg: &ExperimentConfig) -> Result<DataPair> {
    let train = load_dataset(&cfg.train_dataset_path())
        .context("loading train set (did you run `make artifacts`?)")?;
    let test = load_dataset(&cfg.test_dataset_path())?;
    Ok(DataPair { train, test })
}

/// Load a dataset by stem name, e.g. `digits_test_a30`.
pub fn load_named(artifacts: &std::path::Path, stem: &str) -> Result<Dataset> {
    load_dataset(&artifacts.join("data").join(format!("{stem}.bin")))
}

/// Image-side checks against a model spec: geometry plus pixel-payload
/// consistency.  Sufficient for prediction-only paths, which never read
/// labels (an inference set may carry sentinel labels).
pub fn validate_images(ds: &Dataset, spec: &crate::spec::NetSpec)
                       -> Result<()> {
    let (c, h, w) = spec.input_chw;
    if (ds.c, ds.h, ds.w) != (c, h, w) {
        anyhow::bail!(
            "dataset geometry ({},{},{}) does not match model {} ({c},{h},{w})",
            ds.c, ds.h, ds.w, spec.name
        );
    }
    // Internal consistency: the payload must actually hold what the
    // header dims promise (loaders enforce this on disk, but in-memory
    // datasets can be assembled by hand).
    let want_pixels = [ds.n, ds.c, ds.h, ds.w]
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d));
    if want_pixels != Some(ds.images.len()) {
        anyhow::bail!(
            "dataset holds {} pixel bytes but n·c·h·w = {}·{}·{}·{}",
            ds.images.len(), ds.n, ds.c, ds.h, ds.w
        );
    }
    Ok(())
}

/// Full sanity checks a dataset against a model spec:
/// [`validate_images`] plus label count and range.  The Session/Fleet/
/// serve training and evaluation entry points call this so a bad dataset
/// is a clean `Err`, never a slice panic deep inside the engine.
pub fn validate(ds: &Dataset, spec: &crate::spec::NetSpec) -> Result<()> {
    validate_images(ds, spec)?;
    if ds.labels.len() != ds.n {
        anyhow::bail!("dataset holds {} labels for n = {} samples",
                      ds.labels.len(), ds.n);
    }
    let classes = spec.num_classes();
    if let Some(&bad) = ds.labels.iter().find(|&&l| (l as usize) >= classes) {
        anyhow::bail!("label {bad} out of range for {classes} classes");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::NetSpec;

    #[test]
    fn validate_rejects_geometry_mismatch() {
        let ds = Dataset {
            n: 1,
            c: 3,
            h: 32,
            w: 32,
            images: vec![0; 3 * 32 * 32],
            labels: vec![0],
        };
        assert!(validate(&ds, &NetSpec::tinycnn()).is_err());
        assert!(validate(&ds, &NetSpec::vgg11(0.25)).is_ok());
    }

    #[test]
    fn validate_rejects_bad_labels() {
        let ds = Dataset {
            n: 1,
            c: 1,
            h: 28,
            w: 28,
            images: vec![0; 28 * 28],
            labels: vec![10],
        };
        assert!(validate(&ds, &NetSpec::tinycnn()).is_err());
    }

    #[test]
    fn validate_rejects_inconsistent_payloads() {
        // Right geometry, wrong payload lengths: must be a clean Err, not
        // a later slice panic in Dataset::image / Dataset::label.
        let short_images = Dataset {
            n: 4,
            c: 1,
            h: 28,
            w: 28,
            images: vec![0; 28 * 28], // holds 1 sample, claims 4
            labels: vec![0; 4],
        };
        let err = validate(&short_images, &NetSpec::tinycnn()).unwrap_err();
        assert!(err.to_string().contains("pixel bytes"), "{err}");

        let short_labels = Dataset {
            n: 2,
            c: 1,
            h: 28,
            w: 28,
            images: vec![0; 2 * 28 * 28],
            labels: vec![0], // holds 1 label, claims 2
        };
        let err = validate(&short_labels, &NetSpec::tinycnn()).unwrap_err();
        assert!(err.to_string().contains("labels"), "{err}");
    }

    #[test]
    fn validate_images_ignores_labels() {
        // Inference-only datasets may carry sentinel labels; the
        // prediction path must accept them while full validation rejects.
        let ds = Dataset {
            n: 1,
            c: 1,
            h: 28,
            w: 28,
            images: vec![0; 28 * 28],
            labels: vec![255],
        };
        let spec = NetSpec::tinycnn();
        assert!(validate_images(&ds, &spec).is_ok());
        assert!(validate(&ds, &spec).is_err());
    }
}
