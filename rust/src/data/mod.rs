//! Dataset access layer: resolves experiment configs to the artifact
//! datasets and provides the streaming view the coordinator consumes.
//!
//! Dataset *generation* is build-time Python (`python/compile/dataset.py`,
//! the RotDigits / RotPatterns procedural generators standing in for
//! rotated MNIST / CIFAR-10 — DESIGN.md §2); this module only loads the
//! exported binary files.

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::serial::{load_dataset, Dataset};

/// The train/test pair for one on-device adaptation session.
pub struct DataPair {
    pub train: Dataset,
    pub test: Dataset,
}

pub fn load_pair(cfg: &ExperimentConfig) -> Result<DataPair> {
    let train = load_dataset(&cfg.train_dataset_path())
        .context("loading train set (did you run `make artifacts`?)")?;
    let test = load_dataset(&cfg.test_dataset_path())?;
    Ok(DataPair { train, test })
}

/// Load a dataset by stem name, e.g. `digits_test_a30`.
pub fn load_named(artifacts: &std::path::Path, stem: &str) -> Result<Dataset> {
    load_dataset(&artifacts.join("data").join(format!("{stem}.bin")))
}

/// Sanity checks a dataset against a model spec.
pub fn validate(ds: &Dataset, spec: &crate::spec::NetSpec) -> Result<()> {
    let (c, h, w) = spec.input_chw;
    if (ds.c, ds.h, ds.w) != (c, h, w) {
        anyhow::bail!(
            "dataset geometry ({},{},{}) does not match model {} ({c},{h},{w})",
            ds.c, ds.h, ds.w, spec.name
        );
    }
    let classes = spec.num_classes();
    if let Some(&bad) = ds.labels.iter().find(|&&l| (l as usize) >= classes) {
        anyhow::bail!("label {bad} out of range for {classes} classes");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::NetSpec;

    #[test]
    fn validate_rejects_geometry_mismatch() {
        let ds = Dataset {
            n: 1,
            c: 3,
            h: 32,
            w: 32,
            images: vec![0; 3 * 32 * 32],
            labels: vec![0],
        };
        assert!(validate(&ds, &NetSpec::tinycnn()).is_err());
        assert!(validate(&ds, &NetSpec::vgg11(0.25)).is_ok());
    }

    #[test]
    fn validate_rejects_bad_labels() {
        let ds = Dataset {
            n: 1,
            c: 1,
            h: 28,
            w: 28,
            images: vec![0; 28 * 28],
            labels: vec![10],
        };
        assert!(validate(&ds, &NetSpec::tinycnn()).is_err());
    }
}
