//! Training-method layer: the four methods of Table I as engine-agnostic
//! state machines, plus the [`StepBackend`] trait that lets the coordinator
//! drive either the pure-Rust engine or the AOT/PJRT runtime
//! interchangeably (their bit-equality is asserted in `rust/tests/`).

use anyhow::{bail, Result};

use crate::config::{ExperimentConfig, Method, Selection};
use crate::engine::{Engine, PruneState, StepOut};
use crate::prng::{init_scores, select_mask_random, XorShift32};
use crate::spec::NetSpec;

/// One training backend: consumes (image, label) pairs, produces logits and
/// the overflow probe; owns all mutable training state (weights or scores).
pub trait StepBackend {
    /// One on-device training step (batch 1).
    fn train_step(&mut self, img: &[i32], label: usize) -> StepOut;
    /// Inference for evaluation.
    fn predict(&mut self, img: &[i32]) -> usize;
    /// Current scores, if the method has them (analysis/checkpointing).
    fn scores(&self) -> Option<&[Vec<i32>]>;
    /// PRIOT-S existence masks, if any.
    fn masks(&self) -> Option<&[Vec<i32>]>;
    /// Pruning threshold θ, if the method prunes.
    fn theta(&self) -> Option<i32>;
    /// Backend label for logs.
    fn name(&self) -> &str;
}

/// Per-method mutable state (scores live here; NITI's weights live in the
/// engine itself).
pub enum MethodState {
    Niti { dynamic: bool },
    Priot {
        scores: Vec<Vec<i32>>,
        masks: Vec<Vec<i32>>,
        theta: i32,
        sr: bool,
        /// PRIOT-S fast path: skip gradient work for unscored edges.
        sparse: bool,
    },
}

impl MethodState {
    /// Initialize method state for `cfg` against the given spec/weights.
    /// Scores are drawn from the shared xorshift stream seeded by
    /// `cfg.seed`; PRIOT-S masks by `cfg.selection`.
    pub fn build(cfg: &ExperimentConfig, spec: &NetSpec,
                 weights: &[crate::tensor::Mat]) -> Result<Self> {
        Ok(match cfg.method {
            Method::StaticNiti => MethodState::Niti { dynamic: false },
            Method::DynamicNiti => MethodState::Niti { dynamic: true },
            Method::Priot => {
                let mut rng = XorShift32::new(cfg.seed);
                let scores = spec
                    .layers
                    .iter()
                    .map(|l| widen(init_scores(&mut rng, l.num_params())))
                    .collect();
                let masks =
                    spec.layers.iter().map(|l| vec![1i32; l.num_params()]).collect();
                MethodState::Priot { scores, masks, theta: cfg.theta, sr: false,
                                     sparse: false }
            }
            Method::PriotS => {
                if !(0.0..=1.0).contains(&cfg.frac_scored) {
                    bail!("frac_scored must be in [0,1], got {}", cfg.frac_scored);
                }
                let mut rng = XorShift32::new(cfg.seed);
                let scores: Vec<Vec<i32>> = spec
                    .layers
                    .iter()
                    .map(|l| widen(init_scores(&mut rng, l.num_params())))
                    .collect();
                let masks = match cfg.selection {
                    Selection::Random => spec
                        .layers
                        .iter()
                        .map(|l| {
                            select_mask_random(&mut rng, l.num_params(),
                                               cfg.frac_scored)
                                .into_iter()
                                .map(i32::from)
                                .collect()
                        })
                        .collect(),
                    Selection::WeightBased => select_mask_weight(
                        weights, cfg.frac_scored),
                };
                MethodState::Priot { scores, masks, theta: cfg.theta, sr: false,
                                     sparse: true }
            }
        })
    }
}

fn widen(v: Vec<i8>) -> Vec<i32> {
    v.into_iter().map(|x| x as i32).collect()
}

/// PRIOT-S weight-based selection: score the largest-|W| edges per layer.
/// Deterministic, stable ordering by (-|w|, flat index) — bit-compatible
/// with `intnet.select_mask_weight`.
pub fn select_mask_weight(weights: &[crate::tensor::Mat], frac_scored: f64)
                          -> Vec<Vec<i32>> {
    weights
        .iter()
        .map(|w| {
            let n = w.data.len();
            let k = (frac_scored * n as f64).round() as usize;
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&i| (-(w.data[i].abs() as i64), i));
            let mut m = vec![0i32; n];
            for &i in order.iter().take(k) {
                m[i] = 1;
            }
            m
        })
        .collect()
}

/// The pure-Rust backend: engine + method state + step counter.
pub struct EngineBackend {
    pub engine: Engine,
    pub state: MethodState,
    pub step: u32,
    label: String,
}

impl EngineBackend {
    pub fn new(engine: Engine, state: MethodState) -> Self {
        let label = match &state {
            MethodState::Niti { dynamic: true } => "engine/dynamic-niti",
            MethodState::Niti { dynamic: false } => "engine/static-niti",
            MethodState::Priot { .. } => "engine/priot",
        };
        Self { engine, state, step: 0, label: label.to_string() }
    }

    /// Build from an experiment config (loads weights/scales from
    /// artifacts).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        let spec = NetSpec::by_name(&cfg.model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {}", cfg.model))?;
        let tensors = crate::serial::load_weights(&cfg.weights_path())?;
        let scales = crate::quant::Scales::load(&cfg.scales_path())?;
        let engine = Engine::from_tensors(spec.clone(), &tensors, scales)?;
        let state = MethodState::build(cfg, &spec, &engine.weights)?;
        Ok(Self::new(engine, state))
    }
}

impl EngineBackend {
    /// Checkpoint the trained state: PRIOT scores (plus masks so a resumed
    /// PRIOT-S run prunes identically), or NITI's updated weights.
    pub fn save_state(&self, path: &std::path::Path) -> Result<()> {
        use crate::serial::{save_weights, TensorI8};
        let narrow = |v: &Vec<i32>, shape: (usize, usize)| TensorI8 {
            dims: vec![shape.0, shape.1],
            data: v.iter().map(|&x| x as i8).collect(),
        };
        let shapes: Vec<(usize, usize)> =
            self.engine.spec.layers.iter().map(|l| l.weight_shape()).collect();
        let tensors: Vec<TensorI8> = match &self.state {
            MethodState::Priot { scores, masks, .. } => scores
                .iter()
                .chain(masks.iter())
                .zip(shapes.iter().chain(shapes.iter()))
                .map(|(v, &s)| narrow(v, s))
                .collect(),
            MethodState::Niti { .. } => self
                .engine
                .weights
                .iter()
                .zip(shapes.iter())
                .map(|(m, &s)| narrow(&m.data, s))
                .collect(),
        };
        save_weights(path, &tensors)
    }

    /// Restore a checkpoint produced by [`Self::save_state`] (same method
    /// and model).
    pub fn load_state(&mut self, path: &std::path::Path) -> Result<()> {
        let tensors = crate::serial::load_weights(path)?;
        let n = self.engine.spec.layers.len();
        match &mut self.state {
            MethodState::Priot { scores, masks, .. } => {
                if tensors.len() != 2 * n {
                    bail!("checkpoint has {} tensors, want {} (scores+masks)",
                          tensors.len(), 2 * n);
                }
                for (li, s) in scores.iter_mut().enumerate() {
                    let t = tensors[li].to_i32();
                    if t.len() != s.len() {
                        bail!("checkpoint layer {li} size mismatch");
                    }
                    s.copy_from_slice(&t);
                }
                for (li, m) in masks.iter_mut().enumerate() {
                    let t = tensors[n + li].to_i32();
                    if t.len() != m.len() {
                        bail!("checkpoint mask {li} size mismatch");
                    }
                    m.copy_from_slice(&t);
                }
            }
            MethodState::Niti { .. } => {
                if tensors.len() != n {
                    bail!("checkpoint has {} tensors, want {n}", tensors.len());
                }
                for (li, w) in self.engine.weights.iter_mut().enumerate() {
                    let t = tensors[li].to_i32();
                    if t.len() != w.data.len() {
                        bail!("checkpoint layer {li} size mismatch");
                    }
                    w.data.copy_from_slice(&t);
                }
            }
        }
        Ok(())
    }
}

impl StepBackend for EngineBackend {
    fn train_step(&mut self, img: &[i32], label: usize) -> StepOut {
        let out = match &mut self.state {
            MethodState::Niti { dynamic } => {
                self.engine.step_niti(img, label, *dynamic, self.step)
            }
            MethodState::Priot { scores, masks, theta, sr, sparse } => self
                .engine
                .step_priot(img, label, scores, masks, *theta, self.step, *sr,
                            *sparse),
        };
        self.step += 1;
        out
    }

    fn predict(&mut self, img: &[i32]) -> usize {
        match &self.state {
            MethodState::Niti { .. } => self.engine.predict(img, None),
            MethodState::Priot { scores, masks, theta, .. } => {
                let prune = PruneState { scores, masks, theta: *theta };
                self.engine.predict(img, Some(&prune))
            }
        }
    }

    fn scores(&self) -> Option<&[Vec<i32>]> {
        match &self.state {
            MethodState::Priot { scores, .. } => Some(scores),
            _ => None,
        }
    }

    fn masks(&self) -> Option<&[Vec<i32>]> {
        match &self.state {
            MethodState::Priot { masks, .. } => Some(masks),
            _ => None,
        }
    }

    fn theta(&self) -> Option<i32> {
        match &self.state {
            MethodState::Priot { theta, .. } => Some(*theta),
            _ => None,
        }
    }

    fn name(&self) -> &str {
        &self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::prng::XorShift64;
    use crate::quant::Scales;
    use crate::tensor::Mat;

    fn test_engine(seed: u64) -> (NetSpec, Engine) {
        let spec = NetSpec::tinycnn();
        let mut rng = XorShift64::new(seed);
        let weights: Vec<Mat> = spec
            .layers
            .iter()
            .map(|l| {
                let (r, c) = l.weight_shape();
                Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
            })
            .collect();
        let e = Engine::new(spec.clone(), weights,
                            Scales::default_for(spec.layers.len())).unwrap();
        (spec, e)
    }

    fn cfg_for(method: &str, selection: &str) -> ExperimentConfig {
        let mut c = Config::default();
        c.set("method", method);
        c.set("selection", selection);
        c.set("frac_scored", "0.1");
        ExperimentConfig::from_config(&c).unwrap()
    }

    #[test]
    fn weight_based_selection_picks_largest() {
        let w = Mat::from_vec(2, 3, vec![5, -100, 3, 50, -2, 1]);
        let m = select_mask_weight(&[w], 0.5);
        // 3 of 6 edges: |100|, |50|, |5| → indices 1, 3, 0
        assert_eq!(m[0], vec![1, 1, 0, 1, 0, 0]);
    }

    #[test]
    fn weight_based_selection_tie_break_by_index() {
        let w = Mat::from_vec(1, 4, vec![7, -7, 7, 7]);
        let m = select_mask_weight(&[w], 0.5);
        assert_eq!(m[0], vec![1, 1, 0, 0], "ties resolve to earliest index");
    }

    #[test]
    fn method_state_priot_s_mask_fraction() {
        let (spec, e) = test_engine(31);
        let cfg = cfg_for("priot-s", "random");
        let st = MethodState::build(&cfg, &spec, &e.weights).unwrap();
        if let MethodState::Priot { masks, theta, .. } = st {
            assert_eq!(theta, 0);
            let total: usize = masks.iter().map(|m| m.len()).sum();
            let ones: i64 = masks.iter().flat_map(|m| m.iter()).map(|&v| v as i64).sum();
            let frac = ones as f64 / total as f64;
            assert!((0.07..0.13).contains(&frac), "frac {frac}");
        } else {
            panic!("wrong state");
        }
    }

    #[test]
    fn seeds_give_different_scores_same_seed_same_scores() {
        let (spec, e) = test_engine(32);
        let mut c1 = cfg_for("priot", "random");
        c1.seed = 7;
        let mut c2 = c1.clone();
        c2.seed = 8;
        let s1 = MethodState::build(&c1, &spec, &e.weights).unwrap();
        let s1b = MethodState::build(&c1, &spec, &e.weights).unwrap();
        let s2 = MethodState::build(&c2, &spec, &e.weights).unwrap();
        let get = |s: &MethodState| match s {
            MethodState::Priot { scores, .. } => scores[0].clone(),
            _ => panic!(),
        };
        assert_eq!(get(&s1), get(&s1b));
        assert_ne!(get(&s1), get(&s2));
    }

    #[test]
    fn backend_step_counter_advances() {
        let (spec, e) = test_engine(33);
        let cfg = cfg_for("priot", "random");
        let st = MethodState::build(&cfg, &spec, &e.weights).unwrap();
        let mut b = EngineBackend::new(e, st);
        let img = vec![1i32; b.engine.spec.input_len()];
        b.train_step(&img, 3);
        b.train_step(&img, 4);
        assert_eq!(b.step, 2);
        assert!(b.scores().is_some());
        assert_eq!(b.theta(), Some(-64));
    }
}
