//! `priot::serve` — a long-lived fleet service behind the
//! [`crate::proto`] wire boundary.
//!
//! [`Fleet`](super::Fleet) runs a *closed* roster of devices to
//! completion; this module is the open-ended counterpart: a service that
//! owns one shared `Arc<`[`Backbone`]`>` plus a registry of per-device
//! [`Session`]s and consumes a **stream** of [`Request`] frames from any
//! number of connected [`FleetClient`]s — register a device, train it
//! some epochs, classify an image, evaluate, or swap its local data when
//! the distribution drifts.
//!
//! Clients connect through a [`Transport`]: in-process over
//! [`FleetServer::local_client`] (mpsc frames) or over TCP via
//! [`FleetServer::listen`] + [`FleetClient::connect`].  Both paths run
//! the same codec and dispatch machinery, so responses are bit-identical
//! whichever transport carries them.
//!
//! ## Scheduling
//!
//! Work is *priority-laned* and *epoch-granular*:
//!
//! * Every queued unit is one operation of one device (one training
//!   epoch, one prediction, one evaluation).  A device with pending work
//!   re-queues at the back after each unit, so a device mid-adaptation
//!   never monopolizes a worker while other devices wait.
//! * Within a device, pending requests drain by [`Priority`]
//!   (predict > evaluate > train, FIFO within a class): an interactive
//!   prediction submitted behind a long `Train` is answered between
//!   training epochs instead of after all of them.  A multi-epoch
//!   `Train` materializes one epoch at a time, so it can be preempted at
//!   every epoch boundary.  `Drift` rides the training lane, preserving
//!   train → drift → train submission order.
//! * The dispatcher enforces a bounded per-device **inflight window**
//!   ([`ServeBuilder::window`]): a device with too many unanswered
//!   requests gets an immediate `Error` response instead of an unbounded
//!   backlog.
//! * **Heavy work never runs on the dispatcher thread.**  `Register` —
//!   dataset validation, session construction, store lookups — executes
//!   on the worker pool like everything else (the dispatcher only
//!   creates the registry entry and queues the register unit at the
//!   head of the device's lanes, so it is guaranteed to run before any
//!   op pipelined behind it).  One slow register therefore cannot stall
//!   dispatch for other connections.
//!
//! Operations of one device never run concurrently, so per-device
//! results are bit-identical to a standalone session executing the same
//! operations in the same order.  A synchronous client (one request in
//! flight) therefore sees exactly standalone behavior; pipelined clients
//! opt into priority reordering (pin everything to
//! [`Priority::Background`] to keep strict submission order).
//!
//! Evaluation goes through the batched forward path
//! ([`Session::evaluate_batch`]) — bit-identical to per-sample, faster.
//!
//! ## Durable state and the LRU of resident sessions
//!
//! With a [`StateStore`] attached ([`ServeBuilder::store`] /
//! [`ServeBuilder::state_dir`]), every device's state is **durable**:
//!
//! * Each completed state-mutating request (`Train`, `Drift`, the
//!   initial `Register`) writes the device's [`DeviceSnapshot`] —
//!   exact-i32 scores/masks/weights, step counter, datasets, epoch
//!   progress, drift-angle provenance — *before* its response is
//!   emitted, so any state a client has been told about survives a
//!   crash.
//! * [`ServeBuilder::resident_cap`]`(N)` bounds **live** sessions: the
//!   registry becomes an LRU over the store.  When more than `N`
//!   devices are resident, the least-recently-used *idle* device (no
//!   pending requests — eviction happens at op-queue idle points, never
//!   mid-request) is flushed and dropped from memory.  Any later
//!   request to an evicted device lazily rehydrates it on the worker
//!   pool — bit-identically, so an evicted-and-rehydrated device's
//!   responses are byte-equal to an always-resident one's.
//! * A `Register` for a device the server already knows — live,
//!   evicted, or recovered from a previous process (`priot serve
//!   --state-dir` rescans the store at startup) — is a **resume**:
//!   state is kept, the supplied datasets are ignored, and the response
//!   says `resumed: true`, making reconnecting clients first-class.
//! * [`FleetServer::join`] flushes all dirty state; a restarted server
//!   over the same store resumes every device where it left off.
//!
//! ```no_run
//! use priot::proto::{FleetClient, MethodSpec};
//! use priot::session::{Backbone, FleetServer};
//!
//! let backbone = Backbone::load("artifacts".as_ref(), "tinycnn")?;
//! # let (train, test): (std::sync::Arc<priot::serial::Dataset>,
//! #                     std::sync::Arc<priot::serial::Dataset>) = todo!();
//! let mut server = FleetServer::builder(backbone)
//!     .threads(4)
//!     .state_dir("fleet-state")?   // durable; restart-resumable
//!     .resident_cap(64)            // LRU-bound live sessions
//!     .build();
//! let addr = server.listen("127.0.0.1:0")?;   // or server.local_client()
//! let mut client = FleetClient::connect(addr)?;
//! client.register("dev-00", 1, MethodSpec::priot(), train, test)?;
//! client.train("dev-00", 2)?;
//! client.evaluate("dev-00")?;
//! drop(client);                    // close the connection...
//! let report = server.join()?;     // ...then drain + flush + shut down
//! println!("{}", report.summary());
//! # anyhow::Ok(())
//! ```
//!
//! The `priot serve` CLI subcommand drives a server from a scripted
//! request trace ([`parse_trace`]; [`DEMO_TRACE`] is a worked sample) or
//! listens on TCP (`--listen`, with `--state-dir`/`--resident-cap` for
//! durability); `priot client` replays a trace against a remote server.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Method;
use crate::coordinator::capped;
use crate::proto::codec;
use crate::proto::{
    ChannelTransport, ErrorKind, FleetClient, MethodSpec, Priority, Request,
    Response, TcpTransport, Transport,
};
use crate::serial::{u8_to_i32_pixels, Dataset};
use crate::store::{DeviceSnapshot, DiskStore, MemStore, StateStore};

use super::{Backbone, Session};

// ---------------------------------------------------------------------------
// Ingress
// ---------------------------------------------------------------------------

/// Reply route of one connection: the worker that completes a request
/// sends `(request id, response)` here; the connection's writer pump
/// encodes and ships it.
#[derive(Clone)]
struct Reply(Sender<(u64, Response)>);

/// One accepted request: decoded frame + its reply route.
struct Inbound {
    id: u64,
    priority: Priority,
    req: Request,
    reply: Reply,
}

/// Decode loop shared by every connection flavor: frames in, [`Inbound`]s
/// out.  A malformed frame is answered — and reported — like any other
/// failed request: an `Error` response carrying the frame's own request
/// id (salvaged from the fixed header, so a synchronous client waiting
/// on that id sees the error instead of hanging), counted and recorded
/// via [`respond`].  The connection keeps serving — framing is
/// length-delimited, so one bad payload does not desync the stream.
fn read_loop(shared: &Shared,
             mut recv: impl FnMut() -> Result<Option<Vec<u8>>>,
             ingress: &Sender<Inbound>, reply: &Reply) {
    loop {
        let frame = match recv() {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => break, // peer closed / connection error
        };
        match codec::decode_request(&frame) {
            Ok((id, priority, req)) => {
                let inb = Inbound { id, priority, req, reply: reply.clone() };
                if ingress.send(inb).is_err() {
                    break; // server shutting down
                }
            }
            Err(e) => {
                note_request(shared);
                respond(shared, reply, codec::frame_request_id(&frame),
                        Response::Error {
                            device: String::new(),
                            kind: ErrorKind::Request,
                            message: format!("bad request frame: {e:#}"),
                        });
            }
        }
    }
}

/// Wire up one connection, whatever carries its frames: a writer pump
/// encoding responses into `send_frame` and a reader pump feeding
/// decoded requests to the dispatcher.
fn spawn_connection(
    shared: &Arc<Shared>,
    ingress: Sender<Inbound>,
    mut send_frame: impl FnMut(Vec<u8>) -> bool + Send + 'static,
    recv_frame: impl FnMut() -> Result<Option<Vec<u8>>> + Send + 'static,
) {
    let (otx, orx) = channel::<(u64, Response)>();
    let writer = std::thread::spawn(move || {
        for (id, resp) in orx {
            if !send_frame(codec::encode_response(id, &resp)) {
                break;
            }
        }
    });
    let reply = Reply(otx);
    let reader = {
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            read_loop(&shared, recv_frame, &ingress, &reply);
        })
    };
    track_conn(shared, reader, writer);
}

// ---------------------------------------------------------------------------
// Scheduler internals
// ---------------------------------------------------------------------------

/// The pending work of one accepted request.  A multi-epoch `Train` is a
/// single item that yields one epoch per turn at the device — the unit
/// the priority lanes preempt at.
enum Work {
    /// Build (or resume) the device's session — always the device's
    /// first unit, executed on the worker pool (never the dispatcher).
    Register {
        seed: u32,
        method: MethodSpec,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
        angle: Option<u32>,
    },
    Train { remaining: usize, done: usize, steps: u64 },
    Predict { image: Vec<u8> },
    Evaluate,
    Drift { train: Arc<Dataset>, test: Arc<Dataset>, angle: Option<u32> },
}

/// One queued request: its id, reply route, and pending work.
struct Item {
    id: u64,
    reply: Reply,
    work: Work,
}

/// A device's in-memory presence: its live session (taken by the worker
/// executing its current op) and its current datasets.  `None` on the
/// [`DeviceState`] = the device is evicted (state lives in the store).
struct Resident {
    /// `None` while a worker has the session checked out.
    session: Option<Session>,
    train: Arc<Dataset>,
    test: Arc<Dataset>,
}

struct DeviceState {
    /// Live state, or `None` for an evicted / not-yet-rehydrated device.
    resident: Option<Resident>,
    /// Registration identity — a later `Register` must match to resume.
    seed: u32,
    method: MethodSpec,
    /// False until the register unit completes (the entry is provisional
    /// and its lanes start with the register item, which runs first).
    registered: bool,
    /// True while an evictor is flushing this device to the store; a
    /// worker that pops the device meanwhile steps aside and retries.
    evicting: bool,
    /// Pending items by [`Priority`] lane; FIFO within a lane.  A device
    /// appears in the ready queue iff `queued` — never twice, so its ops
    /// can never run concurrently.
    lanes: [VecDeque<Item>; Priority::COUNT],
    queued: bool,
    /// Accepted, unanswered requests (the inflight-window count).
    pending: usize,
    /// Completed training epochs over the device's lifetime.
    epochs_done: u64,
    /// Data provenance of the current datasets, when the client said.
    angle: Option<u32>,
    /// In-memory state is newer than the store (a failed write-through
    /// leaves this set; eviction and `join()` retry the flush).
    dirty: bool,
    /// LRU clock value of the device's last checkout.
    last_used: u64,
}

impl DeviceState {
    fn new(seed: u32, method: MethodSpec) -> Self {
        Self {
            resident: None,
            seed,
            method,
            registered: false,
            evicting: false,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            queued: false,
            pending: 0,
            epochs_done: 0,
            angle: None,
            dirty: false,
            last_used: 0,
        }
    }

    /// A registered-but-evicted entry recovered from the store at
    /// startup: requests rehydrate it lazily; a `Register` resumes it.
    fn from_snapshot(snap: &DeviceSnapshot) -> Self {
        let mut st = Self::new(snap.session.seed, snap.session.method.clone());
        st.registered = true;
        st.epochs_done = snap.epochs_done;
        st.angle = snap.angle;
        st
    }

    fn has_work(&self) -> bool {
        self.lanes.iter().any(|l| !l.is_empty())
    }
}

/// The device registry plus its LRU bookkeeping, under one lock.
struct Registry {
    map: HashMap<String, DeviceState>,
    /// Devices with `resident.is_some()` (the LRU size).
    resident: usize,
    /// Monotonic LRU clock.
    tick: u64,
}

/// Serving clock: requests/sec covers first request → last response, not
/// idle time before traffic arrives.
#[derive(Default)]
struct Clock {
    first_request: Option<Instant>,
    last_response: Option<Instant>,
}

/// Register-time static-soundness policy (see [`crate::audit`]): what to
/// do when a fresh `Register`'s (backbone, scales, method) combination
/// cannot be statically proven overflow-free.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AuditPolicy {
    /// No register-time audit (the default).
    #[default]
    Off,
    /// Audit and log unsound registrations to stderr, but accept them.
    Warn,
    /// Refuse unsound registrations with a request error.
    Reject,
}

struct Shared {
    backbone: Arc<Backbone>,
    limit: usize,
    eval_batch: usize,
    window: usize,
    /// Register-time static-soundness policy (fresh registers only;
    /// resumes were audited at original registration).
    audit: AuditPolicy,
    /// Durable snapshot store; `None` = memory-only serving (no
    /// eviction, no resume).
    store: Option<Arc<dyn StateStore>>,
    /// Maximum resident sessions (`usize::MAX` = unbounded).
    resident_cap: usize,
    /// Devices + LRU state.  Lock order: `registry` before
    /// `ready`/`outstanding`/`record`/`clock`; none of those four is
    /// ever held while taking another of them or `registry`.
    registry: Mutex<Registry>,
    /// Devices with pending work, round-robin.
    ready: Mutex<VecDeque<String>>,
    ready_cv: Condvar,
    done: AtomicBool,
    /// Accepted op-requests not yet answered (drives graceful shutdown).
    outstanding: Mutex<usize>,
    idle_cv: Condvar,
    requests: AtomicU64,
    /// Sessions rebuilt from the store (lazy rehydrations + resumed
    /// registers).
    rehydrations: AtomicU64,
    /// Idle devices flushed out of memory under `resident_cap` pressure.
    evictions: AtomicU64,
    /// Every response the run produced, completion order (the
    /// [`ServeReport`] source — per-connection streams are routed
    /// separately via [`Reply`]).
    record: Mutex<Vec<Response>>,
    /// Recording off = a long-lived server (`priot serve --listen`) that
    /// never `join()`s does not grow `record` without bound.
    record_enabled: bool,
    clock: Mutex<Clock>,
    accepting: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

/// Track a connection's pump threads, reaping the handles of pumps that
/// already finished (long-lived servers see many connections come and
/// go; their handles must not accumulate until `join()`).
fn track_conn(shared: &Shared, reader: JoinHandle<()>, writer: JoinHandle<()>) {
    let mut conns = shared.conns.lock().expect("serve connections");
    conns.retain(|h| !h.is_finished());
    conns.push(reader);
    conns.push(writer);
}

impl Shared {
    /// Tell the worker pool to exit.  The store must synchronize through
    /// the `ready` mutex: a worker that saw `done == false` keeps the
    /// mutex until it is parked inside `ready_cv.wait`, so passing
    /// through the lock before notifying guarantees the wakeup is not
    /// lost between its check and its wait.
    fn signal_done(&self) {
        self.done.store(true, Ordering::SeqCst);
        drop(self.ready.lock().expect("serve ready queue"));
        self.ready_cv.notify_all();
    }
}

/// Record a response (when recording is on) and route it to its
/// connection.
fn respond(shared: &Shared, reply: &Reply, id: u64, resp: Response) {
    shared.clock.lock().expect("serve clock").last_response =
        Some(Instant::now());
    if shared.record_enabled {
        shared.record.lock().expect("serve record").push(resp.clone());
    }
    let _ = reply.0.send((id, resp));
}

/// Count one received request and start the serving clock on the first.
fn note_request(shared: &Shared) {
    shared.requests.fetch_add(1, Ordering::Relaxed);
    let mut clock = shared.clock.lock().expect("serve clock");
    if clock.first_request.is_none() {
        clock.first_request = Some(Instant::now());
    }
}

/// Close out one answered op-request (graceful shutdown accounting).
fn note_done(shared: &Shared, n: usize) {
    let mut out = shared.outstanding.lock().expect("serve outstanding");
    *out -= n;
    if *out == 0 {
        shared.idle_cv.notify_all();
    }
}

fn dispatch(shared: &Shared, rx: Receiver<Inbound>) {
    for inb in rx {
        note_request(shared);
        let device = inb.req.device().to_string();
        let (id, reply) = (inb.id, inb.reply.clone());
        // After an abort (`Drop` without `join`: worker pool stopped,
        // dispatcher detached) the server must still *answer* — with an
        // error — or a synchronous client that submits after the drop
        // would wait forever on a request nothing will ever run.
        if shared.done.load(Ordering::SeqCst) {
            respond(shared, &reply, id, Response::Error {
                device,
                kind: ErrorKind::Shutdown,
                message: "fleet server is shut down".into(),
            });
            continue;
        }
        if let Err(e) = handle_request(shared, inb) {
            respond(shared, &reply, id, Response::Error {
                device,
                kind: ErrorKind::Request,
                message: format!("{e:#}"),
            });
        }
    }
}

fn handle_request(shared: &Shared, inb: Inbound) -> Result<()> {
    let Inbound { id, priority, req, reply } = inb;
    match req {
        // Register is *routed* here but *executed* on the worker pool:
        // dataset validation, session construction, and store lookups
        // are heavy, and heavy work never runs on the dispatcher (a
        // slow register must not stall dispatch for every connection).
        // The dispatcher only does map surgery: create a provisional
        // entry and queue the register unit at the head lane, so it is
        // guaranteed to run before any op pipelined behind it.
        Request::Register { device, seed, method, train, test, angle } => {
            // Canonicalize the method description up front: snapshots
            // store canonical specs (read back from the live plugin), so
            // resume identity checks must compare canonical forms — a
            // register with an unset θ must match a stored device whose
            // snapshot spells out the method's default θ.
            let method = method.canonical();
            let mut reg = shared.registry.lock().expect("serve registry");
            if let Some(st) = reg.map.get_mut(&device) {
                if st.seed != seed || st.method != method {
                    bail!("device {device} is already registered with a \
                           different method or seed");
                }
                if st.registered {
                    // Known device (live or evicted): a resume handshake.
                    // Its state is kept, the supplied datasets are
                    // ignored, and rehydration stays lazy until real
                    // work arrives.
                    drop(reg);
                    respond(shared, &reply, id,
                            Response::Registered { device, resumed: true });
                    return Ok(());
                }
                // Same identity while the original register is still
                // building on the pool (reconnects can race a slow
                // register): queue the handshake behind it in the head
                // lane — acked as a resume once the build lands, or
                // answered with the register failure if it does not.
                if st.pending >= shared.window {
                    bail!(
                        "device {device}: inflight window full ({} of {} \
                         requests pending)",
                        st.pending, shared.window
                    );
                }
                st.pending += 1;
                st.lanes[0].push_back(Item {
                    id,
                    reply,
                    work: Work::Register { seed, method, train, test, angle },
                });
                *shared.outstanding.lock().expect("serve outstanding") += 1;
                if !st.queued {
                    st.queued = true;
                    shared
                        .ready
                        .lock()
                        .expect("serve ready queue")
                        .push_back(device);
                    shared.ready_cv.notify_one();
                }
                return Ok(());
            }
            let mut st = DeviceState::new(seed, method.clone());
            st.pending = 1;
            st.queued = true;
            st.lanes[0].push_back(Item {
                id,
                reply,
                work: Work::Register { seed, method, train, test, angle },
            });
            reg.map.insert(device.clone(), st);
            *shared.outstanding.lock().expect("serve outstanding") += 1;
            shared
                .ready
                .lock()
                .expect("serve ready queue")
                .push_back(device);
            shared.ready_cv.notify_one();
            Ok(())
        }
        Request::Train { device, epochs } => enqueue(shared, &device, priority,
            Item {
                id,
                reply,
                work: Work::Train { remaining: epochs, done: 0, steps: 0 },
            }),
        Request::Predict { device, image } => enqueue(shared, &device, priority,
            Item { id, reply, work: Work::Predict { image } }),
        Request::Evaluate { device } => enqueue(shared, &device, priority,
            Item { id, reply, work: Work::Evaluate }),
        Request::Drift { device, train, test, angle } => {
            // Validation runs with the op on the worker pool, like
            // Register's.
            enqueue(shared, &device, priority,
                    Item { id, reply, work: Work::Drift { train, test, angle } })
        }
    }
}

fn enqueue(shared: &Shared, device: &str, priority: Priority, item: Item)
           -> Result<()> {
    let mut reg = shared.registry.lock().expect("serve registry");
    let st = reg
        .map
        .get_mut(device)
        .ok_or_else(|| anyhow!("unknown device {device} (register first)"))?;
    if st.pending >= shared.window {
        bail!(
            "device {device}: inflight window full ({} of {} requests \
             pending — drain responses before submitting more)",
            st.pending,
            shared.window
        );
    }
    st.pending += 1;
    st.lanes[priority.lane()].push_back(item);
    *shared.outstanding.lock().expect("serve outstanding") += 1;
    if !st.queued {
        st.queued = true;
        shared
            .ready
            .lock()
            .expect("serve ready queue")
            .push_back(device.to_string());
        shared.ready_cv.notify_one();
    }
    Ok(())
}

/// What one executed unit produced.
enum UnitOut {
    /// A training epoch ran; the request has more epochs to go.
    Continue,
    TrainDone { epochs: usize, steps: u64, train_accuracy: f64 },
    Prediction(usize),
    Evaluation { accuracy: f64, n: usize },
    Drifted { train: Arc<Dataset>, test: Arc<Dataset> },
}

fn run_unit(session: &mut Session, work: &mut Work, train: &Dataset,
            test: &Dataset, eval_batch: usize, limit: usize)
            -> Result<UnitOut> {
    match work {
        Work::Register { .. } => {
            unreachable!("register units run via run_register")
        }
        Work::Train { remaining, done, steps } => {
            if *remaining == 0 {
                // A zero-epoch request reached its queue slot: close it
                // out in order, with nothing executed.
                return Ok(UnitOut::TrainDone {
                    epochs: 0,
                    steps: 0,
                    train_accuracy: 0.0,
                });
            }
            let ep = session.train_epoch(train)?;
            *remaining -= 1;
            *done += 1;
            *steps += ep.steps as u64;
            if *remaining == 0 {
                Ok(UnitOut::TrainDone {
                    epochs: *done,
                    steps: *steps,
                    train_accuracy: ep.train_accuracy,
                })
            } else {
                Ok(UnitOut::Continue)
            }
        }
        Work::Predict { image } => {
            let want = session.spec.input_len();
            if image.len() != want {
                bail!("predict: image has {} pixels, model {} wants {want}",
                      image.len(), session.spec.name);
            }
            let mut img = vec![0i32; want];
            u8_to_i32_pixels(image, &mut img);
            Ok(UnitOut::Prediction(session.predict(&img)))
        }
        Work::Evaluate => {
            let accuracy = session.evaluate_batch(test, eval_batch)?;
            Ok(UnitOut::Evaluation { accuracy, n: capped(test.n, limit) })
        }
        Work::Drift { train: tr, test: te, .. } => {
            crate::data::validate(tr, &session.spec)
                .context("drift train set")?;
            crate::data::validate(te, &session.spec)
                .context("drift test set")?;
            Ok(UnitOut::Drifted {
                train: Arc::clone(tr),
                test: Arc::clone(te),
            })
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Assemble the durable snapshot of one device around its live session.
fn device_snapshot(session: &Session, device: &str, train: &Arc<Dataset>,
                   test: &Arc<Dataset>, epochs_done: u64,
                   angle: Option<u32>) -> Result<DeviceSnapshot> {
    Ok(DeviceSnapshot {
        device: device.to_string(),
        session: session.snapshot()?,
        train: Arc::clone(train),
        test: Arc::clone(test),
        epochs_done,
        angle,
    })
}

/// What a worker found when it claimed a ready device.
enum Claim {
    /// Session + highest-priority item checked out — execute it.
    /// (Boxed: a `Session` inlines the engine workspace, which would
    /// dwarf the other variants.)
    Run {
        session: Box<Session>,
        item: Item,
        lane: usize,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
    },
    /// The device's first unit: build/resume its session.
    Register(Item),
    /// Registered but evicted: rehydrate from the store first.
    Rehydrate,
    /// An evictor is mid-flush on this device: step aside and retry.
    Defer,
}

fn worker(shared: &Shared) {
    loop {
        // Wait for a ready device (or shutdown).
        let device = {
            let mut q = shared.ready.lock().expect("serve ready queue");
            loop {
                if let Some(d) = q.pop_front() {
                    break d;
                }
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready_cv.wait(q).expect("serve ready queue");
            }
        };
        // Claim the device's next unit.  The device is in the ready
        // queue at most once, so nobody else touches its session while
        // we hold this turn.
        let claim = {
            let mut reg = shared.registry.lock().expect("serve registry");
            reg.tick += 1;
            let tick = reg.tick;
            let st = reg.map.get_mut(&device).expect("ready device registered");
            if st.evicting {
                Claim::Defer
            } else {
                let lane = (0..Priority::COUNT)
                    .find(|&l| !st.lanes[l].is_empty())
                    .expect("ready device has work");
                let head_is_register = matches!(
                    st.lanes[lane].front().expect("non-empty lane").work,
                    Work::Register { .. }
                );
                if head_is_register {
                    Claim::Register(
                        st.lanes[lane].pop_front().expect("non-empty lane"),
                    )
                } else if st.resident.is_none() {
                    Claim::Rehydrate
                } else {
                    st.last_used = tick;
                    let item =
                        st.lanes[lane].pop_front().expect("non-empty lane");
                    let res = st.resident.as_mut().expect("resident device");
                    Claim::Run {
                        session: Box::new(
                            res.session
                                .take()
                                .expect("ready device owns its session"),
                        ),
                        item,
                        lane,
                        train: Arc::clone(&res.train),
                        test: Arc::clone(&res.test),
                    }
                }
            }
        };
        match claim {
            Claim::Defer => {
                // Re-queue and retry once the evictor clears the flag.
                // The short sleep keeps the retry loop from burning a
                // core while the flush (a bounded disk write) finishes.
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device);
                std::thread::sleep(Duration::from_micros(500));
            }
            Claim::Rehydrate => {
                match rehydrate_device(shared, &device) {
                    Ok(()) => {
                        // Now resident; re-queue so the pending item runs
                        // (possibly on another worker).
                        shared
                            .ready
                            .lock()
                            .expect("serve ready queue")
                            .push_back(device.clone());
                        shared.ready_cv.notify_one();
                        enforce_resident_cap(shared);
                    }
                    Err(e) => fail_head_item(shared, &device, e),
                }
            }
            Claim::Register(item) => {
                run_register(shared, &device, item);
                enforce_resident_cap(shared);
            }
            Claim::Run { session, item, lane, train, test } => {
                run_op(shared, &device, *session, item, lane, &train, &test);
                enforce_resident_cap(shared);
            }
        }
    }
}

/// Execute one claimed non-register unit, persist on completion of a
/// state-mutating request, check the session back in, and respond.
fn run_op(shared: &Shared, device: &str, mut session: Session, item: Item,
          lane: usize, train: &Arc<Dataset>, test: &Arc<Dataset>) {
    let Item { id, reply, mut work } = item;
    // A panicking op (method plugins are an open extension point) must
    // not kill the worker: the `outstanding` count would never drain
    // and `join()` would hang.  Convert the panic into an error
    // response; engine/score buffers are plain integers, so the
    // checked-back-in session is memory-safe.  Its method state may be
    // mid-step, and memory is authoritative: the device stays dirty and
    // the partial state persists at the next flush (a durable reset /
    // deregister op is a ROADMAP item — today the operator clears the
    // device's store directory to start it over).
    let unit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || run_unit(&mut session, &mut work, train, test,
                    shared.eval_batch, shared.limit),
    ))
    .unwrap_or_else(|payload| {
        Err(anyhow!("op panicked: {}", panic_message(payload.as_ref())))
    });
    // Did this unit (or its failed attempt) touch durable state?
    let mutated = match (&work, &unit) {
        (Work::Predict { .. } | Work::Evaluate, _) => false,
        (_, Ok(UnitOut::TrainDone { epochs: 0, .. })) => false,
        _ => true,
    };
    let drift_angle = match &work {
        Work::Drift { angle, .. } => *angle,
        _ => None,
    };
    // Persist-before-respond: a completed state-mutating request writes
    // the device's snapshot first, so any state a client has been told
    // about survives a crash (the restart-resume contract).  A failed
    // write keeps the device dirty; eviction and join() retry it.
    let mut persisted = false;
    if let Some(store) = &shared.store {
        let flush = match &unit {
            Ok(UnitOut::TrainDone { epochs, .. }) if *epochs > 0 => {
                Some((train, test, *epochs as u64, false))
            }
            Ok(UnitOut::Drifted { train: tr, test: te }) => {
                Some((tr, te, 0, true))
            }
            _ => None,
        };
        if let Some((tr, te, new_epochs, is_drift)) = flush {
            let (base_epochs, cur_angle) = {
                let reg = shared.registry.lock().expect("serve registry");
                let st = reg.map.get(device).expect("device still registered");
                (st.epochs_done, st.angle)
            };
            let angle = if is_drift { drift_angle } else { cur_angle };
            let put = device_snapshot(&session, device, tr, te,
                                      base_epochs + new_epochs, angle)
                .and_then(|snap| store.put(&snap));
            match put {
                Ok(()) => persisted = true,
                Err(e) => eprintln!(
                    "[serve] persisting {device}: {e:#} — state kept in \
                     memory (flushed again at eviction or join)"
                ),
            }
        }
    }
    // Check the session back in and emit the response (if the request
    // completed) *before* re-queuing the device, so a device's
    // responses leave in execution order.
    let mut responded = false;
    {
        let mut reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get_mut(device).expect("device still registered");
        st.resident
            .as_mut()
            .expect("resident while op in flight")
            .session = Some(session);
        let response = match unit {
            Ok(UnitOut::Continue) => {
                // Back to the front of its lane: the request resumes
                // at the device's next turn, after any
                // higher-priority work cuts in.
                st.lanes[lane].push_front(Item {
                    id,
                    reply: reply.clone(),
                    work,
                });
                None
            }
            Ok(UnitOut::TrainDone { epochs, steps, train_accuracy }) => {
                st.epochs_done += epochs as u64;
                Some(Response::TrainDone {
                    device: device.to_string(),
                    epochs,
                    steps,
                    train_accuracy,
                })
            }
            Ok(UnitOut::Prediction(class)) => Some(Response::Prediction {
                device: device.to_string(),
                class,
            }),
            Ok(UnitOut::Evaluation { accuracy, n }) => {
                Some(Response::Evaluation {
                    device: device.to_string(),
                    accuracy,
                    n,
                })
            }
            Ok(UnitOut::Drifted { train, test }) => {
                let res =
                    st.resident.as_mut().expect("resident while op in flight");
                res.train = train;
                res.test = test;
                st.angle = drift_angle;
                Some(Response::Drifted { device: device.to_string() })
            }
            // A failed Train drops its remaining epochs with it: one
            // Error closes out the whole request — it neither trains
            // on for nothing nor emits a TrainDone after its Error.
            Err(e) => Some(Response::Error {
                device: device.to_string(),
                kind: ErrorKind::Request,
                message: format!("{e:#}"),
            }),
        };
        st.dirty = (st.dirty || mutated) && !persisted;
        if let Some(resp) = response {
            st.pending -= 1;
            respond(shared, &reply, id, resp);
            responded = true;
        }
        if st.has_work() {
            shared
                .ready
                .lock()
                .expect("serve ready queue")
                .push_back(device.to_string());
            shared.ready_cv.notify_one();
        } else {
            st.queued = false;
        }
    }
    if responded {
        note_done(shared, 1);
    }
}

/// Classified register failure: what the client is told and how.
struct RegisterFail {
    kind: ErrorKind,
    err: anyhow::Error,
}

fn store_fail(err: anyhow::Error) -> RegisterFail {
    RegisterFail { kind: ErrorKind::Store, err }
}

fn request_fail(err: anyhow::Error) -> RegisterFail {
    RegisterFail { kind: ErrorKind::Request, err }
}

/// Execute a register unit on the worker pool: resume the device from
/// the store when it is known there, otherwise validate + build a fresh
/// session and persist its initial snapshot *before* acknowledging.
fn run_register(shared: &Shared, device: &str, item: Item) {
    let Item { id, reply, work } = item;
    let Work::Register { seed, method, train, test, angle } = work else {
        unreachable!("run_register on a non-register item");
    };
    // A queued resume handshake: a register that raced the device's
    // original registration.  The original register unit always precedes
    // it in the head lane, so by the time this runs the device is
    // registered (identity was already matched at dispatch) — ack the
    // resume without building anything.  (Had the original failed, this
    // item would have been drained with the entry.)
    {
        let mut reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get_mut(device).expect("registering device present");
        if st.registered {
            st.pending -= 1;
            respond(shared, &reply, id, Response::Registered {
                device: device.to_string(),
                resumed: true,
            });
            if st.has_work() {
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device.to_string());
                shared.ready_cv.notify_one();
            } else {
                st.queued = false;
            }
            drop(reg);
            note_done(shared, 1);
            return;
        }
    }
    type Built = (Session, Arc<Dataset>, Arc<Dataset>, u64, Option<u32>, bool);
    let heavy: std::result::Result<Built, RegisterFail> = (|| {
        if let Some(store) = &shared.store {
            let stored = store
                .get(device)
                .with_context(|| format!("device {device}: reading stored \
                                          state"))
                .map_err(store_fail)?;
            if let Some(snap) = stored {
                if snap.session.seed != seed || snap.session.method != method {
                    return Err(request_fail(anyhow!(
                        "device {device} exists in the state store with a \
                         different method or seed"
                    )));
                }
                let session = Session::rehydrate(&shared.backbone,
                                                 &snap.session)
                    .with_context(|| format!("device {device}: rehydrating \
                                              stored state"))
                    .map_err(store_fail)?;
                return Ok((session, snap.train, snap.test, snap.epochs_done,
                           snap.angle, true));
            }
        }
        crate::data::validate(&train, &shared.backbone.spec)
            .with_context(|| format!("registering {device}: train set"))
            .map_err(request_fail)?;
        crate::data::validate(&test, &shared.backbone.spec)
            .with_context(|| format!("registering {device}: test set"))
            .map_err(request_fail)?;
        let session = Session::builder()
            .backbone(Arc::clone(&shared.backbone))
            .method_boxed(method.plugin())
            .seed(seed)
            .limit(shared.limit)
            .eval_batch(shared.eval_batch)
            .track_pruning(false)
            .build()
            .with_context(|| format!("registering {device}"))
            .map_err(request_fail)?;
        // Static soundness gate (`crate::audit`): refuse or flag method
        // specs whose accumulators cannot be proven overflow-free under
        // this backbone + scale table — before any state is persisted.
        // Resumed registers skip this: they were audited when originally
        // registered and carry bit-identical state.
        if shared.audit != AuditPolicy::Off {
            let report = crate::audit::audit_backbone(&shared.backbone,
                                                      &method,
                                                      session.masks())
                .with_context(|| format!("registering {device}: audit"))
                .map_err(request_fail)?;
            if !report.sound() {
                if shared.audit == AuditPolicy::Reject {
                    return Err(request_fail(anyhow!(
                        "registering {device}: statically unsound: {}",
                        report.summary()
                    )));
                }
                eprintln!("[serve] audit warning for {device}: {}",
                          report.summary());
            }
        }
        // Durable registration: the initial snapshot lands before the
        // ack, so a crash right after it can still resume the device.
        if let Some(store) = &shared.store {
            device_snapshot(&session, device, &train, &test, 0, angle)
                .and_then(|snap| store.put(&snap))
                .with_context(|| format!("device {device}: persisting \
                                          initial state"))
                .map_err(store_fail)?;
        }
        Ok((session, train, test, 0, angle, false))
    })();
    match heavy {
        Ok((session, train, test, epochs_done, angle, resumed)) => {
            if resumed {
                shared.rehydrations.fetch_add(1, Ordering::Relaxed);
            }
            let mut reg = shared.registry.lock().expect("serve registry");
            reg.resident += 1;
            reg.tick += 1;
            let tick = reg.tick;
            let st =
                reg.map.get_mut(device).expect("registering device present");
            st.resident = Some(Resident {
                session: Some(session),
                train,
                test,
            });
            st.registered = true;
            st.epochs_done = epochs_done;
            st.angle = angle;
            st.dirty = false;
            st.last_used = tick;
            st.pending -= 1;
            respond(shared, &reply, id, Response::Registered {
                device: device.to_string(),
                resumed,
            });
            if st.has_work() {
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device.to_string());
                shared.ready_cv.notify_one();
            } else {
                st.queued = false;
            }
            drop(reg);
            note_done(shared, 1);
        }
        Err(RegisterFail { kind, err }) => {
            // The provisional entry disappears, and every request already
            // pipelined behind the failed register is answered too.
            let stray = {
                let mut reg = shared.registry.lock().expect("serve registry");
                let mut st = reg
                    .map
                    .remove(device)
                    .expect("registering device present");
                let stray: Vec<Item> = st
                    .lanes
                    .iter_mut()
                    .flat_map(|l| l.drain(..))
                    .collect();
                respond(shared, &reply, id, Response::Error {
                    device: device.to_string(),
                    kind,
                    message: format!("{err:#}"),
                });
                for s in &stray {
                    respond(shared, &s.reply, s.id, Response::Error {
                        device: device.to_string(),
                        kind: ErrorKind::Request,
                        message: format!(
                            "device {device}: register failed, request \
                             dropped"
                        ),
                    });
                }
                stray
            };
            note_done(shared, 1 + stray.len());
        }
    }
}

/// Rebuild an evicted device's session from the store (on the worker
/// pool — the caller holds the device's scheduling turn).
fn rehydrate_device(shared: &Shared, device: &str) -> Result<()> {
    let store = shared.store.as_ref().ok_or_else(|| {
        anyhow!("device {device} is not resident and no state store is \
                 configured")
    })?;
    let (seed, method) = {
        let reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get(device).expect("ready device registered");
        (st.seed, st.method.clone())
    };
    let snap = store
        .get(device)?
        .ok_or_else(|| anyhow!("device {device}: stored state is missing"))?;
    if snap.session.seed != seed || snap.session.method != method {
        bail!("device {device}: stored state does not match the registered \
               identity");
    }
    let session = Session::rehydrate(&shared.backbone, &snap.session)
        .with_context(|| format!("device {device}: rehydrating"))?;
    let mut reg = shared.registry.lock().expect("serve registry");
    reg.resident += 1;
    reg.tick += 1;
    let tick = reg.tick;
    let st = reg.map.get_mut(device).expect("device still registered");
    st.resident = Some(Resident {
        session: Some(session),
        train: snap.train,
        test: snap.test,
    });
    st.epochs_done = snap.epochs_done;
    st.angle = snap.angle;
    st.dirty = false;
    st.last_used = tick;
    shared.rehydrations.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Answer (and drop) the head pending item of a device whose session
/// could not be rehydrated — each queued item retries rehydration on its
/// own turn, so a transient store failure fails requests one at a time
/// instead of wedging the device.
fn fail_head_item(shared: &Shared, device: &str, e: anyhow::Error) {
    {
        let mut reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get_mut(device).expect("ready device registered");
        let lane = (0..Priority::COUNT)
            .find(|&l| !st.lanes[l].is_empty())
            .expect("ready device has work");
        let item = st.lanes[lane].pop_front().expect("non-empty lane");
        st.pending -= 1;
        respond(shared, &item.reply, item.id, Response::Error {
            device: device.to_string(),
            kind: ErrorKind::Store,
            message: format!("{e:#}"),
        });
        if st.has_work() {
            shared
                .ready
                .lock()
                .expect("serve ready queue")
                .push_back(device.to_string());
            shared.ready_cv.notify_one();
        } else {
            st.queued = false;
        }
    }
    note_done(shared, 1);
}

/// Evict least-recently-used idle devices until the resident count is
/// back under the cap.  Runs on worker threads at op-queue idle points;
/// devices with pending work are never touched, so eviction cannot
/// interleave with a device's own ops.  The flush happens outside the
/// registry lock; a worker that claims the device meanwhile sees the
/// `evicting` flag and defers.
fn enforce_resident_cap(shared: &Shared) {
    let Some(store) = &shared.store else {
        return; // nowhere to evict into
    };
    loop {
        let victim = {
            let mut reg = shared.registry.lock().expect("serve registry");
            if reg.resident <= shared.resident_cap {
                return;
            }
            let pick = reg
                .map
                .iter()
                .filter(|(_, st)| {
                    st.pending == 0
                        && !st.evicting
                        && st.resident
                            .as_ref()
                            .is_some_and(|r| r.session.is_some())
                })
                .min_by_key(|(_, st)| st.last_used)
                .map(|(d, _)| d.clone());
            let Some(device) = pick else {
                return; // everyone is busy; re-checked at the next idle point
            };
            let st = reg.map.get_mut(&device).expect("picked device");
            st.evicting = true;
            let res = st.resident.take().expect("picked resident");
            let meta = (st.epochs_done, st.angle, st.dirty);
            reg.resident -= 1;
            (device, res, meta)
        };
        let (device, res, (epochs_done, angle, dirty)) = victim;
        // Flush outside the lock — and only when the store is stale
        // (write-through at op completion usually already covered it).
        let result = if dirty {
            let session = res.session.as_ref().expect("evicted session");
            device_snapshot(session, &device, &res.train, &res.test,
                            epochs_done, angle)
                .and_then(|snap| store.put(&snap))
        } else {
            Ok(())
        };
        let mut reg = shared.registry.lock().expect("serve registry");
        match result {
            Ok(()) => {
                let st = reg.map.get_mut(&device).expect("evicting device");
                st.evicting = false;
                st.dirty = false;
                shared.evictions.fetch_add(1, Ordering::Relaxed);
                // resident stays None: the device is now store-only.
            }
            Err(e) => {
                // Never lose state: keep the device resident and stop
                // evicting for now.
                let st = reg.map.get_mut(&device).expect("evicting device");
                st.evicting = false;
                st.resident = Some(res);
                reg.resident += 1;
                eprintln!(
                    "[serve] evicting {device}: {e:#} — keeping it resident"
                );
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The server handle
// ---------------------------------------------------------------------------

/// Builder for [`FleetServer`].
pub struct ServeBuilder {
    backbone: Arc<Backbone>,
    threads: usize,
    limit: usize,
    eval_batch: usize,
    window: usize,
    record: bool,
    store: Option<Arc<dyn StateStore>>,
    resident_cap: usize,
    audit: AuditPolicy,
}

impl ServeBuilder {
    /// Worker thread count (0 = available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Per-epoch / per-evaluation sample cap handed to every session
    /// (0 = all).
    pub fn limit(mut self, limit: usize) -> Self {
        self.limit = limit;
        self
    }

    /// Samples per forward in evaluation (bit-identical to per-sample;
    /// default 8).
    pub fn eval_batch(mut self, batch: usize) -> Self {
        self.eval_batch = batch;
        self
    }

    /// Per-device inflight window: the maximum accepted-but-unanswered
    /// requests one device may have queued.  Submissions beyond it are
    /// answered with an immediate `Error` instead of growing the backlog
    /// (0 = unbounded; default 64).
    pub fn window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Keep every response for the final [`ServeReport`] (default on).
    /// Turn it off for a long-lived listener that never `join()`s —
    /// responses still reach their clients, but the server no longer
    /// accumulates a copy of each one for the whole process lifetime.
    pub fn record(mut self, on: bool) -> Self {
        self.record = on;
        self
    }

    /// Attach a durable [`StateStore`]: device snapshots are written
    /// through on every completed state-mutating request, known devices
    /// found in the store at startup are resumable, and a `Register`
    /// for a stored device resumes it.
    pub fn store(mut self, store: Arc<dyn StateStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Convenience: attach a [`DiskStore`] rooted at `dir` (created if
    /// missing) — what `priot serve --state-dir DIR` uses.
    pub fn state_dir(self, dir: impl Into<std::path::PathBuf>)
                     -> Result<Self> {
        Ok(self.store(Arc::new(DiskStore::open(dir)?)))
    }

    /// Bound **live** sessions: at most `cap` devices keep their session
    /// (scores, masks, activation buffers) in memory; the least-recently-
    /// used idle devices beyond it are evicted to the store and lazily
    /// rehydrated on their next request — bit-identically.  0 (the
    /// default) = unbounded.  Setting a cap without a store attaches a
    /// [`MemStore`] automatically (eviction needs somewhere to put
    /// state).
    pub fn resident_cap(mut self, cap: usize) -> Self {
        self.resident_cap = cap;
        self
    }

    /// Register-time static-soundness policy (default
    /// [`AuditPolicy::Off`]): with [`AuditPolicy::Reject`] a fresh
    /// `Register` whose method spec cannot be statically proven
    /// overflow-free under this backbone's weights and scale table is
    /// answered with a request error instead of creating a device —
    /// what `priot serve --audit reject` sets.
    pub fn audit(mut self, policy: AuditPolicy) -> Self {
        self.audit = policy;
        self
    }

    /// Spawn the dispatcher + worker pool and return the live handle.
    pub fn build(self) -> FleetServer {
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        let store = self.store.or_else(|| {
            (self.resident_cap > 0).then(|| {
                Arc::new(MemStore::new()) as Arc<dyn StateStore>
            })
        });
        let resident_cap = if self.resident_cap == 0 {
            usize::MAX
        } else {
            self.resident_cap
        };
        // Restart-resume: every device the store already knows becomes a
        // registered (evicted) entry, so a `Train` straight after a
        // restart rehydrates lazily and a `Register` resumes.
        let mut registry =
            Registry { map: HashMap::new(), resident: 0, tick: 0 };
        if let Some(store) = &store {
            match store.devices() {
                Ok(devices) => {
                    for device in devices {
                        match store.get(&device) {
                            Ok(Some(snap))
                                if snap.session.model == self.backbone.model =>
                            {
                                registry.map.insert(
                                    device,
                                    DeviceState::from_snapshot(&snap),
                                );
                            }
                            Ok(Some(snap)) => eprintln!(
                                "[serve] skipping stored device {device}: \
                                 snapshot is for model {}, serving {}",
                                snap.session.model, self.backbone.model
                            ),
                            Ok(None) => {}
                            Err(e) => eprintln!(
                                "[serve] skipping stored device {device}: \
                                 {e:#}"
                            ),
                        }
                    }
                }
                Err(e) => {
                    eprintln!("[serve] scanning the state store: {e:#}");
                }
            }
        }
        let shared = Arc::new(Shared {
            backbone: self.backbone,
            limit: self.limit,
            eval_batch: self.eval_batch,
            window: if self.window == 0 { usize::MAX } else { self.window },
            audit: self.audit,
            store,
            resident_cap,
            registry: Mutex::new(registry),
            ready: Mutex::new(VecDeque::new()),
            ready_cv: Condvar::new(),
            done: AtomicBool::new(false),
            outstanding: Mutex::new(0),
            idle_cv: Condvar::new(),
            requests: AtomicU64::new(0),
            rehydrations: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            record: Mutex::new(Vec::new()),
            record_enabled: self.record,
            clock: Mutex::new(Clock::default()),
            accepting: AtomicBool::new(true),
            conns: Mutex::new(Vec::new()),
        });
        let (itx, irx) = channel::<Inbound>();
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || dispatch(&shared, irx))
        };
        let workers = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker(&shared))
            })
            .collect();
        FleetServer {
            shared,
            ingress: Some(itx),
            dispatcher: Some(dispatcher),
            workers,
            acceptor: None,
            threads,
        }
    }
}

/// The long-lived fleet service: one shared backbone, a registry of
/// per-device sessions (optionally LRU-bounded over a durable
/// [`StateStore`]), a dispatcher thread feeding priority-laned
/// per-device queues, and a worker pool draining them.  Clients talk to
/// it exclusively through [`FleetClient`] — see the module docs.
pub struct FleetServer {
    shared: Arc<Shared>,
    ingress: Option<Sender<Inbound>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    acceptor: Option<JoinHandle<()>>,
    threads: usize,
}

impl FleetServer {
    pub fn builder(backbone: Arc<Backbone>) -> ServeBuilder {
        ServeBuilder {
            backbone,
            threads: 0,
            limit: 0,
            eval_batch: 8,
            window: 64,
            record: true,
            store: None,
            resident_cap: 0,
            audit: AuditPolicy::Off,
        }
    }

    /// Connect an in-process client over a [`ChannelTransport`] — the
    /// successor of the old raw `mpsc::Sender<Request>` front door, now
    /// running the same codec and dispatch path as TCP connections.
    ///
    /// **Lifetime contract:** the dispatcher only shuts down once every
    /// connection has closed.  [`Self::join`] waits for that — so drop
    /// all clients (ending their connections) before calling `join`, or
    /// it will block until they are gone.
    pub fn local_client(&self) -> FleetClient {
        let (client_end, server_end) = ChannelTransport::pair();
        let (stx, srx) = server_end.into_parts();
        let ingress = self.ingress.as_ref().expect("server joined").clone();
        spawn_connection(
            &self.shared,
            ingress,
            move |frame| stx.send(frame).is_ok(),
            move || Ok(srx.recv().ok()),
        );
        FleetClient::over(client_end)
    }

    /// Accept TCP clients on `addr` (e.g. `"127.0.0.1:0"` for an
    /// ephemeral loopback port).  Returns the bound address; connect
    /// with [`FleetClient::connect`].
    pub fn listen(&mut self, addr: &str) -> Result<SocketAddr> {
        if self.acceptor.is_some() {
            bail!("server is already listening");
        }
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding fleet listener on {addr}"))?;
        let local = listener.local_addr()?;
        // Non-blocking accept so the acceptor can observe shutdown.
        listener
            .set_nonblocking(true)
            .context("configuring the fleet listener")?;
        let shared = Arc::clone(&self.shared);
        let ingress = self.ingress.as_ref().expect("server joined").clone();
        self.acceptor = Some(std::thread::spawn(move || {
            while shared.accepting.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // Accepted sockets must not inherit the
                        // listener's non-blocking mode.
                        let _ = stream.set_nonblocking(false);
                        let wstream = match stream.try_clone() {
                            Ok(s) => s,
                            // Connection unusable before it started.
                            Err(_) => continue,
                        };
                        let mut wt = TcpTransport::from_stream(wstream);
                        let mut rt = TcpTransport::from_stream(stream);
                        spawn_connection(
                            &shared,
                            ingress.clone(),
                            move |frame| wt.send(frame).is_ok(),
                            move || rt.recv(),
                        );
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        }));
        Ok(local)
    }

    /// Graceful shutdown: stop accepting connections, finish every
    /// accepted request, stop the pool, **flush all dirty device state
    /// to the store**, and return everything the run produced.
    ///
    /// Blocks until every connection has closed — drop your
    /// [`FleetClient`]s first (see [`Self::local_client`]).
    pub fn join(mut self) -> Result<ServeReport> {
        self.ingress.take(); // our own ingress handle
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            a.join().map_err(|_| anyhow!("serve acceptor panicked"))?;
        }
        // The dispatcher exits once every connection reader has dropped
        // its ingress handle (i.e. every client disconnected).
        if let Some(d) = self.dispatcher.take() {
            d.join().map_err(|_| anyhow!("serve dispatcher panicked"))?;
        }
        {
            let mut out =
                self.shared.outstanding.lock().expect("serve outstanding");
            while *out > 0 {
                out = self.shared.idle_cv.wait(out).expect("serve outstanding");
            }
        }
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow!("serve worker panicked"))?;
        }
        // Flush whatever the write-through path could not persist (a
        // device is only dirty here if an earlier store write failed),
        // so a restarted server resumes exactly this state.
        if let Some(store) = &self.shared.store {
            let reg = self.shared.registry.lock().expect("serve registry");
            for (device, st) in reg.map.iter() {
                if !st.dirty {
                    continue;
                }
                let Some(res) = &st.resident else { continue };
                let Some(session) = &res.session else { continue };
                let flushed = device_snapshot(session, device, &res.train,
                                              &res.test, st.epochs_done,
                                              st.angle)
                    .and_then(|snap| store.put(&snap));
                if let Err(e) = flushed {
                    eprintln!("[serve] final flush of {device}: {e:#}");
                }
            }
        }
        // Connection pumps exit once their peer is gone and their queued
        // responses are flushed (all Reply handles were dropped above).
        let conns: Vec<JoinHandle<()>> = {
            let mut c = self.shared.conns.lock().expect("serve connections");
            c.drain(..).collect()
        };
        for c in conns {
            c.join().map_err(|_| anyhow!("serve connection pump panicked"))?;
        }
        let responses =
            std::mem::take(&mut *self.shared.record.lock().expect("record"));
        let clock = self.shared.clock.lock().expect("serve clock");
        let wall_secs = match (clock.first_request, clock.last_response) {
            (Some(t0), Some(t1)) => {
                t1.saturating_duration_since(t0).as_secs_f64()
            }
            _ => 0.0,
        };
        drop(clock);
        Ok(ServeReport {
            responses,
            requests: self.shared.requests.load(Ordering::Relaxed),
            rehydrations: self.shared.rehydrations.load(Ordering::Relaxed),
            evictions: self.shared.evictions.load(Ordering::Relaxed),
            wall_secs,
            threads: self.threads,
        })
    }
}

impl Drop for FleetServer {
    /// Abort path (no [`Self::join`]): stop accepting, let the pool
    /// drain what is already queued, and reap what can be reaped without
    /// blocking on live clients.  The dispatcher and per-connection
    /// pumps exit on their own once every client disconnects, so they
    /// are *detached*, not joined — dropping a server with a client
    /// still attached must not hang the dropping thread.  Requests
    /// submitted after the drop are answered with an `Error` by the
    /// detached dispatcher; a request racing the drop itself may go
    /// unanswered (an aborting server makes no delivery promises).  No
    /// final store flush runs — but the write-through path has already
    /// persisted every state a client was told about, so a store-backed
    /// fleet still resumes to the last acknowledged state.
    /// No-op after `join()` (which consumed the handles already).
    fn drop(&mut self) {
        self.ingress.take();
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Detach the dispatcher: it exits once every connection reader
        // has dropped its ingress handle (i.e. every client is gone).
        self.dispatcher.take();
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Connection pumps are likewise detached; their handles are
        // freed with `Shared` when the last thread holding it exits.
    }
}

/// Everything one server run produced.
pub struct ServeReport {
    /// Responses in completion order (per device: execution order).
    pub responses: Vec<Response>,
    pub requests: u64,
    /// Sessions rebuilt from the state store (lazy rehydrations of
    /// evicted devices + resumed registers).
    pub rehydrations: u64,
    /// Idle devices flushed out of memory under `resident_cap` pressure.
    pub evictions: u64,
    /// First request received → last response emitted.  Idle time before
    /// traffic arrives does not count against requests/sec.
    pub wall_secs: f64,
    pub threads: usize,
}

impl ServeReport {
    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / self.wall_secs.max(1e-9)
    }

    /// Rehydrations per second of serving wall time (the LRU churn rate
    /// under eviction pressure — what the `serve` bench tracks).
    pub fn rehydrations_per_sec(&self) -> f64 {
        self.rehydrations as f64 / self.wall_secs.max(1e-9)
    }

    pub fn errors(&self) -> usize {
        self.responses.iter().filter(|r| r.is_error()).count()
    }

    /// This device's responses, in its execution order.
    pub fn for_device<'a>(&'a self, device: &str) -> Vec<&'a Response> {
        self.responses.iter().filter(|r| r.device() == device).collect()
    }

    /// One-paragraph run summary.
    pub fn summary(&self) -> String {
        let mut kinds: HashMap<&'static str, usize> = HashMap::new();
        for r in &self.responses {
            let k = match r {
                Response::Registered { .. } => "registered",
                Response::TrainDone { .. } => "train-done",
                Response::Prediction { .. } => "predictions",
                Response::Evaluation { .. } => "evaluations",
                Response::Drifted { .. } => "drifts",
                Response::Error { .. } => "errors",
            };
            *kinds.entry(k).or_insert(0) += 1;
        }
        let mut parts: Vec<String> =
            kinds.iter().map(|(k, v)| format!("{v} {k}")).collect();
        parts.sort();
        let mut out = format!(
            "{} requests in {:.2}s on {} threads — {:.1} requests/s ({})",
            self.requests,
            self.wall_secs,
            self.threads,
            self.requests_per_sec(),
            parts.join(", ")
        );
        if self.rehydrations > 0 || self.evictions > 0 {
            out.push_str(&format!(
                "; {} rehydrations, {} evictions",
                self.rehydrations, self.evictions
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Scripted request traces (the `priot serve` / `priot client` front-ends)
// ---------------------------------------------------------------------------

/// One line of a scripted request trace.  Datasets stay symbolic (an
/// `angle` into the artifact data) — the CLI resolves them to files.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceCmd {
    Register { device: String, seed: u32, method: MethodSpec, angle: u32 },
    Train { device: String, epochs: usize },
    /// Classify sample `sample` of the device's current test set.
    Predict { device: String, sample: usize },
    Evaluate { device: String },
    Drift { device: String, angle: u32 },
}

/// A worked sample trace (also what `priot serve` runs when no `--trace`
/// file is given): two devices with different methods and local drifts —
/// including an arbitrary-angle drift (60°), which the CLI resolves by
/// generating the dataset in-process when no artifact exists
/// ([`crate::data::DataSource`]).
pub const DEMO_TRACE: &str = "\
# priot serve demo trace: <verb> <device> [key=value]...
register dev-a seed=1 method=priot angle=30
register dev-b seed=2 method=priot-s frac=0.1 selection=weight angle=45
train dev-a epochs=2
train dev-b epochs=2
predict dev-a sample=0
predict dev-b sample=3
evaluate dev-a
evaluate dev-b
drift dev-a 45           # drift takes its angle positionally too
train dev-a epochs=1
evaluate dev-a
drift dev-b 60           # any angle: no 60-degree artifact is ever built
train dev-b epochs=1
evaluate dev-b
";

/// Parse a request trace: one command per line, `# comments` and blank
/// lines ignored.  Grammar per line: `<verb> <device> [key=value]...`
/// with verbs `register | train | predict | evaluate | drift`; `drift`
/// also accepts its angle positionally (`drift dev0 60`).
pub fn parse_trace(text: &str) -> Result<Vec<TraceCmd>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        out.push(parse_trace_line(line)
            .with_context(|| format!("trace line {}: {line}", ln + 1))?);
    }
    Ok(out)
}

fn parse_trace_line(line: &str) -> Result<TraceCmd> {
    let mut it = line.split_whitespace();
    let verb = it.next().expect("non-empty line");
    let device = it
        .next()
        .ok_or_else(|| anyhow!("missing device name"))?
        .to_string();
    let mut kv: HashMap<&str, &str> = HashMap::new();
    let mut positional: Vec<&str> = Vec::new();
    for tok in it {
        match tok.split_once('=') {
            Some((k, v)) => {
                kv.insert(k, v);
            }
            None => positional.push(tok),
        }
    }
    if verb != "drift" && !positional.is_empty() {
        bail!("unexpected value {} (expected key=value)", positional[0]);
    }
    let get_usize = |kv: &HashMap<&str, &str>, k: &str, d: usize| -> Result<usize> {
        match kv.get(k) {
            None => Ok(d),
            Some(v) => v.parse().with_context(|| format!("{k}={v}")),
        }
    };
    Ok(match verb {
        "register" => {
            let method = Method::parse(kv.get("method").copied().unwrap_or("priot"))?;
            let selection = crate::config::Selection::parse(
                kv.get("selection").copied().unwrap_or("weight"))?;
            let frac_scored = match kv.get("frac") {
                None => 0.1,
                Some(v) => v.parse().with_context(|| format!("frac={v}"))?,
            };
            let theta = match kv.get("theta") {
                None => None,
                Some(v) => {
                    Some(v.parse().with_context(|| format!("theta={v}"))?)
                }
            };
            TraceCmd::Register {
                device,
                seed: get_usize(&kv, "seed", 1)? as u32,
                method: MethodSpec { method, frac_scored, selection, theta },
                angle: get_usize(&kv, "angle", 30)? as u32,
            }
        }
        "train" => TraceCmd::Train {
            device,
            epochs: get_usize(&kv, "epochs", 1)?,
        },
        "predict" => TraceCmd::Predict {
            device,
            sample: get_usize(&kv, "sample", 0)?,
        },
        "evaluate" => TraceCmd::Evaluate { device },
        "drift" => {
            // Arbitrary drift angles, positionally or as angle=N — no
            // hardcoded 30°/45° pair.
            let angle = match (positional.as_slice(), kv.get("angle")) {
                ([], None) => 45,
                ([], Some(v)) => {
                    v.parse().with_context(|| format!("angle={v}"))?
                }
                ([one], None) => one
                    .parse()
                    .with_context(|| format!("drift angle {one}"))?,
                ([_], Some(_)) => {
                    bail!("drift angle given both positionally and as angle=")
                }
                (more, _) => bail!("too many values: {}", more.join(" ")),
            };
            TraceCmd::Drift { device, angle }
        }
        other => bail!("unknown trace verb {other} \
                        (want register|train|predict|evaluate|drift)"),
    })
}

/// Replay a parsed trace over a connected client, one synchronous
/// request at a time (so per-device order is submission order and the
/// result stream is deterministic — bit-identical across transports and
/// to a standalone [`Session`] executing the same operations).
/// `pair_for` resolves a symbolic drift angle to its datasets; the angle
/// travels with `Register`/`Drift` as provenance, so durable snapshots
/// record which rotation a device's data came from.
pub fn replay_trace(
    client: &mut FleetClient,
    cmds: &[TraceCmd],
    pair_for: &mut dyn FnMut(u32) -> Result<(Arc<Dataset>, Arc<Dataset>)>,
) -> Result<Vec<Response>> {
    let mut device_test: HashMap<String, Arc<Dataset>> = HashMap::new();
    let mut out = Vec::with_capacity(cmds.len());
    for cmd in cmds {
        let resp = match cmd.clone() {
            TraceCmd::Register { device, seed, method, angle } => {
                let (train, test) = pair_for(angle)?;
                device_test.insert(device.clone(), Arc::clone(&test));
                client.register_at(&device, seed, method, train, test,
                                   Some(angle))?
            }
            TraceCmd::Train { device, epochs } => {
                client.train(&device, epochs)?
            }
            TraceCmd::Predict { device, sample } => {
                let test = device_test.get(&device).ok_or_else(|| anyhow!(
                    "trace predicts on unregistered device {device}"))?;
                if test.n == 0 {
                    bail!("trace predicts on device {device}, whose test \
                           set is empty");
                }
                let image = test.image(sample % test.n).to_vec();
                client.predict(&device, image)?
            }
            TraceCmd::Evaluate { device } => client.evaluate(&device)?,
            TraceCmd::Drift { device, angle } => {
                let (train, test) = pair_for(angle)?;
                device_test.insert(device.clone(), Arc::clone(&test));
                client.drift_at(&device, train, test, Some(angle))?
            }
        };
        out.push(resp);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Selection;

    #[test]
    fn parse_trace_demo_roundtrip() {
        let cmds = parse_trace(DEMO_TRACE).unwrap();
        assert_eq!(cmds.len(), 14);
        assert_eq!(cmds[0], TraceCmd::Register {
            device: "dev-a".into(),
            seed: 1,
            method: MethodSpec {
                method: Method::Priot,
                frac_scored: 0.1,
                selection: Selection::WeightBased,
                theta: None,
            },
            angle: 30,
        });
        assert_eq!(cmds[2], TraceCmd::Train { device: "dev-a".into(), epochs: 2 });
        assert_eq!(cmds[8], TraceCmd::Drift { device: "dev-a".into(), angle: 45 });
    }

    #[test]
    fn parse_trace_rejects_garbage() {
        assert!(parse_trace("launch dev-a").is_err(), "unknown verb");
        assert!(parse_trace("train").is_err(), "missing device");
        assert!(parse_trace("train dev-a epochs").is_err(), "bare key");
        assert!(parse_trace("train dev-a epochs=three").is_err(), "bad value");
        assert!(parse_trace("register d method=sgd").is_err(), "bad method");
        let err = parse_trace("ok-line dev\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn parse_trace_drift_takes_arbitrary_angles() {
        // Positional, keyed, and defaulted forms; no hardcoded 30/45 pair.
        let cmds =
            parse_trace("drift d0 60\ndrift d1 angle=135\ndrift d2").unwrap();
        assert_eq!(cmds[0], TraceCmd::Drift { device: "d0".into(), angle: 60 });
        assert_eq!(cmds[1], TraceCmd::Drift { device: "d1".into(), angle: 135 });
        assert_eq!(cmds[2], TraceCmd::Drift { device: "d2".into(), angle: 45 });

        assert!(parse_trace("drift d0 60 angle=45").is_err(),
                "positional + keyed angle is ambiguous");
        assert!(parse_trace("drift d0 60 70").is_err(), "two positionals");
        assert!(parse_trace("drift d0 sixty").is_err(), "non-numeric angle");
        // Positional values stay drift-only.
        assert!(parse_trace("train d0 3").is_err(),
                "train takes epochs=N, not a positional");
    }

    #[test]
    fn method_spec_builds_plugins() {
        let m = MethodSpec {
            method: Method::PriotS,
            frac_scored: 0.2,
            selection: Selection::Random,
            theta: Some(-5),
        };
        assert_eq!(m.plugin().name(), "priot-s");
        let m = MethodSpec::niti_static();
        assert_eq!(m.plugin().name(), "static-niti");
    }
}
