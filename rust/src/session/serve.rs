//! `priot::serve` — a long-lived fleet service.
//!
//! [`Fleet`](super::Fleet) runs a *closed* roster of devices to
//! completion; this module is the open-ended counterpart the ROADMAP's
//! north star asks for: a service that owns one shared
//! `Arc<`[`Backbone`]`>` plus a registry of per-device [`Session`]s and
//! consumes a **stream** of [`Request`] messages over an mpsc channel —
//! register a device, train it some epochs, classify an image, evaluate,
//! or swap its local data when the distribution drifts.
//!
//! Scheduling is epoch-granular, like the fleet queue: every queued unit
//! of work is *one* operation of *one* device (one training epoch, one
//! prediction, one evaluation), and a device with pending work re-queues
//! at the back after each unit, so a device mid-adaptation never
//! monopolizes a worker while other devices' requests wait.  Operations
//! of one device always run in submission order on its own session state,
//! so per-device results are bit-identical to a standalone session; work
//! of *different* devices interleaves freely across the pool.
//!
//! Evaluation goes through the batched forward path
//! ([`Session::evaluate_batch`]) — bit-identical to per-sample, faster.
//!
//! ```no_run
//! use std::sync::Arc;
//! use priot::methods::Priot;
//! use priot::session::{Backbone, FleetServer, Request};
//!
//! let backbone = Backbone::load("artifacts".as_ref(), "tinycnn")?;
//! # let (train, test): (Arc<priot::serial::Dataset>, Arc<priot::serial::Dataset>) = todo!();
//! let server = FleetServer::builder(backbone).threads(4).build();
//! server.submit(Request::Register {
//!     device: "dev-00".into(), seed: 1,
//!     plugin: Box::new(Priot::new()), train, test,
//! })?;
//! server.submit(Request::Train { device: "dev-00".into(), epochs: 2 })?;
//! server.submit(Request::Evaluate { device: "dev-00".into() })?;
//! let report = server.join()?;   // drain + shut down
//! println!("{}", report.summary());
//! # anyhow::Ok(())
//! ```
//!
//! The `priot serve` CLI subcommand drives a server from a scripted
//! request trace ([`parse_trace`]; [`DEMO_TRACE`] is a worked sample).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Method, Selection};
use crate::coordinator::capped;
use crate::methods::{MethodPlugin, Niti, Priot, PriotS};
use crate::serial::{u8_to_i32_pixels, Dataset};

use super::{Backbone, Session};

// ---------------------------------------------------------------------------
// Protocol
// ---------------------------------------------------------------------------

/// One message into the fleet service.  Datasets travel as `Arc` so a
/// request never copies image payloads.
pub enum Request {
    /// Add a device: builds a session over the shared backbone after
    /// validating the device's data against the backbone spec.
    Register {
        device: String,
        seed: u32,
        plugin: Box<dyn MethodPlugin>,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
    },
    /// Adapt for `epochs` epochs on the device's local train set.
    Train { device: String, epochs: usize },
    /// Classify one raw u8 image (the on-device `p >> 1` pixel mapping is
    /// applied server-side).
    Predict { device: String, image: Vec<u8> },
    /// Top-1 accuracy over the device's local test set (batched forward).
    Evaluate { device: String },
    /// The device's local distribution drifted: swap its datasets.  Takes
    /// effect after the device's previously queued work, preserving
    /// submission order.
    Drift {
        device: String,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
    },
}

impl Request {
    /// The device a request addresses.
    pub fn device(&self) -> &str {
        match self {
            Request::Register { device, .. }
            | Request::Train { device, .. }
            | Request::Predict { device, .. }
            | Request::Evaluate { device }
            | Request::Drift { device, .. } => device,
        }
    }
}

/// One message out of the fleet service.  A device's *op* responses
/// (train/predict/evaluate/drift) arrive in its submission order;
/// dispatch-time validation errors are emitted immediately and may
/// overtake responses of the device's still-queued earlier ops.  Responses
/// of different devices interleave freely.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Registered { device: String },
    /// One completed [`Request::Train`]: epochs and **executed** steps.
    TrainDone {
        device: String,
        epochs: usize,
        steps: u64,
        train_accuracy: f64,
    },
    Prediction { device: String, class: usize },
    Evaluation { device: String, accuracy: f64, n: usize },
    Drifted { device: String },
    Error { device: String, message: String },
}

impl Response {
    pub fn device(&self) -> &str {
        match self {
            Response::Registered { device }
            | Response::TrainDone { device, .. }
            | Response::Prediction { device, .. }
            | Response::Evaluation { device, .. }
            | Response::Drifted { device }
            | Response::Error { device, .. } => device,
        }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, Response::Error { .. })
    }
}

// ---------------------------------------------------------------------------
// Scheduler internals
// ---------------------------------------------------------------------------

/// One epoch-granular unit of device work.
enum Op {
    /// One training epoch; `last` closes out the originating
    /// [`Request::Train`] and emits its [`Response::TrainDone`].
    TrainEpoch { last: bool },
    /// A zero-epoch [`Request::Train`]: emits its `TrainDone` from the
    /// queue (not the dispatcher) so per-device response order holds.
    TrainNoop,
    Predict { image: Vec<u8> },
    Evaluate,
    Drift { train: Arc<Dataset>, test: Arc<Dataset> },
}

struct DeviceState {
    /// `None` while a worker has the session checked out.
    session: Option<Session>,
    train: Arc<Dataset>,
    test: Arc<Dataset>,
    /// Pending ops, FIFO.  A device appears in the ready queue iff
    /// `queued` — never twice, so its ops can never run concurrently.
    ops: VecDeque<Op>,
    queued: bool,
    /// Accumulators for the in-flight [`Request::Train`].
    req_epochs: usize,
    req_steps: u64,
}

struct Shared {
    backbone: Arc<Backbone>,
    limit: usize,
    eval_batch: usize,
    devices: Mutex<HashMap<String, DeviceState>>,
    /// Devices with pending ops, round-robin.  Lock order: `devices`
    /// before `ready`; `outstanding` is only taken with `devices` held
    /// (dispatcher) or with nothing held (worker epilogue).
    ready: Mutex<VecDeque<String>>,
    ready_cv: Condvar,
    done: AtomicBool,
    /// Ops enqueued but not yet completed (drives graceful shutdown).
    outstanding: Mutex<usize>,
    idle_cv: Condvar,
    requests: AtomicU64,
}

impl Shared {
    /// Tell the worker pool to exit.  The store must synchronize through
    /// the `ready` mutex: a worker that saw `done == false` keeps the
    /// mutex until it is parked inside `ready_cv.wait`, so passing
    /// through the lock before notifying guarantees the wakeup is not
    /// lost between its check and its wait.
    fn signal_done(&self) {
        self.done.store(true, Ordering::SeqCst);
        drop(self.ready.lock().expect("serve ready queue"));
        self.ready_cv.notify_all();
    }
}

fn dispatch(shared: &Shared, rx: Receiver<Request>, events: &Sender<Response>) {
    for req in rx {
        shared.requests.fetch_add(1, Ordering::Relaxed);
        let device = req.device().to_string();
        if let Err(e) = handle_request(shared, req, events) {
            let _ = events.send(Response::Error {
                device,
                message: format!("{e:#}"),
            });
        }
    }
}

fn handle_request(shared: &Shared, req: Request, events: &Sender<Response>)
                  -> Result<()> {
    match req {
        Request::Register { device, seed, plugin, train, test } => {
            crate::data::validate(&train, &shared.backbone.spec)
                .with_context(|| format!("registering {device}: train set"))?;
            crate::data::validate(&test, &shared.backbone.spec)
                .with_context(|| format!("registering {device}: test set"))?;
            let session = Session::builder()
                .backbone(Arc::clone(&shared.backbone))
                .method_boxed(plugin)
                .seed(seed)
                .limit(shared.limit)
                .eval_batch(shared.eval_batch)
                .track_pruning(false)
                .build()
                .with_context(|| format!("registering {device}"))?;
            let mut devices = shared.devices.lock().expect("serve registry");
            if devices.contains_key(&device) {
                bail!("device {device} already registered");
            }
            devices.insert(device.clone(), DeviceState {
                session: Some(session),
                train,
                test,
                ops: VecDeque::new(),
                queued: false,
                req_epochs: 0,
                req_steps: 0,
            });
            drop(devices);
            let _ = events.send(Response::Registered { device });
            Ok(())
        }
        Request::Train { device, epochs } => {
            if epochs == 0 {
                return enqueue(shared, &device, [Op::TrainNoop]);
            }
            let ops =
                (0..epochs).map(|i| Op::TrainEpoch { last: i + 1 == epochs });
            enqueue(shared, &device, ops)
        }
        Request::Predict { device, image } => {
            enqueue(shared, &device, [Op::Predict { image }])
        }
        Request::Evaluate { device } => enqueue(shared, &device, [Op::Evaluate]),
        Request::Drift { device, train, test } => {
            crate::data::validate(&train, &shared.backbone.spec)
                .with_context(|| format!("drifting {device}: train set"))?;
            crate::data::validate(&test, &shared.backbone.spec)
                .with_context(|| format!("drifting {device}: test set"))?;
            enqueue(shared, &device, [Op::Drift { train, test }])
        }
    }
}

fn enqueue(shared: &Shared, device: &str, ops: impl IntoIterator<Item = Op>)
           -> Result<()> {
    let mut devices = shared.devices.lock().expect("serve registry");
    let st = devices
        .get_mut(device)
        .ok_or_else(|| anyhow!("unknown device {device} (register first)"))?;
    let mut added = 0usize;
    for op in ops {
        st.ops.push_back(op);
        added += 1;
    }
    if added == 0 {
        return Ok(());
    }
    *shared.outstanding.lock().expect("serve outstanding") += added;
    if !st.queued {
        st.queued = true;
        shared
            .ready
            .lock()
            .expect("serve ready queue")
            .push_back(device.to_string());
        shared.ready_cv.notify_one();
    }
    Ok(())
}

/// What one executed op produced (turned into a [`Response`] while the
/// device's accumulators are updated under the registry lock).
enum OpOut {
    Epoch { last: bool, steps: u64, train_accuracy: f64 },
    /// A zero-epoch train request reached its queue slot.
    TrainNoop,
    Prediction(usize),
    Evaluation { accuracy: f64, n: usize },
    Drifted { train: Arc<Dataset>, test: Arc<Dataset> },
}

fn run_op(session: &mut Session, op: Op, train: &Dataset, test: &Dataset,
          eval_batch: usize, limit: usize) -> Result<OpOut> {
    match op {
        Op::TrainEpoch { last } => {
            let ep = session.train_epoch(train)?;
            Ok(OpOut::Epoch {
                last,
                steps: ep.steps as u64,
                train_accuracy: ep.train_accuracy,
            })
        }
        Op::TrainNoop => Ok(OpOut::TrainNoop),
        Op::Predict { image } => {
            let want = session.spec.input_len();
            if image.len() != want {
                bail!("predict: image has {} pixels, model {} wants {want}",
                      image.len(), session.spec.name);
            }
            let mut img = vec![0i32; want];
            u8_to_i32_pixels(&image, &mut img);
            Ok(OpOut::Prediction(session.predict(&img)))
        }
        Op::Evaluate => {
            let accuracy = session.evaluate_batch(test, eval_batch)?;
            Ok(OpOut::Evaluation { accuracy, n: capped(test.n, limit) })
        }
        Op::Drift { train: tr, test: te } => Ok(OpOut::Drifted {
            train: tr,
            test: te,
        }),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

fn worker(shared: &Shared, events: &Sender<Response>) {
    loop {
        // Wait for a ready device (or shutdown).
        let device = {
            let mut q = shared.ready.lock().expect("serve ready queue");
            loop {
                if let Some(d) = q.pop_front() {
                    break d;
                }
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready_cv.wait(q).expect("serve ready queue");
            }
        };
        // Check out the session plus the next op; a device is in the ready
        // queue at most once, so nobody else holds this session.
        let (mut session, op, train, test) = {
            let mut devices = shared.devices.lock().expect("serve registry");
            let st = devices.get_mut(&device).expect("ready device registered");
            let op = st.ops.pop_front().expect("ready device has ops");
            (
                st.session.take().expect("ready device owns its session"),
                op,
                Arc::clone(&st.train),
                Arc::clone(&st.test),
            )
        };
        let epoch_last = match &op {
            Op::TrainEpoch { last } => Some(*last),
            _ => None,
        };
        // A panicking op (method plugins are an open extension point) must
        // not kill the worker: the `outstanding` count would never drain
        // and `join()` would hang.  Convert the panic into an error
        // response; engine/score buffers are plain integers, so the
        // checked-back-in session is memory-safe (its method state may be
        // mid-step — the caller sees the Error and can re-register).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || run_op(&mut session, op, &train, &test, shared.eval_batch,
                      shared.limit),
        ))
        .unwrap_or_else(|payload| {
            Err(anyhow!("op panicked: {}", panic_message(payload.as_ref())))
        });
        // Check the session back in, update accumulators, build the
        // response, and re-queue the device if it still has work.
        let mut drained = 0usize;
        let response = {
            let mut devices = shared.devices.lock().expect("serve registry");
            let st = devices.get_mut(&device).expect("device still registered");
            st.session = Some(session);
            let response = match result {
                Ok(OpOut::Epoch { last, steps, train_accuracy }) => {
                    st.req_epochs += 1;
                    st.req_steps += steps;
                    if last {
                        let r = Response::TrainDone {
                            device: device.clone(),
                            epochs: st.req_epochs,
                            steps: st.req_steps,
                            train_accuracy,
                        };
                        st.req_epochs = 0;
                        st.req_steps = 0;
                        Some(r)
                    } else {
                        None
                    }
                }
                Ok(OpOut::TrainNoop) => Some(Response::TrainDone {
                    device: device.clone(),
                    epochs: 0,
                    steps: 0,
                    train_accuracy: 0.0,
                }),
                Ok(OpOut::Prediction(class)) => Some(Response::Prediction {
                    device: device.clone(),
                    class,
                }),
                Ok(OpOut::Evaluation { accuracy, n }) => {
                    Some(Response::Evaluation {
                        device: device.clone(),
                        accuracy,
                        n,
                    })
                }
                Ok(OpOut::Drifted { train, test }) => {
                    st.train = train;
                    st.test = test;
                    Some(Response::Drifted { device: device.clone() })
                }
                Err(e) => {
                    if let Some(last) = epoch_last {
                        // Abandon the in-flight Train accounting, and for
                        // a non-final epoch drop the request's remaining
                        // TrainEpoch ops (they are contiguous — enqueue
                        // is atomic per request) so the failed request
                        // neither trains on for nothing nor emits a
                        // spurious TrainDone after its Error.
                        st.req_epochs = 0;
                        st.req_steps = 0;
                        if !last {
                            while let Some(Op::TrainEpoch { last }) =
                                st.ops.front()
                            {
                                let was_last = *last;
                                st.ops.pop_front();
                                drained += 1;
                                if was_last {
                                    break;
                                }
                            }
                        }
                    }
                    Some(Response::Error {
                        device: device.clone(),
                        message: format!("{e:#}"),
                    })
                }
            };
            if st.ops.is_empty() {
                st.queued = false;
            } else {
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device.clone());
                shared.ready_cv.notify_one();
            }
            response
        };
        if let Some(r) = response {
            let _ = events.send(r);
        }
        let mut out = shared.outstanding.lock().expect("serve outstanding");
        *out -= 1 + drained; // the executed op plus any aborted-Train ops
        if *out == 0 {
            shared.idle_cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// The server handle
// ---------------------------------------------------------------------------

/// Builder for [`FleetServer`].
pub struct ServeBuilder {
    backbone: Arc<Backbone>,
    threads: usize,
    limit: usize,
    eval_batch: usize,
}

impl ServeBuilder {
    /// Worker thread count (0 = available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Per-epoch / per-evaluation sample cap handed to every session
    /// (0 = all).
    pub fn limit(mut self, limit: usize) -> Self {
        self.limit = limit;
        self
    }

    /// Samples per forward in evaluation (bit-identical to per-sample;
    /// default 8).
    pub fn eval_batch(mut self, batch: usize) -> Self {
        self.eval_batch = batch;
        self
    }

    /// Spawn the dispatcher + worker pool and return the live handle.
    pub fn build(self) -> FleetServer {
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        let shared = Arc::new(Shared {
            backbone: self.backbone,
            limit: self.limit,
            eval_batch: self.eval_batch,
            devices: Mutex::new(HashMap::new()),
            ready: Mutex::new(VecDeque::new()),
            ready_cv: Condvar::new(),
            done: AtomicBool::new(false),
            outstanding: Mutex::new(0),
            idle_cv: Condvar::new(),
            requests: AtomicU64::new(0),
        });
        let (tx, rx) = channel::<Request>();
        let (etx, erx) = channel::<Response>();
        let dispatcher = {
            let shared = Arc::clone(&shared);
            let etx = etx.clone();
            std::thread::spawn(move || dispatch(&shared, rx, &etx))
        };
        let workers = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                let etx = etx.clone();
                std::thread::spawn(move || worker(&shared, &etx))
            })
            .collect();
        drop(etx);
        FleetServer {
            shared,
            tx: Some(tx),
            events: erx,
            seen: Mutex::new(Vec::new()),
            dispatcher: Some(dispatcher),
            workers,
            t0: Instant::now(),
            threads,
        }
    }
}

/// The long-lived fleet service: one shared backbone, a registry of
/// per-device sessions, a dispatcher thread feeding an epoch-granular
/// work queue, and a worker pool draining it.  See the module docs.
pub struct FleetServer {
    shared: Arc<Shared>,
    tx: Option<Sender<Request>>,
    events: Receiver<Response>,
    /// Responses already handed out via [`Self::poll`], kept so the final
    /// [`ServeReport`] still covers the whole run.
    seen: Mutex<Vec<Response>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    t0: Instant,
    threads: usize,
}

impl FleetServer {
    pub fn builder(backbone: Arc<Backbone>) -> ServeBuilder {
        ServeBuilder { backbone, threads: 0, limit: 0, eval_batch: 8 }
    }

    /// A clonable request handle (the raw mpsc front door) for callers
    /// that stream requests from another thread.
    ///
    /// **Lifetime contract:** the dispatcher only shuts down once *every*
    /// `Sender` clone is dropped.  [`Self::join`] closes the server's own
    /// handle, then waits — so drop all clones (end the producer threads)
    /// before calling `join`, or it will block until they finish.
    pub fn sender(&self) -> Sender<Request> {
        self.tx.as_ref().expect("server joined").clone()
    }

    /// Submit one request.  Responses arrive asynchronously — poll with
    /// [`Self::poll`] or collect everything via [`Self::join`].
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .as_ref()
            .expect("server joined")
            .send(req)
            .map_err(|_| anyhow!("fleet server is shut down"))
    }

    /// Responses that have arrived so far (non-blocking).  Polled
    /// responses are also retained for the final [`ServeReport`], so
    /// `join()` still returns the complete run.
    pub fn poll(&self) -> Vec<Response> {
        let fresh: Vec<Response> = self.events.try_iter().collect();
        self.seen
            .lock()
            .expect("serve responses")
            .extend(fresh.iter().cloned());
        fresh
    }

    /// Graceful shutdown: close the request channel, finish every queued
    /// op, stop the pool, and return everything the run produced.
    ///
    /// Blocks until the request stream ends — if clones from
    /// [`Self::sender`] are still alive on other threads, `join` waits
    /// for them to drop (see the `sender` docs).
    pub fn join(mut self) -> Result<ServeReport> {
        self.tx.take(); // dispatcher's recv loop ends once drained
        if let Some(d) = self.dispatcher.take() {
            d.join().map_err(|_| anyhow!("serve dispatcher panicked"))?;
        }
        {
            let mut out = self.shared.outstanding.lock().expect("outstanding");
            while *out > 0 {
                out = self.shared.idle_cv.wait(out).expect("outstanding");
            }
        }
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow!("serve worker panicked"))?;
        }
        let mut responses =
            std::mem::take(&mut *self.seen.lock().expect("serve responses"));
        responses.extend(self.events.try_iter());
        Ok(ServeReport {
            responses,
            requests: self.shared.requests.load(Ordering::Relaxed),
            wall_secs: self.t0.elapsed().as_secs_f64(),
            threads: self.threads,
        })
    }
}

impl Drop for FleetServer {
    /// Abort path (no [`Self::join`]): stop accepting requests, let the
    /// pool drain what is already queued, and reap the threads.
    fn drop(&mut self) {
        self.tx.take();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Everything one server run produced.
pub struct ServeReport {
    /// Responses in completion order (per device: submission order).
    pub responses: Vec<Response>,
    pub requests: u64,
    pub wall_secs: f64,
    pub threads: usize,
}

impl ServeReport {
    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / self.wall_secs.max(1e-9)
    }

    pub fn errors(&self) -> usize {
        self.responses.iter().filter(|r| r.is_error()).count()
    }

    /// This device's responses, in its submission order.
    pub fn for_device<'a>(&'a self, device: &str) -> Vec<&'a Response> {
        self.responses.iter().filter(|r| r.device() == device).collect()
    }

    /// One-paragraph run summary.
    pub fn summary(&self) -> String {
        let mut kinds: HashMap<&'static str, usize> = HashMap::new();
        for r in &self.responses {
            let k = match r {
                Response::Registered { .. } => "registered",
                Response::TrainDone { .. } => "train-done",
                Response::Prediction { .. } => "predictions",
                Response::Evaluation { .. } => "evaluations",
                Response::Drifted { .. } => "drifts",
                Response::Error { .. } => "errors",
            };
            *kinds.entry(k).or_insert(0) += 1;
        }
        let mut parts: Vec<String> =
            kinds.iter().map(|(k, v)| format!("{v} {k}")).collect();
        parts.sort();
        format!(
            "{} requests in {:.2}s on {} threads — {:.1} requests/s ({})",
            self.requests,
            self.wall_secs,
            self.threads,
            self.requests_per_sec(),
            parts.join(", ")
        )
    }
}

// ---------------------------------------------------------------------------
// Scripted request traces (the `priot serve` CLI front-end)
// ---------------------------------------------------------------------------

/// The method half of a trace `register` line.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMethod {
    pub method: Method,
    pub frac_scored: f64,
    pub selection: Selection,
    pub theta: Option<i32>,
}

impl TraceMethod {
    pub fn plugin(&self) -> Box<dyn MethodPlugin> {
        match self.method {
            Method::StaticNiti => Box::new(Niti::static_scale()),
            Method::DynamicNiti => Box::new(Niti::dynamic()),
            Method::Priot => {
                let mut p = Priot::new();
                if let Some(t) = self.theta {
                    p = p.with_theta(t);
                }
                Box::new(p)
            }
            Method::PriotS => {
                let mut p = PriotS::new(self.frac_scored, self.selection);
                if let Some(t) = self.theta {
                    p = p.with_theta(t);
                }
                Box::new(p)
            }
        }
    }
}

/// One line of a scripted request trace.  Datasets stay symbolic (an
/// `angle` into the artifact data) — the CLI resolves them to files.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceCmd {
    Register { device: String, seed: u32, method: TraceMethod, angle: u32 },
    Train { device: String, epochs: usize },
    /// Classify sample `sample` of the device's current test set.
    Predict { device: String, sample: usize },
    Evaluate { device: String },
    Drift { device: String, angle: u32 },
}

/// A worked sample trace (also what `priot serve` runs when no `--trace`
/// file is given): two devices with different methods and local drifts.
pub const DEMO_TRACE: &str = "\
# priot serve demo trace: <verb> <device> [key=value]...
register dev-a seed=1 method=priot angle=30
register dev-b seed=2 method=priot-s frac=0.1 selection=weight angle=45
train dev-a epochs=2
train dev-b epochs=2
predict dev-a sample=0
predict dev-b sample=3
evaluate dev-a
evaluate dev-b
drift dev-a angle=45
train dev-a epochs=1
evaluate dev-a
";

/// Parse a request trace: one command per line, `# comments` and blank
/// lines ignored.  Grammar per line: `<verb> <device> [key=value]...` with
/// verbs `register | train | predict | evaluate | drift`.
pub fn parse_trace(text: &str) -> Result<Vec<TraceCmd>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        out.push(parse_trace_line(line)
            .with_context(|| format!("trace line {}: {line}", ln + 1))?);
    }
    Ok(out)
}

fn parse_trace_line(line: &str) -> Result<TraceCmd> {
    let mut it = line.split_whitespace();
    let verb = it.next().expect("non-empty line");
    let device = it
        .next()
        .ok_or_else(|| anyhow!("missing device name"))?
        .to_string();
    let mut kv: HashMap<&str, &str> = HashMap::new();
    for pair in it {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| anyhow!("expected key=value, got {pair}"))?;
        kv.insert(k, v);
    }
    let get_usize = |kv: &HashMap<&str, &str>, k: &str, d: usize| -> Result<usize> {
        match kv.get(k) {
            None => Ok(d),
            Some(v) => v.parse().with_context(|| format!("{k}={v}")),
        }
    };
    Ok(match verb {
        "register" => {
            let method = Method::parse(kv.get("method").copied().unwrap_or("priot"))?;
            let selection =
                Selection::parse(kv.get("selection").copied().unwrap_or("weight"))?;
            let frac_scored = match kv.get("frac") {
                None => 0.1,
                Some(v) => v.parse().with_context(|| format!("frac={v}"))?,
            };
            let theta = match kv.get("theta") {
                None => None,
                Some(v) => {
                    Some(v.parse().with_context(|| format!("theta={v}"))?)
                }
            };
            TraceCmd::Register {
                device,
                seed: get_usize(&kv, "seed", 1)? as u32,
                method: TraceMethod { method, frac_scored, selection, theta },
                angle: get_usize(&kv, "angle", 30)? as u32,
            }
        }
        "train" => TraceCmd::Train {
            device,
            epochs: get_usize(&kv, "epochs", 1)?,
        },
        "predict" => TraceCmd::Predict {
            device,
            sample: get_usize(&kv, "sample", 0)?,
        },
        "evaluate" => TraceCmd::Evaluate { device },
        "drift" => TraceCmd::Drift {
            device,
            angle: get_usize(&kv, "angle", 45)? as u32,
        },
        other => bail!("unknown trace verb {other} \
                        (want register|train|predict|evaluate|drift)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_trace_demo_roundtrip() {
        let cmds = parse_trace(DEMO_TRACE).unwrap();
        assert_eq!(cmds.len(), 11);
        assert_eq!(cmds[0], TraceCmd::Register {
            device: "dev-a".into(),
            seed: 1,
            method: TraceMethod {
                method: Method::Priot,
                frac_scored: 0.1,
                selection: Selection::WeightBased,
                theta: None,
            },
            angle: 30,
        });
        assert_eq!(cmds[2], TraceCmd::Train { device: "dev-a".into(), epochs: 2 });
        assert_eq!(cmds[8], TraceCmd::Drift { device: "dev-a".into(), angle: 45 });
    }

    #[test]
    fn parse_trace_rejects_garbage() {
        assert!(parse_trace("launch dev-a").is_err(), "unknown verb");
        assert!(parse_trace("train").is_err(), "missing device");
        assert!(parse_trace("train dev-a epochs").is_err(), "bare key");
        assert!(parse_trace("train dev-a epochs=three").is_err(), "bad value");
        assert!(parse_trace("register d method=sgd").is_err(), "bad method");
        let err = parse_trace("ok-line dev\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn trace_method_builds_plugins() {
        let m = TraceMethod {
            method: Method::PriotS,
            frac_scored: 0.2,
            selection: Selection::Random,
            theta: Some(-5),
        };
        assert_eq!(m.plugin().name(), "priot-s");
        let m = TraceMethod {
            method: Method::StaticNiti,
            frac_scored: 0.1,
            selection: Selection::WeightBased,
            theta: None,
        };
        assert_eq!(m.plugin().name(), "static-niti");
    }
}
