//! `priot::serve` — a long-lived fleet service behind the
//! [`crate::proto`] wire boundary.
//!
//! [`Fleet`](super::Fleet) runs a *closed* roster of devices to
//! completion; this module is the open-ended counterpart: a service that
//! owns one shared `Arc<`[`Backbone`]`>` plus a registry of per-device
//! [`Session`]s and consumes a **stream** of [`Request`] frames from any
//! number of connected [`FleetClient`]s — register a device, train it
//! some epochs, classify an image, evaluate, or swap its local data when
//! the distribution drifts.
//!
//! Clients connect through a [`Transport`]: in-process over
//! [`FleetServer::local_client`] (mpsc frames) or over TCP via
//! [`FleetServer::listen`] + [`FleetClient::connect`].  Both paths run
//! the same codec and dispatch machinery, so responses are bit-identical
//! whichever transport carries them.
//!
//! ## Scheduling
//!
//! Work is *priority-laned* and *epoch-granular*:
//!
//! * Every queued unit is one operation of one device (one training
//!   epoch, one prediction, one evaluation).  A device with pending work
//!   re-queues at the back after each unit, so a device mid-adaptation
//!   never monopolizes a worker while other devices wait.
//! * Within a device, pending requests drain by [`Priority`]
//!   (predict > evaluate > train, FIFO within a class): an interactive
//!   prediction submitted behind a long `Train` is answered between
//!   training epochs instead of after all of them.  A multi-epoch
//!   `Train` materializes one epoch at a time, so it can be preempted at
//!   every epoch boundary.  `Drift` rides the training lane, preserving
//!   train → drift → train submission order.
//! * The dispatcher enforces a bounded per-device **inflight window**
//!   ([`ServeBuilder::window`]): a device with too many unanswered
//!   requests gets an immediate `Error` response instead of an unbounded
//!   backlog.
//!
//! Operations of one device never run concurrently, so per-device
//! results are bit-identical to a standalone session executing the same
//! operations in the same order.  A synchronous client (one request in
//! flight) therefore sees exactly standalone behavior; pipelined clients
//! opt into priority reordering (pin everything to
//! [`Priority::Background`] to keep strict submission order).
//!
//! Evaluation goes through the batched forward path
//! ([`Session::evaluate_batch`]) — bit-identical to per-sample, faster.
//!
//! ```no_run
//! use priot::proto::{FleetClient, MethodSpec};
//! use priot::session::{Backbone, FleetServer};
//!
//! let backbone = Backbone::load("artifacts".as_ref(), "tinycnn")?;
//! # let (train, test): (std::sync::Arc<priot::serial::Dataset>,
//! #                     std::sync::Arc<priot::serial::Dataset>) = todo!();
//! let mut server = FleetServer::builder(backbone).threads(4).build();
//! let addr = server.listen("127.0.0.1:0")?;   // or server.local_client()
//! let mut client = FleetClient::connect(addr)?;
//! client.register("dev-00", 1, MethodSpec::priot(), train, test)?;
//! client.train("dev-00", 2)?;
//! client.evaluate("dev-00")?;
//! drop(client);                    // close the connection...
//! let report = server.join()?;     // ...then drain + shut down
//! println!("{}", report.summary());
//! # anyhow::Ok(())
//! ```
//!
//! The `priot serve` CLI subcommand drives a server from a scripted
//! request trace ([`parse_trace`]; [`DEMO_TRACE`] is a worked sample) or
//! listens on TCP (`--listen`); `priot client` replays a trace against a
//! remote server.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Method;
use crate::coordinator::capped;
use crate::proto::codec;
use crate::proto::{
    ChannelTransport, FleetClient, MethodSpec, Priority, Request, Response,
    TcpTransport, Transport,
};
use crate::serial::{u8_to_i32_pixels, Dataset};

use super::{Backbone, Session};

// ---------------------------------------------------------------------------
// Ingress
// ---------------------------------------------------------------------------

/// Reply route of one connection: the worker that completes a request
/// sends `(request id, response)` here; the connection's writer pump
/// encodes and ships it.
#[derive(Clone)]
struct Reply(Sender<(u64, Response)>);

/// One accepted request: decoded frame + its reply route.
struct Inbound {
    id: u64,
    priority: Priority,
    req: Request,
    reply: Reply,
}

/// Decode loop shared by every connection flavor: frames in, [`Inbound`]s
/// out.  A malformed frame is answered — and reported — like any other
/// failed request: an `Error` response carrying the frame's own request
/// id (salvaged from the fixed header, so a synchronous client waiting
/// on that id sees the error instead of hanging), counted and recorded
/// via [`respond`].  The connection keeps serving — framing is
/// length-delimited, so one bad payload does not desync the stream.
fn read_loop(shared: &Shared,
             mut recv: impl FnMut() -> Result<Option<Vec<u8>>>,
             ingress: &Sender<Inbound>, reply: &Reply) {
    loop {
        let frame = match recv() {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => break, // peer closed / connection error
        };
        match codec::decode_request(&frame) {
            Ok((id, priority, req)) => {
                let inb = Inbound { id, priority, req, reply: reply.clone() };
                if ingress.send(inb).is_err() {
                    break; // server shutting down
                }
            }
            Err(e) => {
                note_request(shared);
                respond(shared, reply, codec::frame_request_id(&frame),
                        Response::Error {
                            device: String::new(),
                            message: format!("bad request frame: {e:#}"),
                        });
            }
        }
    }
}

/// Wire up one connection, whatever carries its frames: a writer pump
/// encoding responses into `send_frame` and a reader pump feeding
/// decoded requests to the dispatcher.
fn spawn_connection(
    shared: &Arc<Shared>,
    ingress: Sender<Inbound>,
    mut send_frame: impl FnMut(Vec<u8>) -> bool + Send + 'static,
    recv_frame: impl FnMut() -> Result<Option<Vec<u8>>> + Send + 'static,
) {
    let (otx, orx) = channel::<(u64, Response)>();
    let writer = std::thread::spawn(move || {
        for (id, resp) in orx {
            if !send_frame(codec::encode_response(id, &resp)) {
                break;
            }
        }
    });
    let reply = Reply(otx);
    let reader = {
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            read_loop(&shared, recv_frame, &ingress, &reply);
        })
    };
    track_conn(shared, reader, writer);
}

// ---------------------------------------------------------------------------
// Scheduler internals
// ---------------------------------------------------------------------------

/// The pending work of one accepted request.  A multi-epoch `Train` is a
/// single item that yields one epoch per turn at the device — the unit
/// the priority lanes preempt at.
enum Work {
    Train { remaining: usize, done: usize, steps: u64 },
    Predict { image: Vec<u8> },
    Evaluate,
    Drift { train: Arc<Dataset>, test: Arc<Dataset> },
}

/// One queued request: its id, reply route, and pending work.
struct Item {
    id: u64,
    reply: Reply,
    work: Work,
}

struct DeviceState {
    /// `None` while a worker has the session checked out.
    session: Option<Session>,
    train: Arc<Dataset>,
    test: Arc<Dataset>,
    /// Pending items by [`Priority`] lane; FIFO within a lane.  A device
    /// appears in the ready queue iff `queued` — never twice, so its ops
    /// can never run concurrently.
    lanes: [VecDeque<Item>; Priority::COUNT],
    queued: bool,
    /// Accepted, unanswered requests (the inflight-window count).
    pending: usize,
}

impl DeviceState {
    fn has_work(&self) -> bool {
        self.lanes.iter().any(|l| !l.is_empty())
    }
}

/// Serving clock: requests/sec covers first request → last response, not
/// idle time before traffic arrives.
#[derive(Default)]
struct Clock {
    first_request: Option<Instant>,
    last_response: Option<Instant>,
}

struct Shared {
    backbone: Arc<Backbone>,
    limit: usize,
    eval_batch: usize,
    window: usize,
    devices: Mutex<HashMap<String, DeviceState>>,
    /// Devices with pending work, round-robin.  Lock order: `devices`
    /// before `ready`/`outstanding`/`record`/`clock`; none of those four
    /// is ever held while taking another of them or `devices`.
    ready: Mutex<VecDeque<String>>,
    ready_cv: Condvar,
    done: AtomicBool,
    /// Accepted op-requests not yet answered (drives graceful shutdown).
    outstanding: Mutex<usize>,
    idle_cv: Condvar,
    requests: AtomicU64,
    /// Every response the run produced, completion order (the
    /// [`ServeReport`] source — per-connection streams are routed
    /// separately via [`Reply`]).
    record: Mutex<Vec<Response>>,
    /// Recording off = a long-lived server (`priot serve --listen`) that
    /// never `join()`s does not grow `record` without bound.
    record_enabled: bool,
    clock: Mutex<Clock>,
    accepting: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

/// Track a connection's pump threads, reaping the handles of pumps that
/// already finished (long-lived servers see many connections come and
/// go; their handles must not accumulate until `join()`).
fn track_conn(shared: &Shared, reader: JoinHandle<()>, writer: JoinHandle<()>) {
    let mut conns = shared.conns.lock().expect("serve connections");
    conns.retain(|h| !h.is_finished());
    conns.push(reader);
    conns.push(writer);
}

impl Shared {
    /// Tell the worker pool to exit.  The store must synchronize through
    /// the `ready` mutex: a worker that saw `done == false` keeps the
    /// mutex until it is parked inside `ready_cv.wait`, so passing
    /// through the lock before notifying guarantees the wakeup is not
    /// lost between its check and its wait.
    fn signal_done(&self) {
        self.done.store(true, Ordering::SeqCst);
        drop(self.ready.lock().expect("serve ready queue"));
        self.ready_cv.notify_all();
    }
}

/// Record a response (when recording is on) and route it to its
/// connection.
fn respond(shared: &Shared, reply: &Reply, id: u64, resp: Response) {
    shared.clock.lock().expect("serve clock").last_response =
        Some(Instant::now());
    if shared.record_enabled {
        shared.record.lock().expect("serve record").push(resp.clone());
    }
    let _ = reply.0.send((id, resp));
}

/// Count one received request and start the serving clock on the first.
fn note_request(shared: &Shared) {
    shared.requests.fetch_add(1, Ordering::Relaxed);
    let mut clock = shared.clock.lock().expect("serve clock");
    if clock.first_request.is_none() {
        clock.first_request = Some(Instant::now());
    }
}

fn dispatch(shared: &Shared, rx: Receiver<Inbound>) {
    for inb in rx {
        note_request(shared);
        let device = inb.req.device().to_string();
        let (id, reply) = (inb.id, inb.reply.clone());
        // After an abort (`Drop` without `join`: worker pool stopped,
        // dispatcher detached) the server must still *answer* — with an
        // error — or a synchronous client that submits after the drop
        // would wait forever on a request nothing will ever run.
        if shared.done.load(Ordering::SeqCst) {
            respond(shared, &reply, id, Response::Error {
                device,
                message: "fleet server is shut down".into(),
            });
            continue;
        }
        if let Err(e) = handle_request(shared, inb) {
            respond(shared, &reply, id, Response::Error {
                device,
                message: format!("{e:#}"),
            });
        }
    }
}

fn handle_request(shared: &Shared, inb: Inbound) -> Result<()> {
    let Inbound { id, priority, req, reply } = inb;
    match req {
        // Register runs inline on the dispatcher (not through the
        // lanes): a device's lanes cannot exist before its session does,
        // and building the session here keeps the "registered ⇔ has
        // lanes" invariant trivially single-threaded.  The cost is that
        // a register stalls dispatch for the duration of one session
        // construction (sub-millisecond for the paper's models); moving
        // construction onto the worker pool is a ROADMAP item.
        Request::Register { device, seed, method, train, test } => {
            crate::data::validate(&train, &shared.backbone.spec)
                .with_context(|| format!("registering {device}: train set"))?;
            crate::data::validate(&test, &shared.backbone.spec)
                .with_context(|| format!("registering {device}: test set"))?;
            let session = Session::builder()
                .backbone(Arc::clone(&shared.backbone))
                .method_boxed(method.plugin())
                .seed(seed)
                .limit(shared.limit)
                .eval_batch(shared.eval_batch)
                .track_pruning(false)
                .build()
                .with_context(|| format!("registering {device}"))?;
            {
                let mut devices =
                    shared.devices.lock().expect("serve registry");
                if devices.contains_key(&device) {
                    bail!("device {device} already registered");
                }
                devices.insert(device.clone(), DeviceState {
                    session: Some(session),
                    train,
                    test,
                    lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                    queued: false,
                    pending: 0,
                });
            }
            respond(shared, &reply, id, Response::Registered { device });
            Ok(())
        }
        Request::Train { device, epochs } => enqueue(shared, &device, priority,
            Item {
                id,
                reply,
                work: Work::Train { remaining: epochs, done: 0, steps: 0 },
            }),
        Request::Predict { device, image } => enqueue(shared, &device, priority,
            Item { id, reply, work: Work::Predict { image } }),
        Request::Evaluate { device } => enqueue(shared, &device, priority,
            Item { id, reply, work: Work::Evaluate }),
        Request::Drift { device, train, test } => {
            crate::data::validate(&train, &shared.backbone.spec)
                .with_context(|| format!("drifting {device}: train set"))?;
            crate::data::validate(&test, &shared.backbone.spec)
                .with_context(|| format!("drifting {device}: test set"))?;
            enqueue(shared, &device, priority,
                    Item { id, reply, work: Work::Drift { train, test } })
        }
    }
}

fn enqueue(shared: &Shared, device: &str, priority: Priority, item: Item)
           -> Result<()> {
    let mut devices = shared.devices.lock().expect("serve registry");
    let st = devices
        .get_mut(device)
        .ok_or_else(|| anyhow!("unknown device {device} (register first)"))?;
    if st.pending >= shared.window {
        bail!(
            "device {device}: inflight window full ({} of {} requests \
             pending — drain responses before submitting more)",
            st.pending,
            shared.window
        );
    }
    st.pending += 1;
    st.lanes[priority.lane()].push_back(item);
    *shared.outstanding.lock().expect("serve outstanding") += 1;
    if !st.queued {
        st.queued = true;
        shared
            .ready
            .lock()
            .expect("serve ready queue")
            .push_back(device.to_string());
        shared.ready_cv.notify_one();
    }
    Ok(())
}

/// What one executed unit produced.
enum UnitOut {
    /// A training epoch ran; the request has more epochs to go.
    Continue,
    TrainDone { epochs: usize, steps: u64, train_accuracy: f64 },
    Prediction(usize),
    Evaluation { accuracy: f64, n: usize },
    Drifted { train: Arc<Dataset>, test: Arc<Dataset> },
}

fn run_unit(session: &mut Session, work: &mut Work, train: &Dataset,
            test: &Dataset, eval_batch: usize, limit: usize)
            -> Result<UnitOut> {
    match work {
        Work::Train { remaining, done, steps } => {
            if *remaining == 0 {
                // A zero-epoch request reached its queue slot: close it
                // out in order, with nothing executed.
                return Ok(UnitOut::TrainDone {
                    epochs: 0,
                    steps: 0,
                    train_accuracy: 0.0,
                });
            }
            let ep = session.train_epoch(train)?;
            *remaining -= 1;
            *done += 1;
            *steps += ep.steps as u64;
            if *remaining == 0 {
                Ok(UnitOut::TrainDone {
                    epochs: *done,
                    steps: *steps,
                    train_accuracy: ep.train_accuracy,
                })
            } else {
                Ok(UnitOut::Continue)
            }
        }
        Work::Predict { image } => {
            let want = session.spec.input_len();
            if image.len() != want {
                bail!("predict: image has {} pixels, model {} wants {want}",
                      image.len(), session.spec.name);
            }
            let mut img = vec![0i32; want];
            u8_to_i32_pixels(image, &mut img);
            Ok(UnitOut::Prediction(session.predict(&img)))
        }
        Work::Evaluate => {
            let accuracy = session.evaluate_batch(test, eval_batch)?;
            Ok(UnitOut::Evaluation { accuracy, n: capped(test.n, limit) })
        }
        Work::Drift { train: tr, test: te } => Ok(UnitOut::Drifted {
            train: Arc::clone(tr),
            test: Arc::clone(te),
        }),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

fn worker(shared: &Shared) {
    loop {
        // Wait for a ready device (or shutdown).
        let device = {
            let mut q = shared.ready.lock().expect("serve ready queue");
            loop {
                if let Some(d) = q.pop_front() {
                    break d;
                }
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready_cv.wait(q).expect("serve ready queue");
            }
        };
        // Check out the session plus the highest-priority pending item; a
        // device is in the ready queue at most once, so nobody else holds
        // this session.
        let (mut session, item, lane, train, test) = {
            let mut devices = shared.devices.lock().expect("serve registry");
            let st = devices.get_mut(&device).expect("ready device registered");
            let lane = (0..Priority::COUNT)
                .find(|&l| !st.lanes[l].is_empty())
                .expect("ready device has work");
            let item = st.lanes[lane].pop_front().expect("non-empty lane");
            (
                st.session.take().expect("ready device owns its session"),
                item,
                lane,
                Arc::clone(&st.train),
                Arc::clone(&st.test),
            )
        };
        let Item { id, reply, mut work } = item;
        // A panicking op (method plugins are an open extension point) must
        // not kill the worker: the `outstanding` count would never drain
        // and `join()` would hang.  Convert the panic into an error
        // response; engine/score buffers are plain integers, so the
        // checked-back-in session is memory-safe (its method state may be
        // mid-step — the caller sees the Error and can re-register).
        let unit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || run_unit(&mut session, &mut work, &train, &test,
                        shared.eval_batch, shared.limit),
        ))
        .unwrap_or_else(|payload| {
            Err(anyhow!("op panicked: {}", panic_message(payload.as_ref())))
        });
        // Check the session back in and emit the response (if the request
        // completed) *before* re-queuing the device, so a device's
        // responses leave in execution order.
        let mut responded = false;
        {
            let mut devices = shared.devices.lock().expect("serve registry");
            let st = devices.get_mut(&device).expect("device still registered");
            st.session = Some(session);
            let response = match unit {
                Ok(UnitOut::Continue) => {
                    // Back to the front of its lane: the request resumes
                    // at the device's next turn, after any
                    // higher-priority work cuts in.
                    st.lanes[lane].push_front(Item {
                        id,
                        reply: reply.clone(),
                        work,
                    });
                    None
                }
                Ok(UnitOut::TrainDone { epochs, steps, train_accuracy }) => {
                    Some(Response::TrainDone {
                        device: device.clone(),
                        epochs,
                        steps,
                        train_accuracy,
                    })
                }
                Ok(UnitOut::Prediction(class)) => Some(Response::Prediction {
                    device: device.clone(),
                    class,
                }),
                Ok(UnitOut::Evaluation { accuracy, n }) => {
                    Some(Response::Evaluation {
                        device: device.clone(),
                        accuracy,
                        n,
                    })
                }
                Ok(UnitOut::Drifted { train, test }) => {
                    st.train = train;
                    st.test = test;
                    Some(Response::Drifted { device: device.clone() })
                }
                // A failed Train drops its remaining epochs with it: one
                // Error closes out the whole request — it neither trains
                // on for nothing nor emits a TrainDone after its Error.
                Err(e) => Some(Response::Error {
                    device: device.clone(),
                    message: format!("{e:#}"),
                }),
            };
            if let Some(resp) = response {
                st.pending -= 1;
                respond(shared, &reply, id, resp);
                responded = true;
            }
            if st.has_work() {
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device.clone());
                shared.ready_cv.notify_one();
            } else {
                st.queued = false;
            }
        }
        if responded {
            let mut out = shared.outstanding.lock().expect("serve outstanding");
            *out -= 1;
            if *out == 0 {
                shared.idle_cv.notify_all();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The server handle
// ---------------------------------------------------------------------------

/// Builder for [`FleetServer`].
pub struct ServeBuilder {
    backbone: Arc<Backbone>,
    threads: usize,
    limit: usize,
    eval_batch: usize,
    window: usize,
    record: bool,
}

impl ServeBuilder {
    /// Worker thread count (0 = available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Per-epoch / per-evaluation sample cap handed to every session
    /// (0 = all).
    pub fn limit(mut self, limit: usize) -> Self {
        self.limit = limit;
        self
    }

    /// Samples per forward in evaluation (bit-identical to per-sample;
    /// default 8).
    pub fn eval_batch(mut self, batch: usize) -> Self {
        self.eval_batch = batch;
        self
    }

    /// Per-device inflight window: the maximum accepted-but-unanswered
    /// requests one device may have queued.  Submissions beyond it are
    /// answered with an immediate `Error` instead of growing the backlog
    /// (0 = unbounded; default 64).
    pub fn window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Keep every response for the final [`ServeReport`] (default on).
    /// Turn it off for a long-lived listener that never `join()`s —
    /// responses still reach their clients, but the server no longer
    /// accumulates a copy of each one for the whole process lifetime.
    pub fn record(mut self, on: bool) -> Self {
        self.record = on;
        self
    }

    /// Spawn the dispatcher + worker pool and return the live handle.
    pub fn build(self) -> FleetServer {
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        let shared = Arc::new(Shared {
            backbone: self.backbone,
            limit: self.limit,
            eval_batch: self.eval_batch,
            window: if self.window == 0 { usize::MAX } else { self.window },
            devices: Mutex::new(HashMap::new()),
            ready: Mutex::new(VecDeque::new()),
            ready_cv: Condvar::new(),
            done: AtomicBool::new(false),
            outstanding: Mutex::new(0),
            idle_cv: Condvar::new(),
            requests: AtomicU64::new(0),
            record: Mutex::new(Vec::new()),
            record_enabled: self.record,
            clock: Mutex::new(Clock::default()),
            accepting: AtomicBool::new(true),
            conns: Mutex::new(Vec::new()),
        });
        let (itx, irx) = channel::<Inbound>();
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || dispatch(&shared, irx))
        };
        let workers = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker(&shared))
            })
            .collect();
        FleetServer {
            shared,
            ingress: Some(itx),
            dispatcher: Some(dispatcher),
            workers,
            acceptor: None,
            threads,
        }
    }
}

/// The long-lived fleet service: one shared backbone, a registry of
/// per-device sessions, a dispatcher thread feeding priority-laned
/// per-device queues, and a worker pool draining them.  Clients talk to
/// it exclusively through [`FleetClient`] — see the module docs.
pub struct FleetServer {
    shared: Arc<Shared>,
    ingress: Option<Sender<Inbound>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    acceptor: Option<JoinHandle<()>>,
    threads: usize,
}

impl FleetServer {
    pub fn builder(backbone: Arc<Backbone>) -> ServeBuilder {
        ServeBuilder {
            backbone,
            threads: 0,
            limit: 0,
            eval_batch: 8,
            window: 64,
            record: true,
        }
    }

    /// Connect an in-process client over a [`ChannelTransport`] — the
    /// successor of the old raw `mpsc::Sender<Request>` front door, now
    /// running the same codec and dispatch path as TCP connections.
    ///
    /// **Lifetime contract:** the dispatcher only shuts down once every
    /// connection has closed.  [`Self::join`] waits for that — so drop
    /// all clients (ending their connections) before calling `join`, or
    /// it will block until they are gone.
    pub fn local_client(&self) -> FleetClient {
        let (client_end, server_end) = ChannelTransport::pair();
        let (stx, srx) = server_end.into_parts();
        let ingress = self.ingress.as_ref().expect("server joined").clone();
        spawn_connection(
            &self.shared,
            ingress,
            move |frame| stx.send(frame).is_ok(),
            move || Ok(srx.recv().ok()),
        );
        FleetClient::over(client_end)
    }

    /// Accept TCP clients on `addr` (e.g. `"127.0.0.1:0"` for an
    /// ephemeral loopback port).  Returns the bound address; connect
    /// with [`FleetClient::connect`].
    pub fn listen(&mut self, addr: &str) -> Result<SocketAddr> {
        if self.acceptor.is_some() {
            bail!("server is already listening");
        }
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding fleet listener on {addr}"))?;
        let local = listener.local_addr()?;
        // Non-blocking accept so the acceptor can observe shutdown.
        listener
            .set_nonblocking(true)
            .context("configuring the fleet listener")?;
        let shared = Arc::clone(&self.shared);
        let ingress = self.ingress.as_ref().expect("server joined").clone();
        self.acceptor = Some(std::thread::spawn(move || {
            while shared.accepting.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // Accepted sockets must not inherit the
                        // listener's non-blocking mode.
                        let _ = stream.set_nonblocking(false);
                        let wstream = match stream.try_clone() {
                            Ok(s) => s,
                            // Connection unusable before it started.
                            Err(_) => continue,
                        };
                        let mut wt = TcpTransport::from_stream(wstream);
                        let mut rt = TcpTransport::from_stream(stream);
                        spawn_connection(
                            &shared,
                            ingress.clone(),
                            move |frame| wt.send(frame).is_ok(),
                            move || rt.recv(),
                        );
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        }));
        Ok(local)
    }

    /// Graceful shutdown: stop accepting connections, finish every
    /// accepted request, stop the pool, and return everything the run
    /// produced.
    ///
    /// Blocks until every connection has closed — drop your
    /// [`FleetClient`]s first (see [`Self::local_client`]).
    pub fn join(mut self) -> Result<ServeReport> {
        self.ingress.take(); // our own ingress handle
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            a.join().map_err(|_| anyhow!("serve acceptor panicked"))?;
        }
        // The dispatcher exits once every connection reader has dropped
        // its ingress handle (i.e. every client disconnected).
        if let Some(d) = self.dispatcher.take() {
            d.join().map_err(|_| anyhow!("serve dispatcher panicked"))?;
        }
        {
            let mut out =
                self.shared.outstanding.lock().expect("serve outstanding");
            while *out > 0 {
                out = self.shared.idle_cv.wait(out).expect("serve outstanding");
            }
        }
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow!("serve worker panicked"))?;
        }
        // Connection pumps exit once their peer is gone and their queued
        // responses are flushed (all Reply handles were dropped above).
        let conns: Vec<JoinHandle<()>> = {
            let mut c = self.shared.conns.lock().expect("serve connections");
            c.drain(..).collect()
        };
        for c in conns {
            c.join().map_err(|_| anyhow!("serve connection pump panicked"))?;
        }
        let responses =
            std::mem::take(&mut *self.shared.record.lock().expect("record"));
        let clock = self.shared.clock.lock().expect("serve clock");
        let wall_secs = match (clock.first_request, clock.last_response) {
            (Some(t0), Some(t1)) => {
                t1.saturating_duration_since(t0).as_secs_f64()
            }
            _ => 0.0,
        };
        drop(clock);
        Ok(ServeReport {
            responses,
            requests: self.shared.requests.load(Ordering::Relaxed),
            wall_secs,
            threads: self.threads,
        })
    }
}

impl Drop for FleetServer {
    /// Abort path (no [`Self::join`]): stop accepting, let the pool
    /// drain what is already queued, and reap what can be reaped without
    /// blocking on live clients.  The dispatcher and per-connection
    /// pumps exit on their own once every client disconnects, so they
    /// are *detached*, not joined — dropping a server with a client
    /// still attached must not hang the dropping thread.  Requests
    /// submitted after the drop are answered with an `Error` by the
    /// detached dispatcher; a request racing the drop itself may go
    /// unanswered (an aborting server makes no delivery promises).
    /// No-op after `join()` (which consumed the handles already).
    fn drop(&mut self) {
        self.ingress.take();
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Detach the dispatcher: it exits once every connection reader
        // has dropped its ingress handle (i.e. every client is gone).
        self.dispatcher.take();
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Connection pumps are likewise detached; their handles are
        // freed with `Shared` when the last thread holding it exits.
    }
}

/// Everything one server run produced.
pub struct ServeReport {
    /// Responses in completion order (per device: execution order).
    pub responses: Vec<Response>,
    pub requests: u64,
    /// First request received → last response emitted.  Idle time before
    /// traffic arrives does not count against requests/sec.
    pub wall_secs: f64,
    pub threads: usize,
}

impl ServeReport {
    pub fn requests_per_sec(&self) -> f64 {
        self.requests as f64 / self.wall_secs.max(1e-9)
    }

    pub fn errors(&self) -> usize {
        self.responses.iter().filter(|r| r.is_error()).count()
    }

    /// This device's responses, in its execution order.
    pub fn for_device<'a>(&'a self, device: &str) -> Vec<&'a Response> {
        self.responses.iter().filter(|r| r.device() == device).collect()
    }

    /// One-paragraph run summary.
    pub fn summary(&self) -> String {
        let mut kinds: HashMap<&'static str, usize> = HashMap::new();
        for r in &self.responses {
            let k = match r {
                Response::Registered { .. } => "registered",
                Response::TrainDone { .. } => "train-done",
                Response::Prediction { .. } => "predictions",
                Response::Evaluation { .. } => "evaluations",
                Response::Drifted { .. } => "drifts",
                Response::Error { .. } => "errors",
            };
            *kinds.entry(k).or_insert(0) += 1;
        }
        let mut parts: Vec<String> =
            kinds.iter().map(|(k, v)| format!("{v} {k}")).collect();
        parts.sort();
        format!(
            "{} requests in {:.2}s on {} threads — {:.1} requests/s ({})",
            self.requests,
            self.wall_secs,
            self.threads,
            self.requests_per_sec(),
            parts.join(", ")
        )
    }
}

// ---------------------------------------------------------------------------
// Scripted request traces (the `priot serve` / `priot client` front-ends)
// ---------------------------------------------------------------------------

/// One line of a scripted request trace.  Datasets stay symbolic (an
/// `angle` into the artifact data) — the CLI resolves them to files.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceCmd {
    Register { device: String, seed: u32, method: MethodSpec, angle: u32 },
    Train { device: String, epochs: usize },
    /// Classify sample `sample` of the device's current test set.
    Predict { device: String, sample: usize },
    Evaluate { device: String },
    Drift { device: String, angle: u32 },
}

/// A worked sample trace (also what `priot serve` runs when no `--trace`
/// file is given): two devices with different methods and local drifts —
/// including an arbitrary-angle drift (60°), which the CLI resolves by
/// generating the dataset in-process when no artifact exists
/// ([`crate::data::DataSource`]).
pub const DEMO_TRACE: &str = "\
# priot serve demo trace: <verb> <device> [key=value]...
register dev-a seed=1 method=priot angle=30
register dev-b seed=2 method=priot-s frac=0.1 selection=weight angle=45
train dev-a epochs=2
train dev-b epochs=2
predict dev-a sample=0
predict dev-b sample=3
evaluate dev-a
evaluate dev-b
drift dev-a 45           # drift takes its angle positionally too
train dev-a epochs=1
evaluate dev-a
drift dev-b 60           # any angle: no 60-degree artifact is ever built
train dev-b epochs=1
evaluate dev-b
";

/// Parse a request trace: one command per line, `# comments` and blank
/// lines ignored.  Grammar per line: `<verb> <device> [key=value]...`
/// with verbs `register | train | predict | evaluate | drift`; `drift`
/// also accepts its angle positionally (`drift dev0 60`).
pub fn parse_trace(text: &str) -> Result<Vec<TraceCmd>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        out.push(parse_trace_line(line)
            .with_context(|| format!("trace line {}: {line}", ln + 1))?);
    }
    Ok(out)
}

fn parse_trace_line(line: &str) -> Result<TraceCmd> {
    let mut it = line.split_whitespace();
    let verb = it.next().expect("non-empty line");
    let device = it
        .next()
        .ok_or_else(|| anyhow!("missing device name"))?
        .to_string();
    let mut kv: HashMap<&str, &str> = HashMap::new();
    let mut positional: Vec<&str> = Vec::new();
    for tok in it {
        match tok.split_once('=') {
            Some((k, v)) => {
                kv.insert(k, v);
            }
            None => positional.push(tok),
        }
    }
    if verb != "drift" && !positional.is_empty() {
        bail!("unexpected value {} (expected key=value)", positional[0]);
    }
    let get_usize = |kv: &HashMap<&str, &str>, k: &str, d: usize| -> Result<usize> {
        match kv.get(k) {
            None => Ok(d),
            Some(v) => v.parse().with_context(|| format!("{k}={v}")),
        }
    };
    Ok(match verb {
        "register" => {
            let method = Method::parse(kv.get("method").copied().unwrap_or("priot"))?;
            let selection = crate::config::Selection::parse(
                kv.get("selection").copied().unwrap_or("weight"))?;
            let frac_scored = match kv.get("frac") {
                None => 0.1,
                Some(v) => v.parse().with_context(|| format!("frac={v}"))?,
            };
            let theta = match kv.get("theta") {
                None => None,
                Some(v) => {
                    Some(v.parse().with_context(|| format!("theta={v}"))?)
                }
            };
            TraceCmd::Register {
                device,
                seed: get_usize(&kv, "seed", 1)? as u32,
                method: MethodSpec { method, frac_scored, selection, theta },
                angle: get_usize(&kv, "angle", 30)? as u32,
            }
        }
        "train" => TraceCmd::Train {
            device,
            epochs: get_usize(&kv, "epochs", 1)?,
        },
        "predict" => TraceCmd::Predict {
            device,
            sample: get_usize(&kv, "sample", 0)?,
        },
        "evaluate" => TraceCmd::Evaluate { device },
        "drift" => {
            // Arbitrary drift angles, positionally or as angle=N — no
            // hardcoded 30°/45° pair.
            let angle = match (positional.as_slice(), kv.get("angle")) {
                ([], None) => 45,
                ([], Some(v)) => {
                    v.parse().with_context(|| format!("angle={v}"))?
                }
                ([one], None) => one
                    .parse()
                    .with_context(|| format!("drift angle {one}"))?,
                ([_], Some(_)) => {
                    bail!("drift angle given both positionally and as angle=")
                }
                (more, _) => bail!("too many values: {}", more.join(" ")),
            };
            TraceCmd::Drift { device, angle }
        }
        other => bail!("unknown trace verb {other} \
                        (want register|train|predict|evaluate|drift)"),
    })
}

/// Replay a parsed trace over a connected client, one synchronous
/// request at a time (so per-device order is submission order and the
/// result stream is deterministic — bit-identical across transports and
/// to a standalone [`Session`] executing the same operations).
/// `pair_for` resolves a symbolic drift angle to its datasets.
pub fn replay_trace(
    client: &mut FleetClient,
    cmds: &[TraceCmd],
    pair_for: &mut dyn FnMut(u32) -> Result<(Arc<Dataset>, Arc<Dataset>)>,
) -> Result<Vec<Response>> {
    let mut device_test: HashMap<String, Arc<Dataset>> = HashMap::new();
    let mut out = Vec::with_capacity(cmds.len());
    for cmd in cmds {
        let resp = match cmd.clone() {
            TraceCmd::Register { device, seed, method, angle } => {
                let (train, test) = pair_for(angle)?;
                device_test.insert(device.clone(), Arc::clone(&test));
                client.register(&device, seed, method, train, test)?
            }
            TraceCmd::Train { device, epochs } => {
                client.train(&device, epochs)?
            }
            TraceCmd::Predict { device, sample } => {
                let test = device_test.get(&device).ok_or_else(|| anyhow!(
                    "trace predicts on unregistered device {device}"))?;
                if test.n == 0 {
                    bail!("trace predicts on device {device}, whose test \
                           set is empty");
                }
                let image = test.image(sample % test.n).to_vec();
                client.predict(&device, image)?
            }
            TraceCmd::Evaluate { device } => client.evaluate(&device)?,
            TraceCmd::Drift { device, angle } => {
                let (train, test) = pair_for(angle)?;
                device_test.insert(device.clone(), Arc::clone(&test));
                client.drift(&device, train, test)?
            }
        };
        out.push(resp);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Selection;

    #[test]
    fn parse_trace_demo_roundtrip() {
        let cmds = parse_trace(DEMO_TRACE).unwrap();
        assert_eq!(cmds.len(), 14);
        assert_eq!(cmds[0], TraceCmd::Register {
            device: "dev-a".into(),
            seed: 1,
            method: MethodSpec {
                method: Method::Priot,
                frac_scored: 0.1,
                selection: Selection::WeightBased,
                theta: None,
            },
            angle: 30,
        });
        assert_eq!(cmds[2], TraceCmd::Train { device: "dev-a".into(), epochs: 2 });
        assert_eq!(cmds[8], TraceCmd::Drift { device: "dev-a".into(), angle: 45 });
    }

    #[test]
    fn parse_trace_rejects_garbage() {
        assert!(parse_trace("launch dev-a").is_err(), "unknown verb");
        assert!(parse_trace("train").is_err(), "missing device");
        assert!(parse_trace("train dev-a epochs").is_err(), "bare key");
        assert!(parse_trace("train dev-a epochs=three").is_err(), "bad value");
        assert!(parse_trace("register d method=sgd").is_err(), "bad method");
        let err = parse_trace("ok-line dev\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn parse_trace_drift_takes_arbitrary_angles() {
        // Positional, keyed, and defaulted forms; no hardcoded 30/45 pair.
        let cmds =
            parse_trace("drift d0 60\ndrift d1 angle=135\ndrift d2").unwrap();
        assert_eq!(cmds[0], TraceCmd::Drift { device: "d0".into(), angle: 60 });
        assert_eq!(cmds[1], TraceCmd::Drift { device: "d1".into(), angle: 135 });
        assert_eq!(cmds[2], TraceCmd::Drift { device: "d2".into(), angle: 45 });

        assert!(parse_trace("drift d0 60 angle=45").is_err(),
                "positional + keyed angle is ambiguous");
        assert!(parse_trace("drift d0 60 70").is_err(), "two positionals");
        assert!(parse_trace("drift d0 sixty").is_err(), "non-numeric angle");
        // Positional values stay drift-only.
        assert!(parse_trace("train d0 3").is_err(),
                "train takes epochs=N, not a positional");
    }

    #[test]
    fn method_spec_builds_plugins() {
        let m = MethodSpec {
            method: Method::PriotS,
            frac_scored: 0.2,
            selection: Selection::Random,
            theta: Some(-5),
        };
        assert_eq!(m.plugin().name(), "priot-s");
        let m = MethodSpec::niti_static();
        assert_eq!(m.plugin().name(), "static-niti");
    }
}
