//! # PRIOT — pruning-based integer-only transfer learning
//!
//! A three-layer reproduction of *PRIOT: Pruning-Based Integer-Only Transfer
//! Learning for Embedded Systems* (IEEE ESL 2025):
//!
//! * **Layer 1/2** (build-time Python): Pallas integer-GEMM kernels composed
//!   into JAX training-step graphs, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3** (this crate): the on-device-learning coordinator, the pure
//!   Rust integer training engine ("picoengine" — the device
//!   implementation), the Raspberry Pi Pico cost/memory simulator, and the
//!   experiment harness that regenerates every table and figure in the
//!   paper.
//!
//! Two interchangeable step backends implement [`methods::StepBackend`]:
//! [`engine`] (pure Rust) and [`runtime`] (PJRT execution of the AOT
//! artifacts).  Integration tests assert they agree **bit-for-bit** — the
//! entire stack is deterministic integer arithmetic.
//!
//! Entry points: the `priot` binary (`rust/src/main.rs`), the examples in
//! `examples/`, and the benches in `rust/benches/` (one per paper
//! table/figure).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod methods;
pub mod metrics;
pub mod pico;
pub mod prng;
pub mod ptest;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serial;
pub mod spec;
pub mod tensor;

/// Symmetric int8 magnitude bound: values live in `[-127, 127]`
/// (`-128` is never produced by any requantization).
pub const INT8_MAX: i32 = 127;
