//! # PRIOT — pruning-based integer-only transfer learning
//!
//! A three-layer reproduction of *PRIOT: Pruning-Based Integer-Only Transfer
//! Learning for Embedded Systems* (IEEE ESL 2025):
//!
//! * **Layer 1/2** (build-time Python): Pallas integer-GEMM kernels composed
//!   into JAX training-step graphs, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3** (this crate): the on-device-learning stack — the pure Rust
//!   integer training engine ("picoengine"), the Raspberry Pi Pico
//!   cost/memory simulator, and the experiment harness that regenerates
//!   every table and figure in the paper.
//!
//! ## The Session/Fleet API
//!
//! All training runs are constructed through [`session`]:
//!
//! ```no_run
//! use priot::session::Session;
//! use priot::methods::PriotS;
//! use priot::config::Selection;
//!
//! let mut session = Session::builder()
//!     .artifacts("artifacts")
//!     .model("tinycnn")
//!     .method(PriotS::new(0.1, Selection::WeightBased))
//!     .seed(7)
//!     .epochs(10)
//!     .build()?;
//! // session.train(&train, &test) / .predict(..) / .save(..) / .restore(..)
//! # anyhow::Ok(())
//! ```
//!
//! * [`session::Backbone`] — the deployed read-only model, loaded once and
//!   shared across sessions via `Arc` (no per-session weight copies).
//! * [`session::Session`] — one adapting device: a training method bound
//!   to an execution backend.  Dataset-facing entry points validate
//!   geometry up front and return clean errors; evaluation can run
//!   batched ([`session::Session::evaluate_batch`]) — bit-identical to
//!   per-sample, faster.
//! * [`session::Fleet`] — many concurrent sessions over one backbone,
//!   scheduled at **epoch granularity** across the worker pool: the
//!   Table I seed sweep, the `priot fleet` multi-device simulation, and
//!   the `fleet` throughput bench all build on it.
//! * [`serve`] (= [`session::serve`]) — the long-lived, request-driven
//!   fleet service: a stream of `(device, op)` [`serve::Request`]s over an
//!   mpsc channel into a registry of per-device sessions.  Driven by the
//!   `priot serve` CLI subcommand from a scripted request trace, and
//!   benchmarked by the `serve` bench (requests/sec + batched-eval
//!   speedup).
//!
//! ## Methods are plugins
//!
//! Training methods implement [`methods::MethodPlugin`]
//! (init/step/predict/checkpoint hooks).  Built-ins: [`methods::Niti`],
//! [`methods::Priot`], [`methods::PriotS`].  Adding a method touches
//! neither the engine nor the coordinator.
//!
//! ## Backends
//!
//! Two interchangeable executors drive a plugin: the pure-Rust [`engine`]
//! and (behind the `pjrt` cargo feature) PJRT execution of the AOT
//! artifacts ([`runtime`]).  Integration tests assert they agree
//! **bit-for-bit** — the entire stack is deterministic integer arithmetic.
//!
//! Entry points: the `priot` binary (`rust/src/main.rs`), the examples in
//! `examples/`, and the benches in `rust/benches/` (one per paper
//! table/figure, plus `fleet` for session throughput).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod methods;
pub mod metrics;
pub mod pico;
pub mod prng;
pub mod ptest;
pub mod quant;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serial;
pub mod session;
pub mod spec;
pub mod tensor;

pub use session::serve;

/// Symmetric int8 magnitude bound: values live in `[-127, 127]`
/// (`-128` is never produced by any requantization).
pub const INT8_MAX: i32 = 127;
