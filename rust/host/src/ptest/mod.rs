//! Mini property-testing framework (no `proptest` in the offline image).
//!
//! A property is a closure over a seeded [`XorShift64`]; the runner executes
//! it for `iters` independent seeds and reports the first failing seed so a
//! failure is reproducible with `check_seed`.  Shrinking is out of scope —
//! generators here produce small cases by construction.

use crate::prng::XorShift64;

/// True when the suite runs under the hermetic CI gate (`PRIOT_CI=1`).
/// A test that would self-skip (e.g. optional real-artifact or PJRT
/// coverage) must `panic!` instead of silently returning when this is
/// set — CI asserts the hermetic suite never loses coverage quietly.
pub fn ci_strict() -> bool {
    std::env::var("PRIOT_CI").map(|v| v == "1").unwrap_or(false)
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok,
    Failed { seed: u64, message: String },
}

/// Run `prop` for `iters` seeds derived from `base_seed`.  Panics (test
/// failure) with the reproducing seed on the first counterexample.
pub fn check<F>(name: &str, base_seed: u64, iters: u64, prop: F)
where
    F: Fn(&mut XorShift64) -> Result<(), String>,
{
    for i in 0..iters {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(i);
        let mut rng = XorShift64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at iter {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing seed (for debugging a reported failure).
pub fn check_seed<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut XorShift64) -> Result<(), String>,
{
    let mut rng = XorShift64::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use crate::prng::XorShift64;
    use crate::tensor::Mat;

    /// int8-range vector of length `n`.
    pub fn vec_i8(rng: &mut XorShift64, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.int_in(-127, 127)).collect()
    }

    /// int8-range matrix.
    pub fn mat_i8(rng: &mut XorShift64, rows: usize, cols: usize) -> Mat {
        Mat::from_vec(rows, cols, vec_i8(rng, rows * cols))
    }

    /// 0/1 mask vector with ~`frac` ones.
    pub fn mask(rng: &mut XorShift64, n: usize, frac: f64) -> Vec<i32> {
        let thresh = (frac * u32::MAX as f64) as u64;
        (0..n)
            .map(|_| i32::from(rng.next_u64() as u32 as u64 <= thresh))
            .collect()
    }

    /// Small dimension in `[1, hi]`.
    pub fn dim(rng: &mut XorShift64, hi: usize) -> usize {
        1 + rng.below(hi)
    }

    use std::sync::Arc;

    use crate::serial::Dataset;
    use crate::session::Backbone;

    /// A seeded in-memory tinycnn backbone (random int8 weights, default
    /// scales) — the artifact-free fixture shared by the session/serve
    /// test suites, the `serve` bench, and the `fleet_server` example.
    /// Thin wrapper over [`Backbone::synthetic`] (same weight stream).
    pub fn synthetic_backbone(seed: u64) -> Arc<Backbone> {
        Backbone::synthetic("tinycnn", seed).expect("tinycnn spec exists")
    }

    /// A seeded dataset matching the tinycnn input geometry: upright
    /// procedural digits from [`crate::datagen`] — tests, benches and
    /// drift traces all share the one generator (labels cycle 0..10,
    /// shuffled).
    pub fn synthetic_dataset(seed: u64, n: usize) -> Dataset {
        crate::datagen::generate(crate::datagen::Task::Digits, n, seed, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 1, 50, |rng| {
            let (a, b) = (rng.int_in(-1000, 1000), rng.int_in(-1000, 1000));
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 2, 5, |_| Err("nope".into()));
    }

    #[test]
    fn generators_produce_in_range() {
        let mut rng = crate::prng::XorShift64::new(3);
        let v = gen::vec_i8(&mut rng, 100);
        assert!(v.iter().all(|&x| (-127..=127).contains(&x)));
        let m = gen::mask(&mut rng, 1000, 0.3);
        let ones: i32 = m.iter().sum();
        assert!((150..450).contains(&ones), "ones {ones}");
        for _ in 0..100 {
            let d = gen::dim(&mut rng, 8);
            assert!((1..=8).contains(&d));
        }
    }
}
