//! Host-side view of the quantization layer: everything from
//! [`priot_core::quant`], plus loading a scale table off disk.

pub use priot_core::quant::*;

use anyhow::{Context, Result};
use std::path::Path;

/// Load and parse an `artifacts/<model>.scales.txt` scale table
/// (the file-reading counterpart of [`Scales::from_text`], which is
/// `no_std` and lives in the core crate).
pub fn load_scales(path: &Path) -> Result<Scales> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading scales file {}", path.display()))?;
    Ok(Scales::from_text(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_scales_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join("priot_quant_shim_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.scales.txt");
        let s = Scales::default_for(3);
        std::fs::write(&path, s.to_text()).unwrap();
        assert_eq!(load_scales(&path).unwrap(), s);
        assert!(load_scales(&dir.join("missing.txt")).is_err());
    }
}
