//! The versioned binary codec for [`DeviceSnapshot`]s.
//!
//! Since version 2 a snapshot is stored in two parts, so the mutable
//! training state (small, rewritten on every train/drift) no longer
//! drags the device's datasets (large, immutable between drifts) through
//! every write:
//!
//! * the **body** — everything per-device and mutable, plus the content
//!   hashes of the two dataset blobs it references;
//! * two **dataset blobs** — content-addressed by FNV-1a64 of their
//!   encoded bytes, written once per distinct dataset and shared between
//!   devices/snapshots that carry identical data.
//!
//! Body layout, all integers little-endian:
//!
//! ```text
//! u32 magic   "PRST" (0x50525354)
//! u8  version (= SNAPSHOT_VERSION)
//! str device, str model            (u32 len + utf8 bytes each)
//! u32 seed
//! method spec                      (the proto wire encoding)
//! u32 step                         (executed training steps)
//! u64 eval_batch, u64 limit
//! u64 epochs_done
//! opt u32 angle                    (u8 presence flag + value)
//! u8  state tag (0 = scores+masks, 1 = weights)
//!   tag 0: u32 layers, layers × (u32 len + len·i32 scores),
//!          layers × (u32 len + len·i32 masks)
//!   tag 1: u32 layers, layers × (u32 len + len·i32 weights)
//! u64 train blob hash, u64 test blob hash
//! u64 FNV-1a of everything above
//! ```
//!
//! Blob layout (the address is `fnv1a64(blob bytes)`):
//!
//! ```text
//! u32 n, u32 c, u32 h, u32 w
//! n·c·h·w image bytes, n label bytes
//! ```
//!
//! Values are exact i32 — unlike the int8 checkpoint files
//! ([`crate::serial::save_weights`]), a snapshot never narrows state, so
//! rehydration is provably lossless.  Decoding follows the
//! `serial`/`proto` checked discipline (every read names what it reads;
//! truncation and trailing bytes are contextful errors at the failing
//! offset).  The body carries a trailing FNV-1a checksum; blobs are
//! self-checking by construction — the store recomputes each blob's hash
//! on read and rejects any byte flip against the address the body pinned.
//!
//! [`StateStore`]: super::StateStore

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::datagen::fnv1a64;
use crate::proto::codec::{
    put_dataset, put_method, put_opt_u32, put_str, put_u32, put_u64, Reader,
};
use crate::serial::Dataset;

use super::{DeviceSnapshot, PluginState, SessionSnapshot};

/// "PRST" — the snapshot file magic (sibling of serial's PRWT/PRDS).
pub const SNAPSHOT_MAGIC: u32 = 0x5052_5354;

/// Snapshot layout revision.  Bump on any layout change; decoders reject
/// other versions with a clean error.  Version 2 split the dataset
/// payloads out of the body into content-addressed blobs.
pub const SNAPSHOT_VERSION: u8 = 2;

const STATE_SCORES: u8 = 0;
const STATE_WEIGHTS: u8 = 1;

fn put_vec_i32(buf: &mut Vec<u8>, v: &[i32]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_layers(buf: &mut Vec<u8>, layers: &[Vec<i32>]) {
    for l in layers {
        put_vec_i32(buf, l);
    }
}

/// Incremental FNV-1a64 (same constants as [`fnv1a64`]) so a dataset can
/// be content-hashed without first encoding it into a scratch buffer.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
}

/// The content address of `ds`: FNV-1a64 over its encoded blob bytes,
/// computed without allocating the blob.  By construction equal to
/// `fnv1a64(&encode_dataset_blob(ds))`.
pub fn dataset_content_hash(ds: &Dataset) -> u64 {
    let mut h = Fnv::new();
    h.update(&(ds.n as u32).to_le_bytes());
    h.update(&(ds.c as u32).to_le_bytes());
    h.update(&(ds.h as u32).to_le_bytes());
    h.update(&(ds.w as u32).to_le_bytes());
    h.update(&ds.images);
    h.update(&ds.labels);
    h.0
}

/// Encode one dataset blob (dims header + image bytes + label bytes).
pub fn encode_dataset_blob(ds: &Dataset) -> Vec<u8> {
    let mut buf =
        Vec::with_capacity(16 + ds.images.len() + ds.labels.len());
    put_dataset(&mut buf, ds);
    buf
}

/// Decode one dataset blob, verifying its bytes still hash to the
/// address the referencing body pinned.
pub fn decode_dataset_blob(
    bytes: &[u8],
    want: u64,
    what: &str,
) -> Result<Arc<Dataset>> {
    let got = fnv1a64(bytes);
    if got != want {
        bail!(
            "{what}: blob content hash mismatch (want {want:#018x}, \
             computed {got:#018x}) — the blob is corrupt"
        );
    }
    let mut r = Reader::new(bytes);
    let ds = r.dataset(what)?;
    r.finish(what)?;
    Ok(ds)
}

/// The encoded form of one snapshot: the body plus the addresses of the
/// two dataset blobs it references.  The caller (a [`StateStore`]) is
/// responsible for making both blobs durable *before* the body — a body
/// referencing a missing blob is corruption, the reverse is garbage.
///
/// [`StateStore`]: super::StateStore
pub struct EncodedSnapshot {
    pub body: Vec<u8>,
    pub train_hash: u64,
    pub test_hash: u64,
}

/// Encode one snapshot body (including the trailing checksum), returning
/// it with the content addresses of the snapshot's datasets.  Dataset
/// bytes are *not* encoded here — stores call [`encode_dataset_blob`]
/// only for addresses they don't already hold.
pub fn encode_snapshot(snap: &DeviceSnapshot) -> EncodedSnapshot {
    let train_hash = dataset_content_hash(&snap.train);
    let test_hash = dataset_content_hash(&snap.test);
    let mut buf = Vec::new();
    put_u32(&mut buf, SNAPSHOT_MAGIC);
    buf.push(SNAPSHOT_VERSION);
    put_str(&mut buf, &snap.device);
    let s = &snap.session;
    put_str(&mut buf, &s.model);
    put_u32(&mut buf, s.seed);
    put_method(&mut buf, &s.method);
    put_u32(&mut buf, s.step);
    put_u64(&mut buf, s.eval_batch as u64);
    put_u64(&mut buf, s.limit as u64);
    put_u64(&mut buf, snap.epochs_done);
    put_opt_u32(&mut buf, snap.angle);
    match &s.state {
        PluginState::Scores { scores, masks } => {
            debug_assert_eq!(scores.len(), masks.len());
            buf.push(STATE_SCORES);
            put_u32(&mut buf, scores.len() as u32);
            put_layers(&mut buf, scores);
            put_layers(&mut buf, masks);
        }
        PluginState::Weights(weights) => {
            buf.push(STATE_WEIGHTS);
            put_u32(&mut buf, weights.len() as u32);
            put_layers(&mut buf, weights);
        }
    }
    put_u64(&mut buf, train_hash);
    put_u64(&mut buf, test_hash);
    let hash = fnv1a64(&buf);
    put_u64(&mut buf, hash);
    EncodedSnapshot { body: buf, train_hash, test_hash }
}

/// Per-layer count bound, mirroring `serial::load_weights`' "implausible
/// tensor count" guard — a corrupt header must not size huge allocations.
const MAX_LAYERS: usize = 1024;
/// Per-layer value bound (i32 count): 256 MiB of i32s.
const MAX_LAYER_LEN: usize = 64 << 20;

fn read_vec_i32(r: &mut Reader<'_>, what: &str) -> Result<Vec<i32>> {
    let len = r.u32(what)? as usize;
    if len > MAX_LAYER_LEN {
        bail!("{what}: implausible length {len}");
    }
    let raw = r.take(len * 4, what)?;
    Ok(raw
        .chunks_exact(4)
        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_layers(r: &mut Reader<'_>, n: usize, what: &str)
               -> Result<Vec<Vec<i32>>> {
    (0..n)
        .map(|li| read_vec_i32(r, &format!("{what} layer {li}")))
        .collect()
}

/// A decoded snapshot body: everything but the dataset payloads, which
/// the store resolves by content address and attaches via [`assemble`].
///
/// [`assemble`]: SnapshotBody::assemble
pub struct SnapshotBody {
    pub device: String,
    pub session: SessionSnapshot,
    pub epochs_done: u64,
    pub angle: Option<u32>,
    pub train_hash: u64,
    pub test_hash: u64,
}

impl SnapshotBody {
    /// Attach the resolved dataset blobs, completing the snapshot.
    pub fn assemble(
        self,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
    ) -> DeviceSnapshot {
        DeviceSnapshot {
            device: self.device,
            session: self.session,
            train,
            test,
            epochs_done: self.epochs_done,
            angle: self.angle,
        }
    }
}

/// Decode one snapshot body, verifying structure *and* the trailing
/// checksum.
pub fn decode_body(bytes: &[u8]) -> Result<SnapshotBody> {
    if bytes.len() < 8 {
        bail!("snapshot truncated: {} bytes is too short to carry a \
               checksum", bytes.len());
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let mut r = Reader::new(body);
    let magic = r.u32("snapshot magic")?;
    if magic != SNAPSHOT_MAGIC {
        bail!("bad snapshot magic {magic:#x} (want PRST)");
    }
    let version = r.u8("snapshot version")?;
    if version != SNAPSHOT_VERSION {
        bail!("unsupported snapshot version {version} \
               (this build reads version {SNAPSHOT_VERSION})");
    }
    let device = r.str("snapshot device")?;
    let model = r.str("snapshot model")?;
    let seed = r.u32("snapshot seed")?;
    let method = r.method()?;
    let step = r.u32("snapshot step")?;
    let eval_batch = r.u64("snapshot eval_batch")? as usize;
    let limit = r.u64("snapshot limit")? as usize;
    let epochs_done = r.u64("snapshot epochs_done")?;
    let angle = r.opt_u32("snapshot angle")?;
    let state = match r.u8("snapshot state tag")? {
        STATE_SCORES => {
            let n = r.u32("snapshot layer count")? as usize;
            if n > MAX_LAYERS {
                bail!("snapshot has an implausible layer count {n}");
            }
            let scores = read_layers(&mut r, n, "snapshot scores")?;
            let masks = read_layers(&mut r, n, "snapshot masks")?;
            PluginState::Scores { scores, masks }
        }
        STATE_WEIGHTS => {
            let n = r.u32("snapshot layer count")? as usize;
            if n > MAX_LAYERS {
                bail!("snapshot has an implausible layer count {n}");
            }
            PluginState::Weights(read_layers(&mut r, n, "snapshot weights")?)
        }
        other => bail!("unknown snapshot state tag {other}"),
    };
    let train_hash = r.u64("snapshot train blob hash")?;
    let test_hash = r.u64("snapshot test blob hash")?;
    r.finish("the snapshot body")?;
    let want = u64::from_le_bytes([
        tail[0], tail[1], tail[2], tail[3], tail[4], tail[5], tail[6], tail[7],
    ]);
    let got = fnv1a64(body);
    if got != want {
        bail!("snapshot checksum mismatch (stored {want:#018x}, computed \
               {got:#018x}) — the file is corrupt");
    }
    Ok(SnapshotBody {
        device,
        session: SessionSnapshot {
            model,
            seed,
            method,
            step,
            eval_batch,
            limit,
            state,
        },
        epochs_done,
        angle,
        train_hash,
        test_hash,
    })
}

// Decode context helper shared by the stores: name the device so a bad
// snapshot error says whose state failed.
pub(super) fn decode_body_for(device: &str, bytes: &[u8])
                              -> Result<SnapshotBody> {
    let body = decode_body(bytes)
        .with_context(|| format!("decoding the snapshot of device {device}"))?;
    if body.device != device {
        bail!(
            "snapshot stored under device {device} names device {} — \
             store layout corrupt",
            body.device
        );
    }
    Ok(body)
}
