//! `priot::store` — durable per-device session state.
//!
//! PRIOT's training state is ideal for persistence: integer scores and
//! masks plus static scale factors snapshot **bit-exactly**, so a device
//! can be evicted from memory and rehydrated later with provably lossless
//! trajectories.  This module is the persistence layer under the serving
//! stack:
//!
//! * [`SessionSnapshot`] — the exact mutable state of one
//!   [`Session`](crate::session::Session): the serializable method
//!   description, the seed, the executed-step counter, and the plugin
//!   state (i32 scores+masks for PRIOT/PRIOT-S, trained weights for
//!   NITI).  Produced by [`Session::snapshot`], consumed by
//!   [`Session::rehydrate`] — a rehydrated session produces
//!   **byte-identical** predict/evaluate/train trajectories to one that
//!   never left memory.
//! * [`DeviceSnapshot`] — a session snapshot plus everything the fleet
//!   server needs to resume the device: its datasets, lifetime epoch
//!   progress, and data provenance (drift angle) when known.
//! * [`StateStore`] — where snapshots live.  [`MemStore`] keeps encoded
//!   bytes in memory (tests, cache-only eviction); [`DiskStore`] keeps a
//!   directory per device with atomic write-rename updates, so a crashed
//!   process never leaves a half-written snapshot behind.
//! * [`codec`] — the versioned binary snapshot format ("PRST"),
//!   `serial`-style checked decoding plus an FNV-1a integrity trailer.
//!
//! Both stores persist the **encoded bytes**, so every `put`/`get` pair
//! round-trips the codec — the bit-identity guarantee is exercised on
//! every eviction, not only on restarts.
//!
//! Since snapshot version 2 the datasets live in **content-addressed
//! blobs** keyed by FNV-1a64 of their encoded bytes, separate from the
//! per-device body.  Datasets are immutable between `Register`/`Drift`
//! requests but dominate the snapshot size, so the steady-state
//! train-eval-evict churn rewrites only the small body; a blob is
//! encoded and written once per distinct dataset and shared by every
//! device carrying identical data.  `remove` drops only the body —
//! content addressing makes leftover blobs harmless — and unreferenced
//! blobs are reclaimed explicitly by [`StateStore::gc_blobs`], a
//! mark-sweep over the body headers that the fleet server runs at
//! startup and shutdown.  Startup scans read only those headers
//! ([`StateStore::get_body`]): recovering a thousand-device fleet never
//! materializes a single dataset blob.
//!
//! The serving integration lives in [`crate::session::serve`]:
//! `ServeBuilder::state_dir(..)` / `store(..)` + `resident_cap(N)` turn
//! the registry into an LRU of live sessions over a store, and a
//! restarted `priot serve --state-dir ...` resumes every device where it
//! left off.
//!
//! [`Session::snapshot`]: crate::session::Session::snapshot
//! [`Session::rehydrate`]: crate::session::Session::rehydrate

pub mod codec;

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::proto::MethodSpec;
use crate::serial::Dataset;

/// The exact mutable state of one session — everything that
/// distinguishes a mid-adaptation session from a freshly built one.
/// Scores, masks, and weights are stored as exact i32 (never narrowed to
/// int8 like the portable checkpoint files), so restore is lossless by
/// construction.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSnapshot {
    /// Backbone model name; rehydration refuses a mismatched backbone.
    pub model: String,
    /// The seed the session was built with (replays plugin `init`).
    pub seed: u32,
    /// Serializable method description (rebuilds the plugin object).
    pub method: MethodSpec,
    /// Training steps executed so far — the counter NITI's stochastic
    /// rounding consumes, so it must survive eviction exactly.
    pub step: u32,
    /// Evaluation batch width (part of the session's behavior contract).
    pub eval_batch: usize,
    /// Per-epoch / per-evaluation sample cap (0 = all).
    pub limit: usize,
    /// The method's mutable state.
    pub state: PluginState,
}

/// Method-specific mutable state, exact i32.
#[derive(Clone, Debug, PartialEq)]
pub enum PluginState {
    /// Score-state methods (PRIOT, PRIOT-S): per-layer scores and
    /// existence masks.
    Scores { scores: Vec<Vec<i32>>, masks: Vec<Vec<i32>> },
    /// Weight-state methods (NITI): the executor's trained weights.
    Weights(Vec<Vec<i32>>),
}

/// One device's complete durable state: the session snapshot plus the
/// serve-level context needed to resume it (datasets, epoch progress,
/// data provenance).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSnapshot {
    pub device: String,
    pub session: SessionSnapshot,
    /// The device's local train set at snapshot time (post-drift).
    pub train: Arc<Dataset>,
    /// The device's local test set at snapshot time (post-drift).
    pub test: Arc<Dataset>,
    /// Completed training epochs over the device's lifetime.
    pub epochs_done: u64,
    /// Drift angle of the current datasets, when the client supplied it
    /// (trace replays do) — provenance only, never interpreted.
    pub angle: Option<u32>,
}

/// Where device snapshots live.  Implementations are shared across the
/// serve worker pool (`Send + Sync`); each call is self-contained.
pub trait StateStore: Send + Sync {
    /// Persist `snap` under its device name, replacing any previous
    /// snapshot atomically (a reader never observes a torn write).
    fn put(&self, snap: &DeviceSnapshot) -> Result<()>;

    /// The current snapshot of `device`, or `None` if the store has
    /// never seen it.  A present-but-undecodable snapshot is an `Err`
    /// (corruption must be loud, not an implicit fresh start).
    fn get(&self, device: &str) -> Result<Option<DeviceSnapshot>>;

    /// Forget `device` entirely.  Removing an unknown device is a no-op.
    fn remove(&self, device: &str) -> Result<()>;

    /// Every device with a stored snapshot, sorted by name.
    fn devices(&self) -> Result<Vec<String>>;

    /// The decoded snapshot *body* of `device` — session state, epoch
    /// progress, provenance, and the content hashes of its dataset
    /// blobs — **without** materializing the datasets.  `None` if the
    /// store has never seen the device; a present-but-undecodable body
    /// is an `Err`, exactly like [`get`](Self::get).
    ///
    /// The default implementation materializes the full snapshot via
    /// `get` and re-derives the body from it — correct for any store,
    /// but it touches the blobs.  [`MemStore`] and [`DiskStore`]
    /// override it to read the body alone, so scanning a large fleet at
    /// startup costs one small read per device and zero blob IO.
    fn get_body(&self, device: &str) -> Result<Option<codec::SnapshotBody>> {
        match self.get(device)? {
            None => Ok(None),
            Some(snap) => {
                let enc = codec::encode_snapshot(&snap);
                Ok(Some(codec::decode_body(&enc.body)?))
            }
        }
    }

    /// Collect dataset blobs that no stored body references, returning
    /// the number of entries removed.  Mark-sweep: the mark phase reads
    /// every device's body *header* ([`get_body`](Self::get_body)) and
    /// aborts — collecting nothing — if any body is undecodable,
    /// because a corrupt-but-recoverable body may still reference live
    /// blobs.  Callers must quiesce writers first: a `put` racing the
    /// sweep could lose a just-written, not-yet-referenced blob.  The
    /// fleet server runs it at startup (before workers exist) and at
    /// `join()` (after the pool drains).
    ///
    /// The default implementation collects nothing — a store without a
    /// separate blob table has nothing to sweep.
    fn gc_blobs(&self) -> Result<usize> {
        Ok(0)
    }
}

// ---------------------------------------------------------------------------
// MemStore
// ---------------------------------------------------------------------------

/// In-memory [`StateStore`]: encoded snapshot bodies in a map plus a
/// content-addressed blob table.  State dies with the process — useful
/// for tests and for LRU eviction without a disk (bounding resident
/// sessions while keeping evicted state around).
#[derive(Default)]
pub struct MemStore {
    map: Mutex<HashMap<String, Vec<u8>>>,
    /// Dataset blobs by content hash; an already-present hash skips
    /// re-encoding entirely.  Swept only by explicit
    /// [`gc_blobs`](StateStore::gc_blobs) calls.
    blobs: Mutex<HashMap<u64, Vec<u8>>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn blob(&self, hash: u64, what: &str) -> Result<Vec<u8>> {
        self.blobs
            .lock()
            .expect("mem store blobs")
            .get(&hash)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!(
                "{what}: dataset blob {hash:#018x} is missing from the store"
            ))
    }
}

impl StateStore for MemStore {
    fn put(&self, snap: &DeviceSnapshot) -> Result<()> {
        let enc = codec::encode_snapshot(snap);
        {
            let mut blobs = self.blobs.lock().expect("mem store blobs");
            blobs
                .entry(enc.train_hash)
                .or_insert_with(|| codec::encode_dataset_blob(&snap.train));
            blobs
                .entry(enc.test_hash)
                .or_insert_with(|| codec::encode_dataset_blob(&snap.test));
        }
        self.map
            .lock()
            .expect("mem store map")
            .insert(snap.device.clone(), enc.body);
        Ok(())
    }

    fn get(&self, device: &str) -> Result<Option<DeviceSnapshot>> {
        let Some(body) = self.get_body(device)? else {
            return Ok(None);
        };
        let train = codec::decode_dataset_blob(
            &self.blob(body.train_hash,
                       &format!("device {device} train set"))?,
            body.train_hash,
            &format!("device {device} train set"),
        )?;
        let test = codec::decode_dataset_blob(
            &self.blob(body.test_hash, &format!("device {device} test set"))?,
            body.test_hash,
            &format!("device {device} test set"),
        )?;
        Ok(Some(body.assemble(train, test)))
    }

    fn remove(&self, device: &str) -> Result<()> {
        // Blobs stay: they are content-addressed and possibly shared.
        self.map.lock().expect("mem store map").remove(device);
        Ok(())
    }

    fn devices(&self) -> Result<Vec<String>> {
        let mut out: Vec<String> =
            self.map.lock().expect("mem store map").keys().cloned().collect();
        out.sort();
        Ok(out)
    }

    fn get_body(&self, device: &str) -> Result<Option<codec::SnapshotBody>> {
        match self.map.lock().expect("mem store map").get(device) {
            Some(bytes) => Ok(Some(codec::decode_body_for(device, bytes)?)),
            None => Ok(None),
        }
    }

    fn gc_blobs(&self) -> Result<usize> {
        // Mark — every hash any body references.  The map lock is
        // released before the blob lock is taken; `put` never holds
        // both either, so lock order cannot deadlock.
        let live = {
            let map = self.map.lock().expect("mem store map");
            let mut live = HashSet::new();
            for (device, bytes) in map.iter() {
                let body =
                    codec::decode_body_for(device, bytes).with_context(|| {
                        format!("blob GC aborted: body of device {device}")
                    })?;
                live.insert(body.train_hash);
                live.insert(body.test_hash);
            }
            live
        };
        // Sweep.
        let mut blobs = self.blobs.lock().expect("mem store blobs");
        let before = blobs.len();
        blobs.retain(|hash, _| live.contains(hash));
        Ok(before - blobs.len())
    }
}

// ---------------------------------------------------------------------------
// DiskStore
// ---------------------------------------------------------------------------

const SNAPSHOT_FILE: &str = "snapshot.bin";
const SNAPSHOT_TMP: &str = "snapshot.bin.tmp";
/// Content-addressed dataset blobs live here, one flat dir per store
/// root.  The leading dot can never collide with a device dir —
/// [`escape_device`] maps `.` to `%2E`.
const BLOBS_DIR: &str = ".blobs";

/// Uniquifies concurrent same-process blob temp files (two workers
/// persisting devices that share a dataset race on the same address).
static BLOB_TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// On-disk [`StateStore`]: one directory per device under a root, each
/// holding a `snapshot.bin` body, plus a shared `.blobs/` directory of
/// content-addressed dataset blobs (`<fnv1a64 hex>.bin`).  Updates write
/// a temp file and `rename` it into place, so a crash mid-write leaves
/// either the old snapshot or the new one — never a torn file (the
/// decode checksum would catch one anyway, but atomicity means no state
/// is *lost*).  Blobs become durable before the body that references
/// them, so a readable body always finds its datasets.
///
/// Device names are escaped into filesystem-safe directory names
/// (alphanumerics, `_`, `-` kept; every other byte becomes `%XX`), so
/// arbitrary wire names can never traverse outside the root.
pub struct DiskStore {
    root: PathBuf,
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root).with_context(|| {
            format!("creating state store root {}", root.display())
        })?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn device_dir(&self, device: &str) -> Result<PathBuf> {
        Ok(self.root.join(escape_device(device)?))
    }

    fn blob_path(&self, hash: u64) -> PathBuf {
        self.root.join(BLOBS_DIR).join(format!("{hash:016x}.bin"))
    }

    /// Make the blob at `hash` durable, encoding it only if it isn't
    /// already on disk (the common case after the first put).  Atomic
    /// via temp + rename; concurrent writers of the same address write
    /// identical bytes, so whichever rename lands last is still correct.
    fn write_blob(
        &self,
        hash: u64,
        encode: impl FnOnce() -> Vec<u8>,
    ) -> Result<()> {
        let path = self.blob_path(hash);
        if path.exists() {
            return Ok(());
        }
        let dir = self.root.join(BLOBS_DIR);
        std::fs::create_dir_all(&dir).with_context(|| {
            format!("creating blob dir {}", dir.display())
        })?;
        let seq = BLOB_TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = dir.join(format!(
            "{hash:016x}.{}.{seq}.tmp",
            std::process::id()
        ));
        let bytes = encode();
        (|| -> std::io::Result<()> {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
            drop(f);
            std::fs::rename(&tmp, &path)
        })()
        .with_context(|| {
            format!("writing dataset blob {}", path.display())
        })
    }

    fn read_blob(&self, hash: u64, what: &str) -> Result<Vec<u8>> {
        let path = self.blob_path(hash);
        std::fs::read(&path).with_context(|| {
            format!("{what}: reading dataset blob {}", path.display())
        })
    }
}

/// Escape a device name into a safe directory name (reversible).
fn escape_device(device: &str) -> Result<String> {
    if device.is_empty() {
        bail!("empty device name");
    }
    let mut out = String::with_capacity(device.len());
    for &b in device.as_bytes() {
        match b {
            b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'_' | b'-' => {
                out.push(b as char);
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    Ok(out)
}

/// Invert [`escape_device`]; `None` for names this store never wrote.
fn unescape_device(name: &str) -> Option<String> {
    let mut bytes = Vec::with_capacity(name.len());
    let mut it = name.bytes();
    while let Some(b) = it.next() {
        if b == b'%' {
            let hi = it.next()?;
            let lo = it.next()?;
            let hex = [hi, lo];
            let s = std::str::from_utf8(&hex).ok()?;
            bytes.push(u8::from_str_radix(s, 16).ok()?);
        } else {
            bytes.push(b);
        }
    }
    String::from_utf8(bytes).ok()
}

impl StateStore for DiskStore {
    fn put(&self, snap: &DeviceSnapshot) -> Result<()> {
        let dir = self.device_dir(&snap.device)?;
        std::fs::create_dir_all(&dir).with_context(|| {
            format!("creating device state dir {}", dir.display())
        })?;
        let enc = codec::encode_snapshot(snap);
        // Blobs first: a body must never reference a blob that a crash
        // could have left unwritten.
        self.write_blob(enc.train_hash,
                        || codec::encode_dataset_blob(&snap.train))?;
        self.write_blob(enc.test_hash,
                        || codec::encode_dataset_blob(&snap.test))?;
        let tmp = dir.join(SNAPSHOT_TMP);
        let path = dir.join(SNAPSHOT_FILE);
        (|| -> std::io::Result<()> {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&enc.body)?;
            // The rename is only atomic-durable if the payload hit disk
            // first.
            f.sync_all()?;
            drop(f);
            std::fs::rename(&tmp, &path)
        })()
        .with_context(|| {
            format!("writing snapshot of device {} to {}", snap.device,
                    path.display())
        })
    }

    fn get(&self, device: &str) -> Result<Option<DeviceSnapshot>> {
        let Some(body) = self.get_body(device)? else {
            return Ok(None);
        };
        let train = codec::decode_dataset_blob(
            &self.read_blob(body.train_hash,
                            &format!("device {device} train set"))?,
            body.train_hash,
            &format!("device {device} train set"),
        )?;
        let test = codec::decode_dataset_blob(
            &self.read_blob(body.test_hash,
                            &format!("device {device} test set"))?,
            body.test_hash,
            &format!("device {device} test set"),
        )?;
        Ok(Some(body.assemble(train, test)))
    }

    fn remove(&self, device: &str) -> Result<()> {
        // Blobs stay: content-addressed and possibly shared with other
        // devices (see the module docs on garbage collection).
        let dir = self.device_dir(device)?;
        match std::fs::remove_dir_all(&dir) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| {
                format!("removing device state dir {}", dir.display())
            }),
        }
    }

    fn devices(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(&self.root).with_context(|| {
            format!("listing state store root {}", self.root.display())
        })?;
        for entry in entries {
            let entry = entry?;
            if !entry.path().join(SNAPSHOT_FILE).exists() {
                continue; // not a device dir (or an interrupted write)
            }
            if let Some(device) =
                entry.file_name().to_str().and_then(unescape_device)
            {
                out.push(device);
            }
        }
        out.sort();
        Ok(out)
    }

    fn get_body(&self, device: &str) -> Result<Option<codec::SnapshotBody>> {
        let path = self.device_dir(device)?.join(SNAPSHOT_FILE);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None);
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("reading snapshot {}", path.display())
                });
            }
        };
        Ok(Some(codec::decode_body_for(device, &bytes).with_context(
            || format!("snapshot file {}", path.display()),
        )?))
    }

    fn gc_blobs(&self) -> Result<usize> {
        // Mark — the hashes every readable body references.  Reading
        // only headers keeps a thousand-device sweep cheap; an
        // undecodable body aborts the whole GC, since its blobs may
        // still be live even if the body is not currently readable.
        let mut live = HashSet::new();
        for device in self.devices()? {
            let Some(body) = self.get_body(&device).with_context(|| {
                format!("blob GC aborted: body of device {device}")
            })?
            else {
                continue; // raced a remove; nothing to mark
            };
            live.insert(body.train_hash);
            live.insert(body.test_hash);
        }
        // Sweep — unreferenced `<fnv1a64 hex>.bin` entries plus temp
        // files a crashed writer left behind (GC runs quiesced, so a
        // surviving `.tmp` can only be a leftover, never in flight).
        let dir = self.root.join(BLOBS_DIR);
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(0); // no blob dir, nothing ever written
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("listing blob dir {}", dir.display())
                });
            }
        };
        let mut collected = 0;
        for entry in entries {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let dead = match name.strip_suffix(".bin") {
                Some(stem) if stem.len() == 16 => {
                    match u64::from_str_radix(stem, 16) {
                        Ok(hash) => !live.contains(&hash),
                        Err(_) => false, // not one of ours; leave it be
                    }
                }
                Some(_) => false,
                None => name.ends_with(".tmp"),
            };
            if dead {
                std::fs::remove_file(&path).with_context(|| {
                    format!("sweeping dead blob {}", path.display())
                })?;
                collected += 1;
            }
        }
        Ok(collected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_name_escaping_roundtrips() {
        for name in ["dev-00", "a/b", "../../etc", "δevice", "d.1", "%", "a b"] {
            let escaped = escape_device(name).unwrap();
            assert!(
                escaped.bytes().all(|b| b.is_ascii_alphanumeric()
                    || b == b'_' || b == b'-' || b == b'%'),
                "{name} escaped to unsafe {escaped}"
            );
            assert_eq!(unescape_device(&escaped).as_deref(), Some(name));
        }
        assert!(escape_device("").is_err(), "empty names are rejected");
    }

    #[test]
    fn escaping_keeps_paths_inside_the_root() {
        // Path separators and dots are always escaped, so a hostile
        // device name cannot climb out of the store root.
        for name in ["..", ".", "../x", "a/../../b", "/abs"] {
            let escaped = escape_device(name).unwrap();
            assert!(!escaped.contains('/') && !escaped.contains('.'),
                    "{name} → {escaped}");
        }
    }
}
