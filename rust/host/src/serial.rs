//! Host-side view of the binary interchange formats: the in-memory types
//! and layout constants come from [`priot_core::serial`]; this shim adds
//! the file readers/writers (the core crate is `no_std` and does no IO).

pub use priot_core::serial::*;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

/// Error unless `r` is exactly at end-of-file (the formats are
/// fixed-layout: trailing bytes mean a corrupt or mismatched file).
fn expect_eof(r: &mut impl Read, path: &Path, what: &str) -> Result<()> {
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        bail!("{}: trailing bytes after {what}", path.display());
    }
    Ok(())
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

/// Load a "PRWT" weights file (list of int8 tensors).
pub fn load_weights(path: &Path) -> Result<Vec<TensorI8>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening weights file {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    let magic = read_u32(&mut r)?;
    if magic != WEIGHTS_MAGIC {
        bail!("{}: bad magic {magic:#x} (want PRWT)", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("{}: unsupported weights version {version}", path.display());
    }
    let n = read_u32(&mut r)? as usize;
    if n > 1024 {
        bail!("{}: implausible tensor count {n}", path.display());
    }
    let mut out = Vec::with_capacity(n);
    for ti in 0..n {
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 8 {
            bail!("{}: tensor {ti} has {ndim} dims", path.display());
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let size = checked_size(&dims)
            .filter(|&s| s <= 256 << 20)
            .with_context(|| {
                format!("{}: tensor {ti} has implausible dims {dims:?}",
                        path.display())
            })?;
        let mut raw = vec![0u8; size];
        r.read_exact(&mut raw).with_context(|| {
            format!("{}: tensor {ti} truncated (want {size} bytes)",
                    path.display())
        })?;
        let data: Vec<i8> = raw.into_iter().map(|b| b as i8).collect();
        out.push(TensorI8 { dims, data });
    }
    expect_eof(&mut r, path, &format!("{n} tensors"))?;
    Ok(out)
}

/// Save a "PRWT" weights file (used for on-device checkpoints: the trained
/// scores / updated weights).
pub fn save_weights(path: &Path, tensors: &[TensorI8]) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating weights file {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    write_u32(&mut w, WEIGHTS_MAGIC)?;
    write_u32(&mut w, 1)?;
    write_u32(&mut w, tensors.len() as u32)?;
    for t in tensors {
        write_u32(&mut w, t.dims.len() as u32)?;
        for &d in &t.dims {
            write_u32(&mut w, d as u32)?;
        }
        let raw: Vec<u8> = t.data.iter().map(|&v| v as u8).collect();
        w.write_all(&raw)?;
    }
    Ok(())
}

/// Load a "PRDS" dataset file.
pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening dataset {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    let magic = read_u32(&mut r)?;
    if magic != DATASET_MAGIC {
        bail!("{}: bad magic {magic:#x} (want PRDS)", path.display());
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("{}: unsupported dataset version {version}", path.display());
    }
    let n = read_u32(&mut r)? as usize;
    let c = read_u32(&mut r)? as usize;
    let h = read_u32(&mut r)? as usize;
    let w = read_u32(&mut r)? as usize;
    // NB `c * h * w` must be checked too — the header is untrusted, and an
    // unchecked product can wrap before the old `n.checked_mul(...)` ever
    // saw it.
    let total = checked_size(&[n, c, h, w])
        .filter(|&t| t <= 1 << 31)
        .with_context(|| {
            format!("{}: implausible dims n={n} c={c} h={h} w={w}",
                    path.display())
        })?;
    let mut images = vec![0u8; total];
    r.read_exact(&mut images).with_context(|| {
        format!("{}: image payload truncated (want {total} bytes)",
                path.display())
    })?;
    let mut labels = vec![0u8; n];
    r.read_exact(&mut labels).with_context(|| {
        format!("{}: label payload truncated (want {n} bytes)", path.display())
    })?;
    expect_eof(&mut r, path, "the label payload")?;
    Ok(Dataset { n, c, h, w, images, labels })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_roundtrip() {
        let dir = std::env::temp_dir().join("priot_serial_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let tensors = vec![
            TensorI8 { dims: vec![2, 3], data: vec![1, -2, 3, -4, 5, -128] },
            TensorI8 { dims: vec![4], data: vec![0, 127, -127, 7] },
        ];
        save_weights(&path, &tensors).unwrap();
        let back = load_weights(&path).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("priot_serial_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 32]).unwrap();
        assert!(load_weights(&path).is_err());
        assert!(load_dataset(&path).is_err());
    }

    /// Write raw bytes to a temp fixture and return its path.
    fn fixture(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("priot_serial_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    fn le(words: &[u32]) -> Vec<u8> {
        words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    /// A well-formed 2-sample 1×2×2 dataset header + payload.
    fn dataset_bytes() -> Vec<u8> {
        let mut b = le(&[DATASET_MAGIC, 1, 2, 1, 2, 2]);
        b.extend([10u8, 20, 30, 40, 50, 60, 70, 80]); // 2 × 4 pixels
        b.extend([1u8, 2]); // labels
        b
    }

    #[test]
    fn dataset_roundtrip_and_exact_length() {
        let path = fixture("ds_ok.bin", &dataset_bytes());
        let ds = load_dataset(&path).unwrap();
        assert_eq!((ds.n, ds.c, ds.h, ds.w), (2, 1, 2, 2));
        assert_eq!(ds.labels, vec![1, 2]);
    }

    #[test]
    fn dataset_truncated_payload_is_clean_error() {
        let mut bytes = dataset_bytes();
        bytes.truncate(bytes.len() - 5); // cut into the image payload
        let path = fixture("ds_trunc.bin", &bytes);
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err:#}");

        let mut bytes = dataset_bytes();
        bytes.truncate(bytes.len() - 1); // labels short by one
        let path = fixture("ds_trunc_labels.bin", &bytes);
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("label"), "{err:#}");
    }

    #[test]
    fn dataset_trailing_bytes_rejected() {
        let mut bytes = dataset_bytes();
        bytes.push(0xAA);
        let path = fixture("ds_trailing.bin", &bytes);
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err:#}");
    }

    #[test]
    fn dataset_overflowing_dims_are_clean_error() {
        // n·c·h·w wraps usize if multiplied unchecked — must be a clean
        // error, not a garbage tensor or an abort.
        let bytes = le(&[DATASET_MAGIC, 1, u32::MAX, u32::MAX, u32::MAX,
                         u32::MAX]);
        let path = fixture("ds_overflow.bin", &bytes);
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err:#}");
        // ...and merely-huge (non-wrapping) dims hit the same guard.
        let bytes = le(&[DATASET_MAGIC, 1, 1 << 20, 16, 64, 64]);
        let path = fixture("ds_huge.bin", &bytes);
        assert!(load_dataset(&path).is_err());
    }

    #[test]
    fn weights_truncated_tensor_is_clean_error() {
        // magic, v1, 1 tensor, ndim=2, dims 2×3, then only 4 of 6 bytes.
        let mut bytes = le(&[WEIGHTS_MAGIC, 1, 1, 2, 2, 3]);
        bytes.extend([1u8, 2, 3, 4]);
        let path = fixture("w_trunc.bin", &bytes);
        let err = load_weights(&path).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err:#}");
        assert!(err.to_string().contains("tensor 0"), "{err:#}");
    }

    #[test]
    fn weights_overflowing_dims_are_clean_error() {
        let bytes = le(&[WEIGHTS_MAGIC, 1, 1, 4, u32::MAX, u32::MAX, u32::MAX,
                         u32::MAX]);
        let path = fixture("w_overflow.bin", &bytes);
        let err = load_weights(&path).unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err:#}");
    }

    #[test]
    fn weights_trailing_bytes_rejected() {
        let mut bytes = le(&[WEIGHTS_MAGIC, 1, 1, 1, 2]);
        bytes.extend([7u8, 9, 0xFF]); // one byte too many
        let path = fixture("w_trailing.bin", &bytes);
        let err = load_weights(&path).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err:#}");
    }
}
