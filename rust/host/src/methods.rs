//! Host-side view of the training-method layer.
//!
//! The plugins themselves ([`MethodPlugin`], [`Niti`], [`Priot`],
//! [`PriotS`]) and the method descriptions ([`Method`], [`Selection`],
//! [`MethodSpec`]) are `no_std` and live in [`priot_core::methods`] —
//! re-exported here wholesale.  This shim adds the two pieces that need an
//! OS: the [`StepBackend`] executor trait (checkpoints to filesystem
//! paths) and the config→plugin bridge [`plugin_for`].

pub use priot_core::methods::*;

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::engine::StepOut;

/// One training backend: consumes (image, label) pairs, produces logits and
/// the overflow probe; owns all mutable training state (weights or scores).
pub trait StepBackend {
    /// One on-device training step (batch 1).
    fn train_step(&mut self, img: &[i32], label: usize) -> StepOut;
    /// Inference for evaluation.
    fn predict(&mut self, img: &[i32]) -> usize;
    /// Batched inference (one sample per row of `imgs`).  The default is
    /// the per-sample loop so every backend stays correct; the engine
    /// executor overrides it with the batched forward (bit-identical —
    /// asserted by `rust/cli/tests/serve.rs`).
    fn predict_batch(&mut self, imgs: &crate::tensor::Mat) -> Vec<usize> {
        let mut out = Vec::with_capacity(imgs.rows);
        for bi in 0..imgs.rows {
            out.push(self.predict(imgs.row(bi)));
        }
        out
    }
    /// Chunked training over one sample per row of `imgs` (bit-identical
    /// to the per-sample loop — the contract of
    /// [`MethodPlugin::train_chunk`]).  The default *is* that loop, so
    /// every backend stays correct; the engine executor overrides it to
    /// batch the forward passes and fall back per sample after a
    /// θ-crossing.
    fn train_chunk(&mut self, imgs: &crate::tensor::Mat, labels: &[usize])
                   -> Vec<StepOut> {
        assert_eq!(imgs.rows, labels.len(), "train_chunk: labels != rows");
        let mut outs = Vec::with_capacity(imgs.rows);
        for bi in 0..imgs.rows {
            outs.push(self.train_step(imgs.row(bi), labels[bi]));
        }
        outs
    }
    /// Current scores, if the method has them (analysis/checkpointing).
    fn scores(&self) -> Option<&[Vec<i32>]>;
    /// PRIOT-S existence masks, if any.
    fn masks(&self) -> Option<&[Vec<i32>]>;
    /// Pruning threshold θ, if the method prunes.
    fn theta(&self) -> Option<i32>;
    /// Backend label for logs.
    fn name(&self) -> &str;
    /// Persist the trained state (scores or updated weights).
    fn save_state(&self, path: &std::path::Path) -> Result<()> {
        bail!("{}: checkpointing not supported", path.display())
    }
    /// Restore state produced by [`Self::save_state`].
    fn load_state(&mut self, path: &std::path::Path) -> Result<()> {
        bail!("{}: checkpointing not supported", path.display())
    }
}

/// Build the plugin named by an [`ExperimentConfig`] (the config/CLI
/// bridge; programmatic callers construct plugins directly).
pub fn plugin_for(cfg: &ExperimentConfig) -> Result<Box<dyn MethodPlugin>> {
    Ok(match cfg.method {
        Method::StaticNiti => Box::new(Niti::static_scale()),
        Method::DynamicNiti => Box::new(Niti::dynamic()),
        Method::Priot => Box::new(Priot::new().with_theta(cfg.theta)),
        Method::PriotS => Box::new(
            PriotS::new(cfg.frac_scored, cfg.selection).with_theta(cfg.theta),
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::prng::XorShift64;
    use crate::quant::Scales;
    use crate::spec::NetSpec;
    use crate::tensor::Mat;
    use priot_core::engine::Engine;

    fn test_engine(seed: u64) -> (NetSpec, Engine) {
        let spec = NetSpec::tinycnn();
        let mut rng = XorShift64::new(seed);
        let weights: Vec<Mat> = spec
            .layers
            .iter()
            .map(|l| {
                let (r, c) = l.weight_shape();
                Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
            })
            .collect();
        let e = Engine::new(spec.clone(), weights,
                            Scales::default_for(spec.layers.len())).unwrap();
        (spec, e)
    }

    fn cfg_for(method: &str, selection: &str) -> ExperimentConfig {
        let mut c = Config::default();
        c.set("method", method);
        c.set("selection", selection);
        c.set("frac_scored", "0.1");
        ExperimentConfig::from_config(&c).unwrap()
    }

    #[test]
    fn priot_s_plugin_mask_fraction_and_theta() {
        let (spec, e) = test_engine(31);
        let cfg = cfg_for("priot-s", "random");
        let mut p = plugin_for(&cfg).unwrap();
        p.init(&spec, &e.weights, cfg.seed).unwrap();
        assert_eq!(p.theta(), Some(0));
        let masks = p.masks().unwrap();
        let total: usize = masks.iter().map(|m| m.len()).sum();
        let ones: i64 = masks.iter().flat_map(|m| m.iter()).map(|&v| v as i64).sum();
        let frac = ones as f64 / total as f64;
        assert!((0.07..0.13).contains(&frac), "frac {frac}");
    }

    #[test]
    fn plugin_for_covers_every_method() {
        for (m, want) in [("static-niti", "static-niti"),
                          ("dynamic-niti", "dynamic-niti"),
                          ("priot", "priot"),
                          ("priot-s", "priot-s")] {
            let cfg = cfg_for(m, "random");
            assert_eq!(plugin_for(&cfg).unwrap().name(), want);
        }
    }
}
