//! Raspberry Pi Pico (RP2040) cost model — the Table II substrate.
//!
//! We do not have the physical board, so both Table II columns are computed
//! analytically from the op trace / tensor inventory of one training step
//! (DESIGN.md §2 documents the substitution):
//!
//! * **Time**: the RP2040's Cortex-M0+ is in-order, single-issue, cache-less
//!   (XIP flash cache aside) with a single-cycle 32×32 multiplier, so a
//!   per-op cycle model is faithful.  We count the GEMM/elementwise ops of
//!   each phase of a step and convert at 133 MHz.
//! * **Memory**: the paper sums "the sizes of the tensors stored during
//!   training, including activations, gradients, weights, and scores"; the
//!   accountant below enumerates exactly those for each method.
//!
//! Both models are calibrated in *structure* (which terms exist) by the
//! paper's measurements; the cycle constants are standard M0+ figures.

use crate::config::{Method, Selection};
use crate::quant::Scales;
use crate::spec::{LayerSpec, NetSpec};

/// RP2040 clock (Hz).
pub const CLOCK_HZ: f64 = 133_000_000.0;

/// Cycle costs of the inner-loop primitives on Cortex-M0+ (compiled C,
/// -O2-class code): a MAC iteration = 2 byte loads (2cy each) + single-cycle
/// MUL + ADD + loop overhead (~2cy amortized with unrolling).
pub const CYCLES_PER_MAC: f64 = 8.0;
/// Elementwise int op (load, op, store, overhead).
pub const CYCLES_PER_ELEM: f64 = 6.0;
/// Software integer division (M0+ has no divider; __aeabi_idiv).
pub const CYCLES_PER_DIV: f64 = 35.0;
/// Max-pool window element (load + compare + select).
pub const CYCLES_PER_POOL: f64 = 7.0;
/// Dynamic-scale overhead per int32 accumulator element: the max-|x| scan
/// (load 2, abs 2, cmp+branch 3) plus the extra SRAM round-trip dynamic
/// scaling forces (store int32 4, reload 4) before it can requantize.
pub const CYCLES_PER_DYNSCAN: f64 = 16.0;
/// NITI weight update, per edge: load g32 (2), shift-round (3), clamp (2),
/// load w (2), sub+clamp (3), store (2) — including SR's hash add (+~10
/// amortized over the hash's 6 ALU ops on 4 lanes... conservatively 11).
pub const CYCLES_PER_WUPD: f64 = 11.0;
/// PRIOT score update, per edge: g8 requant (5), load w (2), mul (1),
/// shift+clamp (4), load s (2), sub+clamp+store (4) ≈ 18.
pub const CYCLES_PER_SUPD: f64 = 18.0;
/// PRIOT-S score update, per scored edge: as above + the (index, score)
/// table walk (load idx, address arithmetic) ≈ +4.
pub const CYCLES_PER_SUPD_SPARSE: f64 = 22.0;
/// On-the-fly mask generation per edge in forward (load s, cmp θ, select).
pub const CYCLES_PER_MASK: f64 = 3.0;

/// Byte sizes of one training step's working set.
#[derive(Clone, Debug, Default)]
pub struct MemoryFootprint {
    pub weights: usize,
    pub activations: usize,
    pub gradients: usize,
    pub scores: usize,
    /// PRIOT-S (index, score) table overhead beyond plain scores.
    pub score_index: usize,
    /// int32 accumulator that dynamic scaling must materialize.
    pub dynamic_accum: usize,
    pub misc: usize,
}

impl MemoryFootprint {
    pub fn total(&self) -> usize {
        self.weights
            + self.activations
            + self.gradients
            + self.scores
            + self.score_index
            + self.dynamic_accum
            + self.misc
    }
}

/// Estimated cycles of one training step, by phase.
#[derive(Clone, Debug, Default)]
pub struct StepCost {
    pub fwd_cycles: f64,
    pub bwd_cycles: f64,
    pub update_cycles: f64,
    pub mask_cycles: f64,
    pub dynamic_cycles: f64,
}

impl StepCost {
    pub fn total_cycles(&self) -> f64 {
        self.fwd_cycles
            + self.bwd_cycles
            + self.update_cycles
            + self.mask_cycles
            + self.dynamic_cycles
    }

    pub fn total_ms(&self) -> f64 {
        self.total_cycles() / CLOCK_HZ * 1e3
    }
}

/// Method parameters the models need.
#[derive(Clone, Copy, Debug)]
pub struct MethodParams {
    pub method: Method,
    /// PRIOT-S: fraction of edges with scores (1-p in the paper's notation).
    pub frac_scored: f64,
    pub selection: Selection,
}

impl MethodParams {
    pub fn new(method: Method) -> Self {
        Self { method, frac_scored: 1.0, selection: Selection::Random }
    }

    pub fn priot_s(frac_scored: f64, selection: Selection) -> Self {
        Self { method: Method::PriotS, frac_scored, selection }
    }
}

/// Per-layer flattened activation lengths the backward pass must retain.
fn tape_activations(spec: &NetSpec) -> usize {
    // Stored per layer: the layer *input* (int8) for the weight gradient,
    // the post-relu activation (int8, relu mask), and pool argmax indices
    // (u8 per pooled output).  The input image is the first layer's input.
    let mut bytes = 0usize;
    let mut in_len = spec.input_len();
    for l in &spec.layers {
        bytes += in_len; // layer input, int8
        match *l {
            LayerSpec::Conv { in_h, in_w, out_c, pool, .. } => {
                let pre_pool = out_c * in_h * in_w;
                bytes += pre_pool; // relu output (mask source)
                if pool {
                    bytes += pre_pool / 4; // argmax u8
                }
            }
            LayerSpec::Fc { out_f, .. } => {
                bytes += out_f;
            }
        }
        in_len = l.out_len();
    }
    bytes
}

/// Largest int32 accumulator any layer produces (dynamic scaling must hold
/// the whole tensor before it can pick a shift).
fn largest_accum_bytes(spec: &NetSpec) -> usize {
    spec.layers
        .iter()
        .map(|l| match *l {
            LayerSpec::Conv { in_h, in_w, out_c, .. } => out_c * in_h * in_w * 4,
            LayerSpec::Fc { out_f, .. } => out_f * 4,
        })
        .max()
        .unwrap_or(0)
}

/// Largest weight-gradient tile (the update is applied layer-by-layer, so
/// one reusable int8 buffer of the largest layer suffices).
fn largest_grad_bytes(spec: &NetSpec) -> usize {
    spec.layers.iter().map(|l| l.num_params()).max().unwrap_or(0)
}

/// The Table II memory column for one (model, method) pair.
pub fn memory_footprint(spec: &NetSpec, p: MethodParams) -> MemoryFootprint {
    let params = spec.num_params();
    let mut f = MemoryFootprint {
        weights: params, // int8
        activations: tape_activations(spec),
        // delta buffers: two ping-pong int8 delta tensors of the largest
        // activation + one int8 weight-gradient tile of the largest layer
        gradients: 2 * spec
            .layers
            .iter()
            .map(|l| l.in_len().max(l.out_len()))
            .max()
            .unwrap_or(0)
            + largest_grad_bytes(spec),
        ..Default::default()
    };
    match p.method {
        Method::StaticNiti => {}
        Method::DynamicNiti => {
            f.dynamic_accum = largest_accum_bytes(spec);
        }
        Method::Priot => {
            f.scores = params; // int8 score per edge; masks built on the fly
        }
        Method::PriotS => {
            let scored: usize = spec
                .layers
                .iter()
                .map(|l| (l.num_params() as f64 * p.frac_scored).round() as usize)
                .sum();
            // (u16 index within layer tile, i8 score) entries, padded u32
            f.scores = scored;
            f.score_index = scored * 2;
        }
    }
    f
}

/// The Table II time column for one (model, method) pair.
pub fn step_cost(spec: &NetSpec, scales: &Scales, p: MethodParams) -> StepCost {
    let mut c = StepCost::default();
    let mut prev_out;
    for l in &spec.layers {
        let (fout, k) = l.weight_shape();
        let n = match *l {
            LayerSpec::Conv { in_h, in_w, .. } => in_h * in_w,
            LayerSpec::Fc { .. } => 1,
        };
        let fwd_macs = (fout * k * n) as f64;
        let out_elems = (fout * n) as f64;
        prev_out = l.out_len() as f64;
        // forward GEMM + requant epilogue
        c.fwd_cycles += fwd_macs * CYCLES_PER_MAC + out_elems * CYCLES_PER_ELEM;
        if let LayerSpec::Conv { pool: true, .. } = l {
            c.fwd_cycles += out_elems * CYCLES_PER_POOL;
        }
        // backward: δx GEMM (skipped for the first layer) + δW GEMM
        // + requant of both
        let bwd_dx_macs = if l.in_len() == spec.input_len() { 0.0 } else { fwd_macs };
        c.bwd_cycles += bwd_dx_macs * CYCLES_PER_MAC
            + fwd_macs * CYCLES_PER_MAC // δW = δy·xᵀ
            + (k * n) as f64 * CYCLES_PER_ELEM
            + prev_out * CYCLES_PER_ELEM;
        let params = (fout * k) as f64;
        match p.method {
            Method::StaticNiti | Method::DynamicNiti => {
                c.update_cycles += params * CYCLES_PER_WUPD;
            }
            Method::Priot => {
                // mask generation on the fly in forward (+4.13% claim)
                c.mask_cycles += params * CYCLES_PER_MASK;
                c.update_cycles += params * CYCLES_PER_SUPD;
            }
            Method::PriotS => {
                let scored = params * p.frac_scored;
                // only scored edges mask the forward weight tile...
                c.mask_cycles += scored * CYCLES_PER_MASK;
                // ...and only scored edges compute score updates; the δW
                // MACs of unscored edges are skipped too (−12.79% claim) —
                // fully for FC layers, partially for conv (δW tiles are
                // shared across positions):
                c.update_cycles += scored * CYCLES_PER_SUPD_SPARSE;
                c.bwd_cycles -= fwd_macs * CYCLES_PER_MAC * (1.0 - p.frac_scored)
                    * gradient_sparsity_factor(l);
            }
        }
        if p.method == Method::DynamicNiti {
            // scan int32 accumulators (fwd + δx + δW) for their max
            c.dynamic_cycles +=
                (out_elems + k as f64 * n as f64 + params) * CYCLES_PER_DYNSCAN;
        }
    }
    // loss backward: exp2 shifts + one integer division per class
    c.bwd_cycles += 10.0 * (CYCLES_PER_ELEM + CYCLES_PER_DIV);
    let _ = scales;
    c
}

/// PRIOT-S only skips the δW MACs of edges without scores; for conv layers
/// the δW GEMM is shared across positions so the skip fraction is partial.
fn gradient_sparsity_factor(l: &LayerSpec) -> f64 {
    match l {
        LayerSpec::Conv { .. } => 0.35,
        LayerSpec::Fc { .. } => 1.0,
    }
}

/// SRAM budget check against the RP2040's 264 KB.
pub const PICO_SRAM_BYTES: usize = 264 * 1024;

pub fn fits_pico(f: &MemoryFootprint) -> bool {
    f.total() <= PICO_SRAM_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, Selection};

    fn tiny() -> NetSpec {
        NetSpec::tinycnn()
    }

    #[test]
    fn memory_ordering_matches_paper() {
        // paper Table II: static < PRIOT-S(90%) < PRIOT-S(80%) < PRIOT
        let s = tiny();
        let m_static = memory_footprint(&s, MethodParams::new(Method::StaticNiti));
        let m_p90 = memory_footprint(
            &s, MethodParams::priot_s(0.1, Selection::Random));
        let m_p80 = memory_footprint(
            &s, MethodParams::priot_s(0.2, Selection::Random));
        let m_priot = memory_footprint(&s, MethodParams::new(Method::Priot));
        assert!(m_static.total() < m_p90.total());
        assert!(m_p90.total() < m_p80.total());
        assert!(m_p80.total() < m_priot.total());
        // PRIOT overhead ≈ +1 byte/param over static (paper: +72%)
        let delta = m_priot.total() - m_static.total();
        assert_eq!(delta, s.num_params());
        let ratio = m_priot.total() as f64 / m_static.total() as f64;
        assert!((1.4..2.1).contains(&ratio), "PRIOT ratio {ratio}");
    }

    #[test]
    fn everything_fits_the_pico_except_dynamic_vgg() {
        let s = tiny();
        for p in [
            MethodParams::new(Method::StaticNiti),
            MethodParams::new(Method::Priot),
            MethodParams::priot_s(0.1, Selection::Random),
        ] {
            assert!(fits_pico(&memory_footprint(&s, p)), "{:?}", p.method);
        }
        // Full-width VGG11 training does NOT fit (the paper's point that
        // dynamic NITI / fp32 "cannot be executed on the Pico").
        let vgg = NetSpec::vgg11(1.0);
        let m = memory_footprint(&vgg, MethodParams::new(Method::DynamicNiti));
        assert!(!fits_pico(&m));
    }

    #[test]
    fn time_ordering_matches_paper() {
        // paper Table II: PRIOT-S < static-NITI < PRIOT (< dynamic-NITI)
        let s = tiny();
        let scales = Scales::default_for(s.layers.len());
        let t_static =
            step_cost(&s, &scales, MethodParams::new(Method::StaticNiti)).total_ms();
        let t_priot =
            step_cost(&s, &scales, MethodParams::new(Method::Priot)).total_ms();
        let t_p90 = step_cost(
            &s, &scales, MethodParams::priot_s(0.1, Selection::Random)).total_ms();
        let t_dyn =
            step_cost(&s, &scales, MethodParams::new(Method::DynamicNiti)).total_ms();
        assert!(t_p90 < t_static, "PRIOT-S {t_p90} < static {t_static}");
        assert!(t_static < t_priot, "static {t_static} < PRIOT {t_priot}");
        assert!(t_priot < t_dyn, "PRIOT {t_priot} < dynamic {t_dyn}");
        // PRIOT overhead over static should be small (paper: +4.13%)
        let ratio = t_priot / t_static;
        assert!((1.0..1.15).contains(&ratio), "PRIOT time ratio {ratio}");
        // absolute scale: tiny CNN step lands in the paper's tens-of-ms
        assert!((20.0..150.0).contains(&t_static), "static {t_static} ms");
    }
}
