//! `priot::audit::mem` — static worst-case RAM/flash planning.
//!
//! PR 6's `priot::audit` proves a config's arithmetic cannot overflow;
//! this module proves the config *fits the device* — before any session,
//! registration, or on-device state exists.  From a [`NetSpec`] +
//! [`MethodSpec`] + eval batch size it computes exact byte budgets per
//! phase and checks them against a pluggable [`DeviceProfile`] (the
//! paper's RP2040: 264 KB SRAM, 2 MB flash), rendering per-phase
//! [`FitVerdict`]s with headroom/overage in bytes.
//!
//! ## The two renderings of one geometry
//!
//! The buffer *shapes* come from the engine itself
//! ([`crate::engine::plan::BufferPlan`]), where they are pinned to the
//! real allocations by `Engine::mem_probe` equality tests.  This module
//! re-prices that geometry at **device widths** and adds **liveness**:
//!
//! * int8 (1 B) activations, tapes, weights, scores; i32 (4 B)
//!   accumulators only where the engine accumulates (`acc`, `dcols`,
//!   `dx32`); `u8` pool indices.
//! * Buffers carry `[born, dies]` intervals over the step's program
//!   points (`fwd[0]..fwd[L-1], bwd[L-1]..bwd[0]`); the reported number
//!   is the **max over points of the live-set sum** — a true peak under
//!   buffer reuse, not the sum of everything ever allocated.
//!
//! ## Device buffer policy (what the plan assumes a device build does)
//!
//! The device model is the engine's algorithm with the host's
//! convenience buffers removed — each removal is bit-compatible:
//!
//! * **No `weff` buffer**: prune masks are applied per-MAC during the
//!   GEMM instead of materializing a masked weight copy (the same
//!   assumption as the RP2040 cycle model's per-MAC mask cost).
//! * **No stored weight-gradient tensor**: `δW = δy·xᵀ` entries are
//!   consumed the moment they are produced — each edge's gradient is a
//!   dot product over the tape (exactly what the engine's PRIOT-S
//!   `sparse_grad` path computes), feeding the score/weight update
//!   per edge.  Dynamic-scale NITI needs `max|δW|` *before* requanting
//!   any entry; the device does a two-pass streaming recompute (pass 1
//!   max, pass 2 update) — extra cycles, zero bytes, identical results.
//! * **Delta/activation ping-pong**: one pair of `max_delta`-sized int8
//!   buffers serves forward activations and backward deltas (the
//!   engine's `dy_a`/`dy_b`, also reused as the layer-output hop).
//! * **Weights are counted in SRAM for every method** — conservative:
//!   NITI mutates them in place so they *must* be RAM-resident;
//!   PRIOT/PRIOT-S could leave frozen weights in XIP flash, which would
//!   only widen their reported headroom.
//! * **Eval is batch-1 by the paper's device protocol**; host-side
//!   batched evaluation (`eval_batch > 1`) is a *server* optimization.
//!   The planner still prices any batch size (the serve gate audits at
//!   batch 1; `priot audit --memory --eval-batch N` prices N).
//!
//! Method state is priced by the core accounting hook
//! [`MethodSpec::state_bytes`]: NITI 0 B, PRIOT one int8 score per
//! parameter, PRIOT-S 3 B per scored edge (int8 score + u16 index) — the
//! paper's PRIOT-vs-PRIOT-S footprint comparison, derived statically.
//!
//! Entry points: [`audit_mem_backbone`] (serve/CLI), [`audit_mem_spec`]
//! (explicit parts, no weights needed).  The runtime cross-check lives
//! in `rust/cli/tests/mem.rs`: `Engine::mem_probe` measured allocations
//! equal the plan's host rendering across methods × drift angles ×
//! batched eval.

use anyhow::{bail, Result};

use crate::engine::plan::BufferPlan;
use crate::proto::MethodSpec;
use crate::session::Backbone;
use crate::spec::NetSpec;

use super::json_str;

const ACC_BYTES: usize = 4; // i32 accumulators keep full width on device

/// A deployment target's memory budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DeviceProfile {
    pub name: String,
    pub sram_bytes: usize,
    pub flash_bytes: usize,
}

impl DeviceProfile {
    /// The paper's target: Raspberry Pi Pico (RP2040) — 264 KB SRAM,
    /// 2 MB QSPI flash.
    pub fn rp2040() -> Self {
        Self {
            name: "rp2040".into(),
            sram_bytes: 264 * 1024,
            flash_bytes: 2 * 1024 * 1024,
        }
    }

    pub fn custom(name: &str, sram_bytes: usize, flash_bytes: usize) -> Self {
        Self { name: name.into(), sram_bytes, flash_bytes }
    }

    /// Known profile registry (`priot audit --memory --device NAME`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "rp2040" | "pico" => Some(Self::rp2040()),
            _ => None,
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} ({} B SRAM / {} B flash)",
            self.name, self.sram_bytes, self.flash_bytes
        )
    }
}

/// Does a byte requirement fit a budget, and by how much?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitVerdict {
    Fits { headroom: usize },
    Exceeds { overage: usize },
}

impl FitVerdict {
    fn of(bytes: usize, budget: usize) -> Self {
        if bytes <= budget {
            FitVerdict::Fits { headroom: budget - bytes }
        } else {
            FitVerdict::Exceeds { overage: bytes - budget }
        }
    }

    pub fn fits(&self) -> bool {
        matches!(self, FitVerdict::Fits { .. })
    }

    pub fn render(&self) -> String {
        match self {
            FitVerdict::Fits { headroom } => format!("fits (+{headroom})"),
            FitVerdict::Exceeds { overage } => {
                format!("EXCEEDS (over by {overage})")
            }
        }
    }
}

/// One buffer's lifetime over the phase's program points (inclusive).
struct LiveBuf {
    label: String,
    bytes: usize,
    born: usize,
    dies: usize,
}

/// True peak over the program points: at each point sum the live
/// buffers, return `(peak_bytes, peak_point, live-set breakdown)`.
fn liveness_peak(
    bufs: &[LiveBuf],
    n_points: usize,
) -> (usize, usize, Vec<(String, usize)>) {
    let mut peak = (0usize, 0usize);
    for p in 0..n_points {
        let total: usize = bufs
            .iter()
            .filter(|b| b.born <= p && p <= b.dies)
            .map(|b| b.bytes)
            .sum();
        if total > peak.0 {
            peak = (total, p);
        }
    }
    let breakdown = bufs
        .iter()
        .filter(|b| b.born <= peak.1 && peak.1 <= b.dies && b.bytes > 0)
        .map(|b| (b.label.clone(), b.bytes))
        .collect();
    (peak.0, peak.1, breakdown)
}

/// One phase's budget: resident state + transient peak, with a verdict
/// against the device's SRAM.
#[derive(Clone, Debug)]
pub struct PhaseBudget {
    /// `load`, `train-step`, or `eval-batch(B)`.
    pub phase: String,
    /// Always-resident bytes (weights + scales + method state).
    pub resident_bytes: usize,
    /// Worst-point transient bytes (tapes, arenas, accumulators).
    pub transient_bytes: usize,
    /// `resident + transient` — the number checked against SRAM.
    pub bytes: usize,
    /// Program point of the transient peak (`resident` for load).
    pub peak_at: String,
    /// Live transient buffers at the peak, largest first.
    pub breakdown: Vec<(String, usize)>,
    pub verdict: FitVerdict,
}

/// The full static memory report for one (model, method, device).
#[derive(Clone, Debug)]
pub struct MemReport {
    pub model: String,
    pub method: String,
    pub device: DeviceProfile,
    pub params: usize,
    /// Scored (trainable) edges the method materializes.
    pub scored: usize,
    /// Method state bytes (scores + sparse indices).
    pub state_bytes: usize,
    /// Device scale table: 4 per-layer shifts + 2 global, 1 B each.
    pub scale_bytes: usize,
    /// Frozen image in flash: weights + scale table.
    pub flash_bytes: usize,
    pub flash_verdict: FitVerdict,
    pub phases: Vec<PhaseBudget>,
}

impl MemReport {
    /// Override the method label (roster entries like
    /// `priot-s-90-weight` are more specific than the method name).
    pub fn with_label(mut self, label: &str) -> Self {
        self.method = label.to_string();
        self
    }

    /// Every phase fits SRAM and the frozen image fits flash.
    pub fn fits(&self) -> bool {
        self.flash_verdict.fits() && self.phases.iter().all(|p| p.verdict.fits())
    }

    /// One-line outcome (serve-gate rejection messages, CLI summary).
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        if !self.flash_verdict.fits() {
            parts.push(format!(
                "flash {} B {}",
                self.flash_bytes,
                self.flash_verdict.render()
            ));
        }
        for p in &self.phases {
            if !p.verdict.fits() {
                parts.push(format!(
                    "{} {} B {}",
                    p.phase,
                    p.bytes,
                    p.verdict.render()
                ));
            }
        }
        if parts.is_empty() {
            let worst = self
                .phases
                .iter()
                .max_by_key(|p| p.bytes)
                .map(|p| format!("peak {} B at {}", p.bytes, p.phase))
                .unwrap_or_else(|| "no phases".into());
            format!("fits {} — {worst}", self.device.summary())
        } else {
            format!("exceeds {}: {}", self.device.summary(), parts.join("; "))
        }
    }

    /// Markdown rendering (the `priot audit --memory` table).
    pub fn render_table(&self) -> String {
        let mut s = format!(
            "## {} / {} @ {} — {}\n\n",
            self.model,
            self.method,
            self.device.summary(),
            if self.fits() { "FITS" } else { "EXCEEDS" }
        );
        s.push_str(&format!(
            "weights {} B · scales {} B · method state {} B \
             ({}/{} edges scored)\n",
            self.params, self.scale_bytes, self.state_bytes, self.scored,
            self.params
        ));
        s.push_str(&format!(
            "flash (weights + scales): {} B — {}\n\n",
            self.flash_bytes,
            self.flash_verdict.render()
        ));
        s.push_str("| phase | peak SRAM [B] | peak at | verdict |\n");
        s.push_str("|---|---|---|---|\n");
        for p in &self.phases {
            s.push_str(&format!(
                "| {} | {} | {} | {} |\n",
                p.phase, p.bytes, p.peak_at,
                p.verdict.render()
            ));
        }
        for p in &self.phases {
            if p.breakdown.is_empty() {
                continue;
            }
            let parts: Vec<String> = p
                .breakdown
                .iter()
                .map(|(l, b)| format!("{l} {b}"))
                .collect();
            s.push_str(&format!(
                "\n{} peak at {}: {} = {} transient + {} resident\n",
                p.phase,
                p.peak_at,
                parts.join(" + "),
                p.transient_bytes,
                p.resident_bytes
            ));
        }
        s
    }

    /// JSON rendering (stable keys; `priot audit --memory --json`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"model\": {},\n", json_str(&self.model)));
        s.push_str(&format!("  \"method\": {},\n", json_str(&self.method)));
        s.push_str(&format!("  \"device\": {},\n",
                            json_str(&self.device.name)));
        s.push_str(&format!("  \"sram_bytes\": {},\n",
                            self.device.sram_bytes));
        s.push_str(&format!("  \"flash_limit_bytes\": {},\n",
                            self.device.flash_bytes));
        s.push_str(&format!("  \"params\": {},\n", self.params));
        s.push_str(&format!("  \"scored\": {},\n", self.scored));
        s.push_str(&format!("  \"state_bytes\": {},\n", self.state_bytes));
        s.push_str(&format!("  \"scale_bytes\": {},\n", self.scale_bytes));
        s.push_str(&format!("  \"flash_bytes\": {},\n", self.flash_bytes));
        s.push_str(&format!("  \"flash_fits\": {},\n",
                            self.flash_verdict.fits()));
        s.push_str(&format!("  \"fits\": {},\n", self.fits()));
        s.push_str("  \"phases\": [\n");
        for (i, p) in self.phases.iter().enumerate() {
            let margin: i64 = match p.verdict {
                FitVerdict::Fits { headroom } => headroom as i64,
                FitVerdict::Exceeds { overage } => -(overage as i64),
            };
            s.push_str(&format!(
                "    {{ \"phase\": {}, \"bytes\": {}, \"resident\": {}, \
                 \"transient\": {}, \"peak_at\": {}, \"fits\": {}, \
                 \"margin_bytes\": {} }}{}\n",
                json_str(&p.phase),
                p.bytes,
                p.resident_bytes,
                p.transient_bytes,
                json_str(&p.peak_at),
                p.verdict.fits(),
                margin,
                if i + 1 == self.phases.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Transient liveness for one training step (batch-1, device widths).
/// Program points: `fwd[0..L)`, then `bwd[L-1..=0]` — update is fused
/// into each layer's backward (bit-compatible: layer `i-1`'s backward
/// reads `w[i-1]`, untouched by layer `i`'s update).
fn train_step_peak(plan: &BufferPlan) -> (usize, String, Vec<(String, usize)>) {
    let nl = plan.layers.len();
    let n_points = 2 * nl;
    let bwd = |li: usize| n_points - 1 - li;
    let mut bufs = Vec::new();
    for l in &plan.layers {
        let i = l.index;
        // Tape: im2col patches / fc input, kept until this layer's
        // backward computes δW from them.
        bufs.push(LiveBuf {
            label: format!("cols[{i}]"),
            bytes: l.k * l.n,
            born: i,
            dies: bwd(i),
        });
        if l.relu {
            // Kept for the backward ReLU mask.
            bufs.push(LiveBuf {
                label: format!("relu[{i}]"),
                bytes: l.pre_pool,
                born: i,
                dies: bwd(i),
            });
        } else if l.pooled {
            // Pre-pool staging only (no ReLU mask needed in backward).
            bufs.push(LiveBuf {
                label: format!("stage[{i}]"),
                bytes: l.pre_pool,
                born: i,
                dies: i,
            });
        }
        if l.pooled {
            bufs.push(LiveBuf {
                label: format!("pool_idx[{i}]"),
                bytes: l.pre_pool / 4,
                born: i,
                dies: bwd(i),
            });
        }
    }
    // The shared activation/delta ping-pong pair (int8), alive all step.
    bufs.push(LiveBuf {
        label: "ping-pong".into(),
        bytes: 2 * plan.max_delta,
        born: 0,
        dies: n_points - 1,
    });
    // Forward i32 accumulator arena, sized for the largest layer.
    bufs.push(LiveBuf {
        label: "acc32".into(),
        bytes: plan.max_pre * ACC_BYTES,
        born: 0,
        dies: nl.saturating_sub(1),
    });
    // Conv backward scratch: δcols (i32) for col2im, only needed at the
    // backward points of conv layers that propagate δx (index > 0).
    let dconv: Vec<&crate::engine::plan::LayerPlan> = plan
        .layers
        .iter()
        .filter(|l| l.conv && l.index > 0)
        .collect();
    if let Some(max_kn) = dconv.iter().map(|l| l.k * l.n).max() {
        let first = dconv.iter().map(|l| bwd(l.index)).min().unwrap();
        let last = dconv.iter().map(|l| bwd(l.index)).max().unwrap();
        bufs.push(LiveBuf {
            label: "dcols32".into(),
            bytes: max_kn * ACC_BYTES,
            born: first,
            dies: last,
        });
    }
    // δx i32 accumulator arena, needed while any layer above 0 runs
    // backward.
    if let Some(max_in) =
        plan.layers.iter().filter(|l| l.index > 0).map(|l| l.in_len).max()
    {
        bufs.push(LiveBuf {
            label: "dx32".into(),
            bytes: max_in * ACC_BYTES,
            born: nl, // bwd[L-1]
            dies: n_points.saturating_sub(2), // bwd[1]
        });
    }
    let (peak, point, mut breakdown) = liveness_peak(&bufs, n_points);
    breakdown.sort_by_key(|(_, b)| core::cmp::Reverse(*b));
    let at = if point < nl {
        format!("fwd[{point}]")
    } else {
        format!("bwd[{}]", n_points - 1 - point)
    };
    (peak, at, breakdown)
}

/// Transient liveness for one batched evaluation forward (device
/// widths).  The geometry is the engine's `BatchBufs`, rendered at int8
/// activation width; per-layer buffers are live only at their own layer
/// (inference records no tape).
fn eval_peak(plan: &BufferPlan, b: usize)
             -> (usize, String, Vec<(String, usize)>) {
    let nl = plan.layers.len();
    let mut bufs = Vec::new();
    for l in &plan.layers {
        let i = l.index;
        bufs.push(LiveBuf {
            label: format!("cols[{i}]"),
            bytes: l.k * l.n * b,
            born: i,
            dies: i,
        });
        bufs.push(LiveBuf {
            label: format!("acc32[{i}]"),
            bytes: l.f * l.n * b * ACC_BYTES,
            born: i,
            dies: i,
        });
        bufs.push(LiveBuf {
            label: format!("relu[{i}]"),
            bytes: l.f * l.n * b,
            born: i,
            dies: i,
        });
        if l.conv {
            bufs.push(LiveBuf {
                label: format!("im2col[{i}]"),
                bytes: l.k * l.n,
                born: i,
                dies: i,
            });
        }
    }
    bufs.push(LiveBuf {
        label: "x ping-pong".into(),
        bytes: 2 * b * plan.batch_unit,
        born: 0,
        dies: nl.saturating_sub(1),
    });
    bufs.push(LiveBuf {
        label: "gather".into(),
        bytes: plan.max_pre,
        born: 0,
        dies: nl.saturating_sub(1),
    });
    bufs.push(LiveBuf {
        label: "pool_idx".into(),
        bytes: plan.max_pre / 4,
        born: 0,
        dies: nl.saturating_sub(1),
    });
    let (peak, point, mut breakdown) = liveness_peak(&bufs, nl);
    breakdown.sort_by_key(|(_, b)| core::cmp::Reverse(*b));
    (peak, format!("fwd[{point}]"), breakdown)
}

/// Audit a deployed [`Backbone`] — the serve-gate / CLI entry point.
/// `masks` are the concrete PRIOT-S existence masks when a session
/// exists (exact scored counts); `None` prices the nominal selection.
/// `eval_batch` sizes the batched-eval phase (0 = no eval phase; the
/// device protocol is batch-1, so gates audit with `eval_batch = 1`).
pub fn audit_mem_backbone(
    bb: &Backbone,
    method: &MethodSpec,
    masks: Option<&[Vec<i32>]>,
    eval_batch: usize,
    device: &DeviceProfile,
) -> Result<MemReport> {
    audit_mem_spec(&bb.model, &bb.spec, method, masks, eval_batch, device)
}

/// [`audit_mem_backbone`] from a spec alone — no weights needed (the
/// plan is pure geometry), so hypothetical models can be priced without
/// materializing them.
pub fn audit_mem_spec(
    model: &str,
    spec: &NetSpec,
    method: &MethodSpec,
    masks: Option<&[Vec<i32>]>,
    eval_batch: usize,
    device: &DeviceProfile,
) -> Result<MemReport> {
    if let Some(m) = masks {
        if m.len() != spec.layers.len() {
            bail!(
                "memory audit: {} mask layers for {} layers",
                m.len(),
                spec.layers.len()
            );
        }
    }
    let plan = BufferPlan::of(spec);
    let params = spec.num_params();
    let scored = method.scored_params(spec, masks);
    let state_bytes = method.state_bytes(spec, masks);
    // Device scale table: fwd/bwd/grad/score shifts per layer + the two
    // global lr shifts, one byte each.
    let scale_bytes = 4 * spec.layers.len() + 2;
    let resident = params + scale_bytes + state_bytes;
    let flash_bytes = params + scale_bytes;

    let mut phases = Vec::new();
    phases.push(PhaseBudget {
        phase: "load".into(),
        resident_bytes: resident,
        transient_bytes: 0,
        bytes: resident,
        peak_at: "resident".into(),
        breakdown: Vec::new(),
        verdict: FitVerdict::of(resident, device.sram_bytes),
    });
    let (train_peak, train_at, train_bd) = train_step_peak(&plan);
    phases.push(PhaseBudget {
        phase: "train-step".into(),
        resident_bytes: resident,
        transient_bytes: train_peak,
        bytes: resident + train_peak,
        peak_at: train_at,
        breakdown: train_bd,
        verdict: FitVerdict::of(resident + train_peak, device.sram_bytes),
    });
    if eval_batch > 0 {
        let (eval_pk, eval_at, eval_bd) = eval_peak(&plan, eval_batch);
        phases.push(PhaseBudget {
            phase: format!("eval-batch({eval_batch})"),
            resident_bytes: resident,
            transient_bytes: eval_pk,
            bytes: resident + eval_pk,
            peak_at: eval_at,
            breakdown: eval_bd,
            verdict: FitVerdict::of(resident + eval_pk, device.sram_bytes),
        });
    }
    Ok(MemReport {
        model: model.to_string(),
        method: method.method.name().to_string(),
        device: device.clone(),
        params,
        scored,
        state_bytes,
        scale_bytes,
        flash_bytes,
        flash_verdict: FitVerdict::of(flash_bytes, device.flash_bytes),
        phases,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Selection;

    fn tinycnn_report(method: &MethodSpec, eval_batch: usize) -> MemReport {
        audit_mem_spec(
            "tinycnn",
            &NetSpec::tinycnn(),
            method,
            None,
            eval_batch,
            &DeviceProfile::rp2040(),
        )
        .unwrap()
    }

    #[test]
    fn tinycnn_pinned_budgets() {
        // Hand-computed totals for the device rendering of the tinycnn
        // geometry (see the module docs for the policies).  Pinned so a
        // silent model/engine change must update the plan and these
        // numbers together.
        let niti = tinycnn_report(&MethodSpec::niti_static(), 1);
        assert_eq!(niti.params, 52_040);
        assert_eq!(niti.scale_bytes, 18);
        assert_eq!(niti.state_bytes, 0);
        assert_eq!(niti.phases[0].bytes, 52_058); // load
        assert_eq!(niti.phases[1].bytes, 160_250); // train-step
        assert_eq!(niti.phases[1].transient_bytes, 108_192);
        assert_eq!(niti.phases[1].peak_at, "bwd[1]");
        assert_eq!(niti.phases[2].bytes, 108_506); // eval-batch(1)
        assert!(niti.fits(), "{}", niti.summary());

        let priot = tinycnn_report(&MethodSpec::priot(), 1);
        assert_eq!(priot.state_bytes, 52_040);
        assert_eq!(priot.phases[1].bytes, 212_290);
        assert!(priot.fits(), "{}", priot.summary());

        let ps90 = tinycnn_report(
            &MethodSpec::priot_s(0.1, Selection::WeightBased), 1);
        assert_eq!(ps90.scored, 5_204);
        assert_eq!(ps90.state_bytes, 15_612);
        assert_eq!(ps90.phases[1].bytes, 175_862);

        let ps80 = tinycnn_report(
            &MethodSpec::priot_s(0.2, Selection::WeightBased), 1);
        assert_eq!(ps80.scored, 10_407);
        assert_eq!(ps80.phases[1].bytes, 191_471);

        // The paper's Table II story, statically: PRIOT-S strictly
        // below PRIOT at both sparsities.
        assert!(ps90.phases[1].bytes < priot.phases[1].bytes);
        assert!(ps80.phases[1].bytes < priot.phases[1].bytes);
    }

    #[test]
    fn oversized_configs_exceed() {
        // Host-side batched eval has no device counterpart: batch 8
        // alone blows the RP2040 budget (which is why gates audit at
        // the device protocol's batch 1).
        let b8 = tinycnn_report(&MethodSpec::priot(), 8);
        assert!(!b8.phases[2].verdict.fits(), "{}", b8.summary());
        assert!(b8.phases[1].verdict.fits(), "train still fits");

        // A VGG-class model exceeds both SRAM and the 2 MB flash.
        let vgg = audit_mem_spec(
            "vgg11w1",
            &NetSpec::vgg11(1.0),
            &MethodSpec::priot(),
            None,
            1,
            &DeviceProfile::rp2040(),
        )
        .unwrap();
        assert_eq!(vgg.params, 9_747_136);
        assert!(!vgg.flash_verdict.fits());
        assert!(!vgg.phases[0].verdict.fits(), "load alone exceeds");
        assert!(!vgg.fits());
    }

    #[test]
    fn render_and_json_shapes() {
        let r = tinycnn_report(&MethodSpec::priot(), 1);
        let table = r.render_table();
        assert!(table.starts_with("## tinycnn / priot @ rp2040"), "{table}");
        assert!(table.contains("FITS"), "{table}");
        assert!(table.contains("| phase | peak SRAM [B] | peak at | verdict |"),
                "{table}");
        assert!(table.contains("fits (+"), "{table}");
        let json = r.to_json();
        for key in [
            "\"model\"", "\"method\"", "\"device\"", "\"sram_bytes\"",
            "\"params\"", "\"scored\"", "\"state_bytes\"", "\"flash_bytes\"",
            "\"fits\"", "\"phases\"", "\"peak_at\"", "\"margin_bytes\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.contains("\"fits\": true"), "{json}");
    }
}
