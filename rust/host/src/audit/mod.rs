//! `priot::audit` — static quantization-soundness analysis.
//!
//! PRIOT trains with **static** scale shifts, which makes silent i32
//! accumulator overflow and requant saturation the failure mode the paper's
//! Fig. 2 can only *observe* at runtime.  This module proves the absence of
//! that failure mode ahead of time: an interval-analysis pass over the
//! quantized network that propagates accumulator bounds from the int8 input
//! range through every conv/FC GEMM (i8×i8→i32), requant shift, ReLU, and
//! pooling stage, and emits a per-layer [`Verdict`]:
//!
//! * [`Verdict::Proven`] — the *worst-case envelope* `K·127·127` (any int8
//!   weights, any int8 inputs) plus the rounding bias fits in i32: the layer
//!   can never overflow no matter how training perturbs it.  Reported with
//!   the number of spare doublings (`headroom_bits`).
//! * [`Verdict::Headroom`] — the envelope does not fit, but the
//!   **weight-exact** bound does.  Because the backbone is frozen, the
//!   per-row reachable sum `Σ|w_ij|·|x|` is computable exactly; the verdict
//!   carries how many doublings of that bound remain before overflow.
//! * [`Verdict::Overflowable`] — even the weight-exact bound can exceed
//!   i32; `margin_bits` says how many bits the layer is short.
//!
//! ## Soundness of the bounds
//!
//! Two bound families are tracked per layer:
//!
//! * the **final-accumulator interval** `[Σ eᵢ.lo, Σ eᵢ.hi]` over per-edge
//!   contribution intervals `eᵢ` — exact for the completed dot product and
//!   the input to the requant/saturation analysis;
//! * the **any-prefix reach bounds** `[Σ min(eᵢ.lo,0), Σ max(eᵢ.hi,0)]`,
//!   which bound every *partial* sum in every accumulation order (each
//!   prefix only ever adds a subset of the negative / positive mass).  The
//!   overflow proof uses these, so it holds for the scalar engine, the
//!   batched engine, SIMD re-associations, and any future kernel order.
//!
//! The analysis is **method-aware** via [`WeightModel`]:
//!
//! * `Frozen` — the deployed backbone as-is (the paper's "before" row).
//! * `Pruned` — PRIOT / PRIOT-S: a scored edge may be dropped at any step,
//!   so its contribution interval is widened to include 0 (dropping an edge
//!   can *increase* `|Σ|` when edges cancel — pruning is not monotone, and
//!   the model covers every reachable mask pattern).  With the concrete
//!   PRIOT-S existence masks, unscored edges keep their exact frozen
//!   contribution, tightening the bound.
//! * `WeightDrift` — NITI: weights are re-clamped to `[-127,127]` every
//!   update, so each edge ranges over the full reachable weight envelope.
//!
//! Every requant shift additionally gets a **saturation analysis** (the
//! post-shift interval vs the int8 clamp) and a validity check: a shift
//! `> 31` would overflow the `1 << (s-1)` rounding bias inside
//! [`crate::quant::rshift_round`] itself and is reported as a report-level
//! issue — this is how a hostile or corrupt scale table is rejected at
//! `Register` time (`ServeBuilder::audit(AuditPolicy::Reject)`).
//!
//! Entry points: [`audit_backbone`] (the serving/CLI path — maps a
//! [`MethodSpec`] to its weight model), [`audit_net`] for explicit parts,
//! and [`audit_spec`] for full control including the input interval.  The
//! runtime cross-check lives in [`crate::engine::AccProbe`] — observed
//! per-layer accumulator extremes, asserted against these bounds by
//! `rust/cli/tests/audit.rs`.

pub mod mem;

use anyhow::{bail, Result};

use crate::config::Method;
use crate::proto::MethodSpec;
use crate::quant::Scales;
use crate::session::Backbone;
use crate::spec::{LayerSpec, NetSpec};
use crate::tensor::Mat;

/// Inclusive integer interval, carried in i64 so no bound computation can
/// itself overflow (|values| ≤ 2^31·K with K ≤ 2^20 in any real spec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub lo: i64,
    pub hi: i64,
}

impl Interval {
    pub fn new(lo: i64, hi: i64) -> Self {
        debug_assert!(lo <= hi, "interval [{lo}, {hi}] is empty");
        Self { lo, hi }
    }

    /// Interval spanned by two endpoint products (order-free).
    fn of(a: i64, b: i64) -> Self {
        Self { lo: a.min(b), hi: a.max(b) }
    }

    /// Widen to include 0 (pruned edges, zero-padding pixels).
    fn with_zero(self) -> Self {
        Self { lo: self.lo.min(0), hi: self.hi.max(0) }
    }

    /// Largest absolute value in the interval.
    fn abs_bound(self) -> i64 {
        self.hi.max(-self.lo)
    }

    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }
}

/// The device pixel mapping (`u8 >> 1`, see `serial::u8_to_i32_pixels`)
/// puts every first-layer activation in `[0, 127]`.
pub const PIXEL_INPUT: Interval = Interval { lo: 0, hi: 127 };

/// How the analysis models the weights a layer can hold at runtime.
#[derive(Clone, Copy, Debug)]
pub enum WeightModel<'a> {
    /// The deployed backbone exactly as stored (no adaptation).
    Frozen,
    /// PRIOT / PRIOT-S: weights frozen, but any scored edge may be pruned
    /// at any step.  `masks` are the PRIOT-S existence masks (non-zero =
    /// scored/prunable); `None` treats every edge as prunable — sound for
    /// plain PRIOT and for any PRIOT-S seed.
    Pruned { masks: Option<&'a [Vec<i32>]> },
    /// NITI: weights update every step (re-clamped to int8), so every edge
    /// ranges over the full reachable weight envelope `[-127, 127]`.
    WeightDrift,
}

/// The weight model matching a serializable method description.
pub fn model_for_method(method: Method, masks: Option<&[Vec<i32>]>) -> WeightModel<'_> {
    match method {
        Method::StaticNiti | Method::DynamicNiti => WeightModel::WeightDrift,
        Method::Priot | Method::PriotS => WeightModel::Pruned { masks },
    }
}

impl WeightModel<'_> {
    pub fn name(&self) -> &'static str {
        match self {
            WeightModel::Frozen => "frozen",
            WeightModel::Pruned { masks: Some(_) } => "pruned (exact masks)",
            WeightModel::Pruned { masks: None } => "pruned (any mask)",
            WeightModel::WeightDrift => "weight-drift",
        }
    }
}

/// Per-layer soundness verdict.  `Proven`/`Headroom` are sound layers;
/// `Overflowable` fails the audit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// The any-weights envelope `K·127·127` plus rounding bias fits i32:
    /// overflow is impossible for *any* int8 weights.  `headroom_bits` =
    /// spare doublings before it would stop fitting.
    Proven { headroom_bits: u32 },
    /// The envelope does not fit, but the weight-exact reach bound does;
    /// `bits` = spare doublings of the exact bound.
    Headroom { bits: u32 },
    /// Even the weight-exact bound can exceed i32; the layer is
    /// `margin_bits` halvings away from provable.
    Overflowable { margin_bits: u32 },
}

impl Verdict {
    pub fn is_sound(&self) -> bool {
        !matches!(self, Verdict::Overflowable { .. })
    }

    fn render(&self) -> String {
        match *self {
            Verdict::Proven { headroom_bits } => {
                format!("proven (+{headroom_bits} bits)")
            }
            Verdict::Headroom { bits } => {
                format!("headroom {bits} bits (weight-exact only)")
            }
            Verdict::Overflowable { margin_bits } => {
                format!("OVERFLOWABLE (short {margin_bits} bits)")
            }
        }
    }

    fn json_tag(&self) -> (&'static str, u32) {
        match *self {
            Verdict::Proven { headroom_bits } => ("proven", headroom_bits),
            Verdict::Headroom { bits } => ("headroom", bits),
            Verdict::Overflowable { margin_bits } => ("overflowable", margin_bits),
        }
    }
}

/// Everything the analysis derived about one layer.
#[derive(Clone, Debug)]
pub struct LayerAudit {
    pub index: usize,
    /// "conv" or "fc".
    pub kind: &'static str,
    /// GEMM output rows (out channels / out features).
    pub rows: usize,
    /// Dot-product length (per-row MAC count).
    pub k: usize,
    /// The static forward requant shift applied to this accumulator.
    pub shift: u32,
    /// Per-element input interval fed to this layer's GEMM.
    pub input: Interval,
    /// Final-accumulator interval over all rows.
    pub acc: Interval,
    /// Any-prefix partial-sum bounds over all rows and accumulation orders.
    pub reach: Interval,
    /// The any-weights envelope `K·127·127`.
    pub worst_case: i64,
    pub verdict: Verdict,
    /// Post-shift, pre-clamp output interval.
    pub y: Interval,
    /// Whether the requant clamp can actually engage (|y| > 127 reachable).
    pub saturates: bool,
    /// Post-clamp/ReLU interval — the next layer's input.
    pub out: Interval,
}

/// The full audit of one (model, method) pair.
#[derive(Clone, Debug)]
pub struct AuditReport {
    pub model: String,
    /// Human-readable method / weight-model label.
    pub method: String,
    pub layers: Vec<LayerAudit>,
    /// Report-level problems (invalid shifts, …).  Any entry makes the
    /// report unsound even if every layer verdict is.
    pub issues: Vec<String>,
}

impl AuditReport {
    /// Statically sound: no overflowable layer and no report-level issue.
    pub fn sound(&self) -> bool {
        self.issues.is_empty() && self.layers.iter().all(|l| l.verdict.is_sound())
    }

    /// One-line summary ("4/4 layers proven" / first failure).
    pub fn summary(&self) -> String {
        if let Some(issue) = self.issues.first() {
            return issue.clone();
        }
        if let Some(l) = self.layers.iter().find(|l| !l.verdict.is_sound()) {
            return format!("layer {} ({}) is {}", l.index, l.kind, l.verdict.render());
        }
        let proven = self
            .layers
            .iter()
            .filter(|l| matches!(l.verdict, Verdict::Proven { .. }))
            .count();
        format!("{}/{} layers proven, rest bounded", proven, self.layers.len())
    }

    /// Render the per-layer markdown table (the `priot audit` output).
    pub fn render_table(&self) -> String {
        let mut out = format!(
            "## {} / {}  —  {}\n\n\
             | layer | kind | FxK | shift | final acc | any-prefix | \
             worst-case | verdict | y range | sat |\n\
             |---|---|---|---|---|---|---|---|---|---|\n",
            self.model,
            self.method,
            if self.sound() { "SOUND" } else { "UNSOUND" }
        );
        for l in &self.layers {
            out.push_str(&format!(
                "| {} | {} | {}x{} | {} | [{}, {}] | [{}, {}] | {} | {} | \
                 [{}, {}] | {} |\n",
                l.index,
                l.kind,
                l.rows,
                l.k,
                l.shift,
                l.acc.lo,
                l.acc.hi,
                l.reach.lo,
                l.reach.hi,
                l.worst_case,
                l.verdict.render(),
                l.y.lo,
                l.y.hi,
                if l.saturates { "yes" } else { "no" },
            ));
        }
        for issue in &self.issues {
            out.push_str(&format!("\nISSUE: {issue}\n"));
        }
        out
    }

    /// Machine-readable JSON (hand-rolled; the schema is pinned by the
    /// golden test in `rust/cli/tests/audit.rs`).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"model\": {},\n", json_str(&self.model)));
        s.push_str(&format!("  \"method\": {},\n", json_str(&self.method)));
        s.push_str(&format!("  \"sound\": {},\n", self.sound()));
        s.push_str("  \"issues\": [");
        for (i, issue) in self.issues.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&json_str(issue));
        }
        s.push_str("],\n  \"layers\": [\n");
        for (i, l) in self.layers.iter().enumerate() {
            let (tag, bits) = l.verdict.json_tag();
            s.push_str(&format!(
                "    {{\"index\": {}, \"kind\": \"{}\", \"rows\": {}, \
                 \"k\": {}, \"shift\": {}, \"acc_min\": {}, \"acc_max\": {}, \
                 \"reach_min\": {}, \"reach_max\": {}, \"worst_case\": {}, \
                 \"verdict\": \"{}\", \"bits\": {}, \"y_min\": {}, \
                 \"y_max\": {}, \"saturates\": {}, \"out_min\": {}, \
                 \"out_max\": {}}}{}\n",
                l.index,
                l.kind,
                l.rows,
                l.k,
                l.shift,
                l.acc.lo,
                l.acc.hi,
                l.reach.lo,
                l.reach.hi,
                l.worst_case,
                tag,
                bits,
                l.y.lo,
                l.y.hi,
                l.saturates,
                l.out.lo,
                l.out.hi,
                if i + 1 == self.layers.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

const I32_MAX: i64 = i32::MAX as i64;
const W_MAX: i64 = 127;

/// Audit a deployed [`Backbone`] under a serializable method description —
/// the `priot audit` CLI / serve-`Register` entry point.  `masks` are the
/// concrete PRIOT-S existence masks when available (a registered session's
/// `Session::masks()`); `None` audits the method's whole reachable family.
pub fn audit_backbone(
    bb: &Backbone,
    method: &MethodSpec,
    masks: Option<&[Vec<i32>]>,
) -> Result<AuditReport> {
    audit_net(&bb.model, &bb.spec, &bb.weights, &bb.scales, method, masks)
}

/// [`audit_backbone`] over explicit parts.
pub fn audit_net(
    model: &str,
    spec: &NetSpec,
    weights: &[Mat],
    scales: &Scales,
    method: &MethodSpec,
    masks: Option<&[Vec<i32>]>,
) -> Result<AuditReport> {
    let wm = model_for_method(method.method, masks);
    let label = format!("{} [{}]", method.method.name(), wm.name());
    let mut report = audit_spec(model, spec, weights, scales, wm, PIXEL_INPUT)?;
    report.method = label;
    Ok(report)
}

/// The core analysis: full control over weight model and input interval.
pub fn audit_spec(
    model: &str,
    spec: &NetSpec,
    weights: &[Mat],
    scales: &Scales,
    wm: WeightModel<'_>,
    input: Interval,
) -> Result<AuditReport> {
    if weights.len() != spec.layers.len() {
        bail!(
            "audit: {} weight tensors for {} layers",
            weights.len(),
            spec.layers.len()
        );
    }
    if scales.layers.len() != spec.layers.len() {
        bail!(
            "audit: {} scale rows for {} layers",
            scales.layers.len(),
            spec.layers.len()
        );
    }
    if let WeightModel::Pruned { masks: Some(m) } = wm {
        if m.len() != spec.layers.len() {
            bail!("audit: {} mask layers for {} layers", m.len(), spec.layers.len());
        }
    }

    let mut issues = Vec::new();
    check_shifts(scales, &mut issues);

    let mut layers = Vec::with_capacity(spec.layers.len());
    let mut x = input;
    for (li, (l, w)) in spec.layers.iter().zip(weights.iter()).enumerate() {
        let (f, k) = l.weight_shape();
        if w.rows != f || w.cols != k {
            bail!(
                "audit: layer {li} weight shape ({},{}) != spec ({f},{k})",
                w.rows,
                w.cols
            );
        }
        let (kind, is_conv, relu) = match *l {
            LayerSpec::Conv { relu, .. } => ("conv", true, relu),
            LayerSpec::Fc { relu, .. } => ("fc", false, relu),
        };
        // im2col zero-pads the border patches, so conv GEMM inputs always
        // include 0 whatever the activation interval is.
        let xin = if is_conv { x.with_zero() } else { x };

        let layer_masks: Option<&[i32]> = match wm {
            WeightModel::Pruned { masks: Some(m) } => {
                if m[li].len() != f * k {
                    bail!(
                        "audit: layer {li} mask has {} entries, want {}",
                        m[li].len(),
                        f * k
                    );
                }
                Some(&m[li])
            }
            _ => None,
        };

        // Sentinel as a raw literal: the inverted "empty" interval is
        // collapsed by the first row below (or the f == 0 reset).
        let mut acc = Interval { lo: i64::MAX, hi: i64::MIN };
        let mut reach = Interval { lo: 0, hi: 0 };
        for fi in 0..f {
            let (mut lo, mut hi, mut neg, mut pos) = (0i64, 0i64, 0i64, 0i64);
            for ki in 0..k {
                let prunable = match layer_masks {
                    // Non-zero mask = scored = prunable; zero = always kept.
                    Some(m) => m[fi * k + ki] != 0,
                    None => true,
                };
                let e = edge_interval(wm, prunable, w.data[fi * k + ki] as i64, xin);
                lo += e.lo;
                hi += e.hi;
                neg += e.lo.min(0);
                pos += e.hi.max(0);
            }
            acc.lo = acc.lo.min(lo);
            acc.hi = acc.hi.max(hi);
            reach.lo = reach.lo.min(neg);
            reach.hi = reach.hi.max(pos);
        }
        if f == 0 || k == 0 {
            acc = Interval { lo: 0, hi: 0 };
        }

        let shift = scales.layers[li].fwd;
        let bias = round_bias(shift);
        let worst_case = k as i64 * W_MAX * W_MAX;
        // The reach bounds cover the final sums too (the full sum is one
        // of the prefixes), so one bound serves both overflow conditions:
        // no partial sum wraps, and `acc + bias` inside requant does not.
        let exact_bound = reach.abs_bound();
        let verdict = if worst_case + bias <= I32_MAX {
            Verdict::Proven { headroom_bits: doublings(worst_case, bias) }
        } else if exact_bound + bias <= I32_MAX {
            Verdict::Headroom { bits: doublings(exact_bound, bias) }
        } else {
            Verdict::Overflowable { margin_bits: deficit(exact_bound, bias) }
        };

        // Requant is monotone in the accumulator, so the y interval is the
        // shifted endpoints (mathematical value: meaningful even for an
        // overflowable layer, where the runtime would wrap instead).
        let y = Interval::new(rshift_round_i64(acc.lo, shift), rshift_round_i64(acc.hi, shift));
        let saturates = y.lo < -W_MAX || y.hi > W_MAX;
        let mut out = Interval::new(y.lo.clamp(-W_MAX, W_MAX), y.hi.clamp(-W_MAX, W_MAX));
        if relu {
            out = Interval::new(out.lo.max(0), out.hi.max(0));
        }
        // Max-pool selects an existing value: the interval passes through.
        layers.push(LayerAudit {
            index: li,
            kind,
            rows: f,
            k,
            shift,
            input: xin,
            acc,
            reach,
            worst_case,
            verdict,
            y,
            saturates,
            out,
        });
        x = out;
    }

    Ok(AuditReport {
        model: model.to_string(),
        method: wm.name().to_string(),
        layers,
        issues,
    })
}

/// Contribution interval of one edge under the weight model.
fn edge_interval(wm: WeightModel<'_>, prunable: bool, w: i64, x: Interval) -> Interval {
    match wm {
        WeightModel::Frozen => Interval::of(w * x.lo, w * x.hi),
        WeightModel::WeightDrift => {
            let m = W_MAX * x.lo.abs().max(x.hi.abs());
            Interval { lo: -m, hi: m }
        }
        WeightModel::Pruned { .. } => {
            let base = Interval::of(w * x.lo, w * x.hi);
            // A prunable edge may vanish at any step, so its contribution
            // set also contains 0; an always-kept edge stays exact.
            if prunable {
                base.with_zero()
            } else {
                base
            }
        }
    }
}

/// Rounding bias `rshift_round` adds before shifting (`1 << (s-1)`).
fn round_bias(s: u32) -> i64 {
    if s == 0 {
        0
    } else {
        1i64 << (s.min(62) - 1)
    }
}

/// `quant::rshift_round` replicated in i64 (round-half-up).
fn rshift_round_i64(x: i64, s: u32) -> i64 {
    if s == 0 {
        x
    } else {
        (x + round_bias(s)) >> s.min(63)
    }
}

/// Largest `h` with `(bound << h) + bias <= i32::MAX` (capped at 31).
fn doublings(bound: i64, bias: i64) -> u32 {
    let mut h = 0u32;
    while h < 31 && (bound << (h + 1)) + bias <= I32_MAX {
        h += 1;
    }
    h
}

/// Smallest `m >= 1` with `(bound >> m) + bias <= i32::MAX`.
fn deficit(bound: i64, bias: i64) -> u32 {
    let mut m = 0u32;
    while m < 63 && (bound >> m) + bias > I32_MAX {
        m += 1;
    }
    m
}

/// Shift-table validity: every static shift feeds `rshift_round`'s
/// `1 << (s-1)` bias (i32), so any shift `> 31` is its own overflow —
/// recorded as a report-level issue independent of the layer verdicts.
fn check_shifts(scales: &Scales, issues: &mut Vec<String>) {
    const MAX_SHIFT: u32 = 31;
    for (li, l) in scales.layers.iter().enumerate() {
        for (name, s) in
            [("fwd", l.fwd), ("bwd", l.bwd), ("grad", l.grad), ("score", l.score)]
        {
            if s > MAX_SHIFT {
                issues.push(format!(
                    "layer {li}: {name} shift {s} exceeds {MAX_SHIFT} — the \
                     rounding bias 1<<(s-1) overflows i32"
                ));
            }
        }
        // The combined update shifts are what the engine actually applies.
        if l.grad.saturating_add(scales.lr_shift) > MAX_SHIFT && l.grad <= MAX_SHIFT {
            issues.push(format!(
                "layer {li}: grad+lr_shift = {} exceeds {MAX_SHIFT}",
                l.grad + scales.lr_shift
            ));
        }
        if l.score.saturating_add(scales.score_lr_shift) > MAX_SHIFT && l.score <= MAX_SHIFT
        {
            issues.push(format!(
                "layer {li}: score+score_lr_shift = {} exceeds {MAX_SHIFT}",
                l.score + scales.score_lr_shift
            ));
        }
    }
    for (name, s) in
        [("lr_shift", scales.lr_shift), ("score_lr_shift", scales.score_lr_shift)]
    {
        if s > MAX_SHIFT {
            issues.push(format!("{name} {s} exceeds {MAX_SHIFT}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fc_net(in_f: usize, out_f: usize, relu: bool) -> NetSpec {
        NetSpec {
            name: "toy".to_string(),
            input_chw: (in_f, 1, 1),
            layers: vec![LayerSpec::Fc { in_f, out_f, relu }],
        }
    }

    fn scales_with_fwd(n: usize, fwd: u32) -> Scales {
        let mut s = Scales::default_for(n);
        for l in &mut s.layers {
            l.fwd = fwd;
        }
        s
    }

    #[test]
    fn golden_fc_hand_computed() {
        // FC 3→2, w = [[1,-2,3],[0,5,-1]], x ∈ [0,127], shift 7.
        let spec = fc_net(3, 2, false);
        let w = vec![Mat::from_vec(2, 3, vec![1, -2, 3, 0, 5, -1])];
        let scales = scales_with_fwd(1, 7);
        let r = audit_spec("toy", &spec, &w, &scales, WeightModel::Frozen,
                           PIXEL_INPUT)
            .unwrap();
        let l = &r.layers[0];
        // row0: [0,127] + [-254,0] + [0,381] = [-254, 508]; row1: [-127, 635]
        assert_eq!(l.acc, Interval { lo: -254, hi: 635 });
        assert_eq!(l.reach, Interval { lo: -254, hi: 635 });
        assert_eq!(l.worst_case, 3 * 127 * 127);
        // 48387 << 15 + 64 ≤ i32::MAX < 48387 << 16.
        assert_eq!(l.verdict, Verdict::Proven { headroom_bits: 15 });
        // y = [rshift(-254,7), rshift(635,7)] = [-2, 5]; no saturation.
        assert_eq!(l.y, Interval { lo: -2, hi: 5 });
        assert!(!l.saturates);
        assert_eq!(l.out, Interval { lo: -2, hi: 5 });
        assert!(r.sound());
    }

    #[test]
    fn relu_and_clamp_tighten_the_output() {
        let spec = fc_net(2, 1, true);
        // Huge positive row: y saturates high, relu keeps it nonnegative.
        let w = vec![Mat::from_vec(1, 2, vec![127, 127])];
        let scales = scales_with_fwd(1, 0);
        let r = audit_spec("toy", &spec, &w, &scales, WeightModel::Frozen,
                           PIXEL_INPUT)
            .unwrap();
        let l = &r.layers[0];
        assert!(l.saturates, "unshifted 2·127·127 exceeds the clamp");
        assert_eq!(l.out, Interval { lo: 0, hi: 127 });
    }

    #[test]
    fn pruned_model_widens_cancelling_edges() {
        // w = [127, -127]: frozen final sum cancels to [−16129, 16129],
        // but pruning one edge reaches ±16129 too — and the *prefix* bound
        // must already cover ±16129 even frozen.  With x ∈ [0,127]:
        let spec = fc_net(2, 1, false);
        let w = vec![Mat::from_vec(1, 2, vec![127, -127])];
        let scales = scales_with_fwd(1, 7);
        let frozen = audit_spec("toy", &spec, &w, &scales, WeightModel::Frozen,
                                PIXEL_INPUT)
            .unwrap();
        let pruned = audit_spec("toy", &spec, &w, &scales,
                                WeightModel::Pruned { masks: None },
                                PIXEL_INPUT)
            .unwrap();
        assert_eq!(frozen.layers[0].acc, Interval { lo: -16129, hi: 16129 });
        assert_eq!(frozen.layers[0].reach, Interval { lo: -16129, hi: 16129 });
        // Pruning can only widen, never shrink, the covered set.
        assert!(pruned.layers[0].acc.lo <= frozen.layers[0].acc.lo);
        assert!(pruned.layers[0].acc.hi >= frozen.layers[0].acc.hi);
    }

    #[test]
    fn masks_tighten_the_pruned_bound() {
        // Edge 0 unscored (mask 0, always kept), edge 1 scored (prunable).
        let spec = fc_net(2, 1, false);
        let w = vec![Mat::from_vec(1, 2, vec![100, -100])];
        let scales = scales_with_fwd(1, 7);
        let masks = vec![vec![0, 1]];
        let with_masks = audit_spec(
            "toy", &spec, &w, &scales,
            WeightModel::Pruned { masks: Some(&masks) }, PIXEL_INPUT,
        )
        .unwrap();
        let without = audit_spec("toy", &spec, &w, &scales,
                                 WeightModel::Pruned { masks: None },
                                 PIXEL_INPUT)
            .unwrap();
        // Without masks both edges may drop: hi reaches 12700 (keep only
        // edge 0).  With masks edge 0 always contributes [0, 12700] and
        // edge 1 contributes [-12700, 0] (prunable): same hi, but the
        // model knows edge 0 can never vanish, so lo is the same and the
        // set is a subset.  Assert the containment direction.
        assert!(without.layers[0].acc.lo <= with_masks.layers[0].acc.lo);
        assert!(without.layers[0].acc.hi >= with_masks.layers[0].acc.hi);
    }

    #[test]
    fn weight_drift_reaches_the_envelope() {
        let spec = fc_net(3, 2, false);
        let w = vec![Mat::from_vec(2, 3, vec![1, 0, -1, 2, 0, -2])];
        let scales = scales_with_fwd(1, 7);
        let r = audit_spec("toy", &spec, &w, &scales, WeightModel::WeightDrift,
                           PIXEL_INPUT)
            .unwrap();
        let l = &r.layers[0];
        assert_eq!(l.acc, Interval { lo: -3 * 16129, hi: 3 * 16129 });
        assert_eq!(l.acc.hi, l.worst_case);
    }

    #[test]
    fn headroom_and_overflowable_verdicts() {
        // K large enough that the envelope exceeds i32: 200_000·127·127
        // ≈ 3.2e9 > 2^31.
        let k = 200_000usize;
        let spec = fc_net(k, 1, false);
        let scales = scales_with_fwd(1, 7);
        // Small actual weights → weight-exact bound fits → Headroom.
        let w_small = vec![Mat::from_vec(1, k, vec![1i32; k])];
        let r = audit_spec("toy", &spec, &w_small, &scales, WeightModel::Frozen,
                           PIXEL_INPUT)
            .unwrap();
        match r.layers[0].verdict {
            Verdict::Headroom { bits } => assert!(bits >= 5, "got {bits}"),
            v => panic!("want Headroom, got {v:?}"),
        }
        assert!(r.sound());
        // Full-magnitude weights → even the exact bound overflows.
        let w_big = vec![Mat::from_vec(1, k, vec![127i32; k])];
        let r = audit_spec("toy", &spec, &w_big, &scales, WeightModel::Frozen,
                           PIXEL_INPUT)
            .unwrap();
        match r.layers[0].verdict {
            Verdict::Overflowable { margin_bits } => {
                assert!(margin_bits >= 1)
            }
            v => panic!("want Overflowable, got {v:?}"),
        }
        assert!(!r.sound());
    }

    #[test]
    fn invalid_shifts_are_report_issues() {
        let spec = fc_net(3, 2, false);
        let w = vec![Mat::from_vec(2, 3, vec![0; 6])];
        let scales = scales_with_fwd(1, 40); // 1<<(40-1) overflows i32
        let r = audit_spec("toy", &spec, &w, &scales, WeightModel::Frozen,
                           PIXEL_INPUT)
            .unwrap();
        assert!(!r.sound());
        assert!(r.issues.iter().any(|i| i.contains("fwd shift 40")),
                "issues: {:?}", r.issues);
        // The layer verdict itself stays independent of the shift problem.
        assert!(r.layers[0].verdict.is_sound());
    }

    #[test]
    fn json_and_table_render() {
        let spec = fc_net(3, 2, true);
        let w = vec![Mat::from_vec(2, 3, vec![1, -2, 3, 0, 5, -1])];
        let scales = scales_with_fwd(1, 7);
        let r = audit_spec("toy", &spec, &w, &scales, WeightModel::Frozen,
                           PIXEL_INPUT)
            .unwrap();
        let json = r.to_json();
        for key in ["\"model\": \"toy\"", "\"sound\": true",
                    "\"verdict\": \"proven\"", "\"acc_min\": -254"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let table = r.render_table();
        assert!(table.contains("proven"));
        assert!(table.contains("SOUND"));
    }

    #[test]
    fn rshift_round_i64_matches_i32_reference() {
        for x in [-100_000i32, -129, -128, -5, -1, 0, 1, 5, 127, 100_000] {
            for s in 0u32..12 {
                assert_eq!(
                    rshift_round_i64(x as i64, s),
                    crate::quant::rshift_round(x, s) as i64,
                    "x={x} s={s}"
                );
            }
        }
    }
}
