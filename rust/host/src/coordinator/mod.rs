//! The on-device-learning coordinator: drives training epochs over a
//! [`StepBackend`], evaluates at epoch boundaries, tracks the best model,
//! records the Fig. 2/Fig. 3 probes, and fans seed sweeps out over threads
//! (Table I's mean ± std over 10 runs).
//!
//! This is the L3 "request path": after `make artifacts` everything here is
//! pure Rust — Python never runs again.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::engine::StepOut;
use crate::methods::{plugin_for, StepBackend};
use crate::metrics::{MeanStd, RunMetrics};
use crate::serial::Dataset;
use crate::session::{Backbone, Fleet};
use crate::tensor::Mat;

/// Options controlling a single run.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub epochs: usize,
    /// Cap on train/test samples (0 = use all).
    pub limit: usize,
    /// Record per-layer pruned fractions + mask-flip counts per epoch
    /// (costs a scores scan per epoch — configurable via the
    /// `track_pruning` config key).
    pub track_pruning: bool,
    /// Print a line per epoch.
    pub verbose: bool,
    /// Samples per forward in epoch-boundary evaluation (0/1 = one sample
    /// at a time).  Batched evaluation is bit-identical to per-sample —
    /// the batch dimension is extra GEMM columns, never different
    /// arithmetic.
    pub eval_batch: usize,
    /// Samples per *training* chunk (0/1 = the paper's strictly sequential
    /// loop).  Chunked training batches the forward passes while every
    /// update stays a sequential batch-1 step — bit-identical (see
    /// [`crate::methods::StepBackend::train_chunk`]).
    pub train_batch: usize,
}

impl RunOptions {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        Self {
            epochs: cfg.epochs,
            limit: cfg.limit,
            track_pruning: cfg.track_pruning,
            verbose: false,
            eval_batch: cfg.eval_batch,
            train_batch: cfg.train_batch,
        }
    }
}

/// Cap `n` samples at `limit` (0 = no cap).
pub fn capped(n: usize, limit: usize) -> usize {
    if limit == 0 {
        n
    } else {
        n.min(limit)
    }
}

/// Summary of one pass over (a cap of) the training set.
#[derive(Clone, Copy, Debug)]
pub struct EpochReport {
    pub steps: usize,
    pub train_accuracy: f64,
    pub overflow: u64,
    pub secs: f64,
}

/// One training epoch over (a cap of) `train` — the single implementation
/// of the inner step loop, shared by [`run_training`] and
/// [`crate::session::Session::train_epoch`].
///
/// `chunk <= 1` is the paper's strictly sequential loop.  `chunk > 1`
/// feeds samples to [`StepBackend::train_chunk`] `chunk` rows at a time,
/// which batches the forward passes through the tiled kernels while
/// keeping every update a sequential batch-1 step — bit-identical to the
/// sequential loop (asserted per method by `rust/cli/tests/batch_train.rs`
/// and at the engine layer by `engine::tests`).
pub fn train_one_epoch(backend: &mut dyn StepBackend, train: &Dataset,
                       limit: usize, chunk: usize) -> EpochReport {
    let n = capped(train.n, limit);
    let len = train.image_len();
    let mut overflow = 0u64;
    let mut correct = 0usize;
    let t0 = crate::obs::Timer::start();
    if chunk <= 1 || n == 0 {
        let mut img = vec![0i32; len];
        for i in 0..n {
            train.image_i32(i, &mut img);
            let label = train.label(i);
            let StepOut { logits, overflow: ovf } =
                backend.train_step(&img, label);
            overflow += ovf as u64;
            if crate::engine::argmax(&logits) == label {
                correct += 1;
            }
        }
    } else {
        let bsz = chunk.min(n);
        let mut imgs = Mat::zeros(bsz, len);
        let mut labels = vec![0usize; bsz];
        let mut i = 0usize;
        while i < n {
            let bcur = bsz.min(n - i);
            if bcur != imgs.rows {
                imgs = Mat::zeros(bcur, len); // remainder chunk
                labels.truncate(bcur);
            }
            for bi in 0..bcur {
                train.image_i32(i + bi,
                                &mut imgs.data[bi * len..(bi + 1) * len]);
                labels[bi] = train.label(i + bi);
            }
            let outs = backend.train_chunk(&imgs, &labels);
            for (out, &label) in outs.iter().zip(labels.iter()) {
                overflow += out.overflow as u64;
                if crate::engine::argmax(&out.logits) == label {
                    correct += 1;
                }
            }
            i += bcur;
        }
    }
    EpochReport {
        steps: n,
        train_accuracy: correct as f64 / n.max(1) as f64,
        overflow,
        secs: t0.elapsed_secs(),
    }
}

/// Evaluate top-1 accuracy of `backend` over (a cap of) `ds`, one sample
/// at a time — the `batch = 1` case of [`evaluate_batched`] (kept as the
/// named per-sample entry point).
pub fn evaluate(backend: &mut dyn StepBackend, ds: &Dataset, limit: usize)
                -> f64 {
    evaluate_batched(backend, ds, limit, 1)
}

/// Predictions over (a cap of) `ds` in batched forwards of up to `batch`
/// samples.  Bit-identical to a per-sample [`StepBackend::predict`] loop
/// (asserted by `rust/cli/tests/serve.rs` for every method plugin); the final
/// chunk covers the `n % batch` remainder.
pub fn predict_batched(backend: &mut dyn StepBackend, ds: &Dataset,
                       limit: usize, batch: usize) -> Vec<usize> {
    let n = capped(ds.n, limit);
    let len = ds.image_len();
    if n == 0 {
        return Vec::new();
    }
    if batch <= 1 {
        let mut img = vec![0i32; len];
        return (0..n)
            .map(|i| {
                ds.image_i32(i, &mut img);
                backend.predict(&img)
            })
            .collect();
    }
    let bsz = batch.min(n);
    let mut imgs = Mat::zeros(bsz, len);
    let mut out = Vec::with_capacity(n);
    let mut i = 0usize;
    while i < n {
        let bcur = bsz.min(n - i);
        if bcur != imgs.rows {
            imgs = Mat::zeros(bcur, len); // remainder chunk
        }
        for bi in 0..bcur {
            ds.image_i32(i + bi, &mut imgs.data[bi * len..(bi + 1) * len]);
        }
        out.extend(backend.predict_batch(&imgs));
        i += bcur;
    }
    out
}

/// Top-1 accuracy via [`predict_batched`] — the fleet/serve evaluation
/// path (`batch <= 1` degenerates to the per-sample loop of [`evaluate`]).
pub fn evaluate_batched(backend: &mut dyn StepBackend, ds: &Dataset,
                        limit: usize, batch: usize) -> f64 {
    let n = capped(ds.n, limit);
    if n == 0 {
        return 0.0;
    }
    let correct = predict_batched(backend, ds, limit, batch)
        .into_iter()
        .enumerate()
        .filter(|&(i, p)| p == ds.label(i))
        .count();
    correct as f64 / n as f64
}

fn pruned_fractions(backend: &dyn StepBackend) -> Vec<f64> {
    match (backend.scores(), backend.masks(), backend.theta()) {
        (Some(scores), Some(masks), Some(theta)) => scores
            .iter()
            .zip(masks.iter())
            .map(|(s, m)| {
                let pruned = s
                    .iter()
                    .zip(m.iter())
                    .filter(|&(&sv, &mv)| mv != 0 && sv < theta)
                    .count();
                pruned as f64 / s.len().max(1) as f64
            })
            .collect(),
        _ => Vec::new(),
    }
}

fn mask_snapshot(backend: &dyn StepBackend) -> Vec<bool> {
    match (backend.scores(), backend.masks(), backend.theta()) {
        (Some(scores), Some(masks), Some(theta)) => scores
            .iter()
            .zip(masks.iter())
            .flat_map(|(s, m)| {
                s.iter()
                    .zip(m.iter())
                    .map(move |(&sv, &mv)| mv != 0 && sv < theta)
            })
            .collect(),
        _ => Vec::new(),
    }
}

/// The epoch-granular training driver: everything [`run_training`] carries
/// between epochs, factored out so schedulers ([`crate::session::Fleet`],
/// `priot::serve`) can interleave the epochs of many sessions across a
/// worker pool without duplicating the run protocol.  One `TrainProgress`
/// belongs to one device; the metrics it accumulates are bit-identical to
/// an uninterrupted [`run_training`] over the same backend.
pub struct TrainProgress {
    metrics: RunMetrics,
    prev_mask: Vec<bool>,
}

impl TrainProgress {
    /// Epoch-0 evaluation (the pre-training point of the paper's curves)
    /// plus the initial mask snapshot.
    pub fn start(backend: &mut dyn StepBackend, test: &Dataset,
                 opts: &RunOptions) -> Self {
        let mut metrics = RunMetrics::default();
        metrics
            .accuracy
            .push(evaluate_batched(backend, test, opts.limit, opts.eval_batch));
        let prev_mask = if opts.track_pruning {
            mask_snapshot(backend)
        } else {
            Vec::new()
        };
        if opts.verbose {
            eprintln!("[{}] epoch 0: test acc {:.4}", backend.name(),
                      metrics.accuracy[0]);
        }
        Self { metrics, prev_mask }
    }

    /// One training epoch + the epoch-boundary evaluation and pruning
    /// tracking.
    pub fn step_epoch(&mut self, backend: &mut dyn StepBackend,
                      train: &Dataset, test: &Dataset, opts: &RunOptions) {
        let ep = train_one_epoch(backend, train, opts.limit, opts.train_batch);
        let m = &mut self.metrics;
        m.epoch_secs.push(ep.secs);
        m.overflow.push(ep.overflow);
        m.steps.push(ep.steps as u64);
        m.train_accuracy.push(ep.train_accuracy);
        m.accuracy
            .push(evaluate_batched(backend, test, opts.limit, opts.eval_batch));
        if opts.track_pruning {
            let fr = pruned_fractions(backend);
            if !fr.is_empty() {
                m.pruned_frac.push(fr);
            }
            let cur = mask_snapshot(backend);
            if !cur.is_empty() && cur.len() == self.prev_mask.len() {
                let flips = cur
                    .iter()
                    .zip(self.prev_mask.iter())
                    .filter(|&(a, b)| a != b)
                    .count() as u64;
                m.mask_flips.push(flips);
                self.prev_mask = cur;
            } else if !cur.is_empty() {
                self.prev_mask = cur;
            }
        }
        if opts.verbose {
            eprintln!(
                "[{}] epoch {}: test acc {:.4} train acc {:.4} overflow {}",
                backend.name(),
                self.epochs_done(),
                m.accuracy.last().unwrap(),
                m.train_accuracy.last().unwrap(),
                ep.overflow
            );
        }
    }

    /// Epochs trained so far (excludes the epoch-0 evaluation).
    pub fn epochs_done(&self) -> usize {
        self.metrics.train_accuracy.len()
    }

    pub fn metrics(&self) -> &RunMetrics {
        &self.metrics
    }

    pub fn finish(self) -> RunMetrics {
        self.metrics
    }
}

/// Run one on-device training session: epoch loop over the train set with
/// an evaluation at every epoch boundary (epoch 0 = the pre-trained
/// backbone — the paper's curves and "best during training" include it).
pub fn run_training(backend: &mut dyn StepBackend, train: &Dataset,
                    test: &Dataset, opts: &RunOptions) -> RunMetrics {
    let mut progress = TrainProgress::start(backend, test, opts);
    for _ in 0..opts.epochs {
        progress.step_epoch(backend, train, test, opts);
    }
    progress.finish()
}

/// Aggregate of a seed sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub best: MeanStd,
    pub runs: Vec<RunMetrics>,
}

/// Run `seeds.len()` independent runs (one per seed) as a [`Fleet`] and
/// aggregate the Table I statistic.  The backbone is loaded **once** and
/// shared read-only across all seed sessions (pre-fleet, every seed
/// re-read the weight file and held its own copy); each session owns only
/// its method state, so runs stay fully isolated.
pub fn sweep_seeds(cfg: &ExperimentConfig, train: &Dataset, test: &Dataset,
                   opts: &RunOptions, seeds: &[u32]) -> Result<SweepResult> {
    let backbone = Backbone::load(&cfg.artifacts_dir, &cfg.model)?;
    let mut fleet = Fleet::builder(backbone).options(opts.clone());
    for &seed in seeds {
        fleet = fleet.device(format!("seed-{seed}"), seed, plugin_for(cfg)?,
                             train, test);
    }
    let report = fleet.run()?;
    let bests = report.best_accuracies();
    let runs: Vec<RunMetrics> =
        report.devices.into_iter().map(|d| d.metrics).collect();
    Ok(SweepResult { best: MeanStd::of(&bests), runs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::StepOut;

    /// A fake backend: predicts (i mod 10) wrongly until "trained" for k
    /// steps, then always matches a fixed oracle function.
    struct FakeBackend {
        steps: usize,
        threshold: usize,
    }

    impl StepBackend for FakeBackend {
        fn train_step(&mut self, _img: &[i32], label: usize) -> StepOut {
            self.steps += 1;
            let mut logits = vec![0i32; 10];
            logits[label] = 10;
            StepOut { logits, overflow: 1 }
        }
        fn predict(&mut self, img: &[i32]) -> usize {
            if self.steps >= self.threshold {
                (img[0] as usize) % 10 // the "true" labelling
            } else {
                9 - (img[0] as usize) % 10
            }
        }
        fn scores(&self) -> Option<&[Vec<i32>]> {
            None
        }
        fn masks(&self) -> Option<&[Vec<i32>]> {
            None
        }
        fn theta(&self) -> Option<i32> {
            None
        }
        fn name(&self) -> &str {
            "fake"
        }
    }

    fn fake_dataset(n: usize) -> Dataset {
        // image[0] encodes the label (×2 so the >>1 mapping recovers it).
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            let label = (i % 10) as u8;
            let mut img = vec![0u8; 4];
            img[0] = label * 2;
            images.extend(img);
            labels.push(label);
        }
        Dataset { n, c: 1, h: 2, w: 2, images, labels }
    }

    #[test]
    fn run_training_records_epochs_and_improvement() {
        let train = fake_dataset(20);
        let test = fake_dataset(10);
        let mut b = FakeBackend { steps: 0, threshold: 20 };
        let opts = RunOptions {
            epochs: 2, limit: 0, track_pruning: true, verbose: false,
            eval_batch: 1, train_batch: 1,
        };
        let m = run_training(&mut b, &train, &test, &opts);
        assert_eq!(m.accuracy.len(), 3, "epoch0 + 2 epochs");
        assert!(m.accuracy[0] < 0.2, "untrained fake is wrong");
        assert_eq!(m.accuracy[2], 1.0, "after 20 steps the fake is perfect");
        assert_eq!(m.overflow, vec![20, 20]);
        assert_eq!(m.best_accuracy(), 1.0);
        assert_eq!(m.train_accuracy.len(), 2);
        assert_eq!(m.train_accuracy[0], 1.0, "train logits always 'correct'");
        assert_eq!(m.steps, vec![20, 20], "executed steps recorded per epoch");
        assert_eq!(m.total_steps(), 40);
    }

    #[test]
    fn limit_caps_samples() {
        let train = fake_dataset(50);
        let test = fake_dataset(50);
        let mut b = FakeBackend { steps: 0, threshold: 5 };
        let opts = RunOptions {
            epochs: 1, limit: 5, track_pruning: false, verbose: false,
            eval_batch: 1, train_batch: 1,
        };
        let m = run_training(&mut b, &train, &test, &opts);
        assert_eq!(b.steps, 5);
        assert_eq!(m.accuracy.len(), 2);
        assert_eq!(m.total_steps(), 5);
    }

    #[test]
    fn batched_evaluation_matches_per_sample() {
        // The default StepBackend::predict_batch is the per-sample loop, so
        // chunking itself (including the remainder chunk) must not change
        // predictions or accuracy.
        let test = fake_dataset(23);
        for batch in [1usize, 2, 7, 23, 64] {
            let mut a = FakeBackend { steps: 0, threshold: 0 };
            let mut b = FakeBackend { steps: 0, threshold: 0 };
            let per_sample = predict_batched(&mut a, &test, 0, 1);
            let batched = predict_batched(&mut b, &test, 0, batch);
            assert_eq!(per_sample, batched, "batch={batch}");
            assert_eq!(
                evaluate(&mut a, &test, 0),
                evaluate_batched(&mut b, &test, 0, batch),
                "batch={batch}"
            );
        }
        let mut e = FakeBackend { steps: 0, threshold: 0 };
        assert_eq!(evaluate_batched(&mut e, &fake_dataset(0), 0, 8), 0.0,
                   "empty dataset evaluates to 0.0, no panic");
    }

    #[test]
    fn chunked_training_matches_per_sample_for_default_backends() {
        // FakeBackend uses the default StepBackend::train_chunk (the
        // per-sample loop), so every chunk width — including ones that
        // leave a remainder or exceed the dataset — must reproduce the
        // sequential epoch exactly.
        let train = fake_dataset(23);
        let mut a = FakeBackend { steps: 0, threshold: 0 };
        let seq = train_one_epoch(&mut a, &train, 0, 1);
        for chunk in [2usize, 5, 23, 64] {
            let mut b = FakeBackend { steps: 0, threshold: 0 };
            let chunked = train_one_epoch(&mut b, &train, 0, chunk);
            assert_eq!(a.steps, b.steps, "chunk={chunk}");
            assert_eq!(seq.steps, chunked.steps, "chunk={chunk}");
            assert_eq!(seq.train_accuracy, chunked.train_accuracy,
                       "chunk={chunk}");
            assert_eq!(seq.overflow, chunked.overflow, "chunk={chunk}");
        }
    }

    #[test]
    fn train_progress_is_bit_identical_to_run_training() {
        // Interleavable epoch stepping must reproduce the one-shot loop.
        let train = fake_dataset(20);
        let test = fake_dataset(10);
        let opts = RunOptions {
            epochs: 3, limit: 0, track_pruning: true, verbose: false,
            eval_batch: 4, train_batch: 3,
        };
        let mut a = FakeBackend { steps: 0, threshold: 20 };
        let whole = run_training(&mut a, &train, &test, &opts);
        let mut b = FakeBackend { steps: 0, threshold: 20 };
        let mut progress = TrainProgress::start(&mut b, &test, &opts);
        for _ in 0..opts.epochs {
            progress.step_epoch(&mut b, &train, &test, &opts);
        }
        assert_eq!(progress.epochs_done(), 3);
        let stepped = progress.finish();
        assert_eq!(whole.accuracy, stepped.accuracy);
        assert_eq!(whole.overflow, stepped.overflow);
        assert_eq!(whole.steps, stepped.steps);
    }
}
