//! LRU eviction of idle resident sessions under
//! [`super::ServeBuilder::resident_cap`] pressure.  The invariants at
//! this seam:
//!
//! * Eviction runs on worker threads at **op-queue idle points** and
//!   only ever picks devices with no pending requests, so it cannot
//!   interleave with a device's own ops.
//! * The store flush happens **outside the registry lock**; the
//!   `evicting` flag marks the gap, and a worker that claims the device
//!   meanwhile defers and retries (see [`super::workers`]).
//! * **State is never lost:** a failed flush puts the device back
//!   resident and stops evicting; only a device whose store copy is
//!   up to date (clean, or freshly flushed) goes store-only.

use std::sync::atomic::Ordering;

use super::registry::Shared;
use super::workers::device_snapshot;

/// Evict least-recently-used idle devices until the resident count is
/// back under the cap.  Runs on worker threads at op-queue idle points;
/// devices with pending work are never touched, so eviction cannot
/// interleave with a device's own ops.  The flush happens outside the
/// registry lock; a worker that claims the device meanwhile sees the
/// `evicting` flag and defers.
pub(super) fn enforce_resident_cap(shared: &Shared) {
    let Some(store) = &shared.store else {
        return; // nowhere to evict into
    };
    loop {
        let victim = {
            let mut reg = shared.registry.lock().expect("serve registry");
            if reg.resident <= shared.resident_cap {
                return;
            }
            let pick = reg
                .map
                .iter()
                .filter(|(_, st)| {
                    st.pending == 0
                        && !st.evicting
                        && st.resident
                            .as_ref()
                            .is_some_and(|r| r.session.is_some())
                })
                .min_by_key(|(_, st)| st.last_used)
                .map(|(d, _)| d.clone());
            let Some(device) = pick else {
                return; // everyone is busy; re-checked at the next idle point
            };
            let st = reg.map.get_mut(&device).expect("picked device");
            st.evicting = true;
            let res = st.resident.take().expect("picked resident");
            let meta = (st.epochs_done, st.angle, st.dirty);
            reg.resident -= 1;
            (device, res, meta)
        };
        let (device, res, (epochs_done, angle, dirty)) = victim;
        // Flush outside the lock — and only when the store is stale
        // (write-through at op completion usually already covered it).
        let result = if dirty {
            let session = res.session.as_ref().expect("evicted session");
            let t = crate::obs::Timer::start();
            let put = device_snapshot(session, &device, &res.train, &res.test,
                                      epochs_done, angle)
                .and_then(|snap| store.put(&snap));
            shared.obs.persist.record(t.elapsed_us());
            put
        } else {
            Ok(())
        };
        let mut reg = shared.registry.lock().expect("serve registry");
        match result {
            Ok(()) => {
                let st = reg.map.get_mut(&device).expect("evicting device");
                st.evicting = false;
                st.dirty = false;
                shared.evictions.fetch_add(1, Ordering::Relaxed);
                // resident stays None: the device is now store-only.
            }
            Err(e) => {
                // Never lose state: keep the device resident and stop
                // evicting for now.
                let st = reg.map.get_mut(&device).expect("evicting device");
                st.evicting = false;
                st.resident = Some(res);
                reg.resident += 1;
                eprintln!(
                    "[serve] evicting {device}: {e:#} — keeping it resident"
                );
                return;
            }
        }
    }
}
