//! The worker pool: claim a ready device, execute one unit, persist,
//! respond, re-queue.  The invariants at this seam:
//!
//! * **Epoch-granular preemption:** a multi-epoch `Train` executes one
//!   epoch per claim; an unfinished request goes back to the *front* of
//!   its lane, so higher-priority work cuts in at every epoch boundary.
//! * **Session check-out/check-in:** a worker takes the device's
//!   session out of the registry for the duration of one unit; the
//!   one-turn-per-device rule (see [`super::registry`]) guarantees no
//!   other worker touches it meanwhile.
//! * **Persist-before-respond:** a completed state-mutating request
//!   writes the device's snapshot to the store *before* its response is
//!   emitted, so any state a client has been told about survives a
//!   crash.  A failed write keeps the device dirty; eviction and
//!   `join()` retry the flush.
//! * **Lazy rehydration:** a claim on an evicted device rebuilds its
//!   session from the store bit-identically before the pending item
//!   runs; an evictor mid-flush makes the claim step aside and retry
//!   (the `Defer` protocol — see [`super::evict`]).
//! * **Panic containment:** a panicking op (method plugins are an open
//!   extension point) becomes an error response, never a dead worker.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::capped;
use crate::obs::{Op, Timer};
use crate::proto::{ErrorKind, Priority, Response};
use crate::serial::{u8_to_i32_pixels, Dataset};
use crate::session::Session;
use crate::store::DeviceSnapshot;

use super::evict::enforce_resident_cap;
use super::registry::{note_done, respond, Item, Resident, Shared, Work};
use super::AuditPolicy;

/// What one executed unit produced.
enum UnitOut {
    /// A training epoch ran; the request has more epochs to go.
    Continue,
    TrainDone { epochs: usize, steps: u64, train_accuracy: f64 },
    Prediction(usize),
    Evaluation { accuracy: f64, n: usize },
    Drifted { train: Arc<Dataset>, test: Arc<Dataset> },
}

fn run_unit(session: &mut Session, work: &mut Work, train: &Dataset,
            test: &Dataset, eval_batch: usize, limit: usize)
            -> Result<UnitOut> {
    match work {
        Work::Register { .. } => {
            unreachable!("register units run via run_register")
        }
        Work::Train { remaining, done, steps } => {
            if *remaining == 0 {
                // A zero-epoch request reached its queue slot: close it
                // out in order, with nothing executed.
                return Ok(UnitOut::TrainDone {
                    epochs: 0,
                    steps: 0,
                    train_accuracy: 0.0,
                });
            }
            let ep = session.train_epoch(train)?;
            *remaining -= 1;
            *done += 1;
            *steps += ep.steps as u64;
            if *remaining == 0 {
                Ok(UnitOut::TrainDone {
                    epochs: *done,
                    steps: *steps,
                    train_accuracy: ep.train_accuracy,
                })
            } else {
                Ok(UnitOut::Continue)
            }
        }
        Work::Predict { image } => {
            let want = session.spec.input_len();
            if image.len() != want {
                bail!("predict: image has {} pixels, model {} wants {want}",
                      image.len(), session.spec.name);
            }
            let mut img = vec![0i32; want];
            u8_to_i32_pixels(image, &mut img);
            Ok(UnitOut::Prediction(session.predict(&img)))
        }
        Work::Evaluate => {
            let accuracy = session.evaluate_batch(test, eval_batch)?;
            Ok(UnitOut::Evaluation { accuracy, n: capped(test.n, limit) })
        }
        Work::Drift { train: tr, test: te, .. } => {
            crate::data::validate(tr, &session.spec)
                .context("drift train set")?;
            crate::data::validate(te, &session.spec)
                .context("drift test set")?;
            Ok(UnitOut::Drifted {
                train: Arc::clone(tr),
                test: Arc::clone(te),
            })
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Drain the session engine's deterministic perf counters into the
/// server's telemetry — after every executed unit, *before* its response
/// is emitted, so a synchronous client's follow-up `GetStats` always
/// sees the MACs behind every response it has received.
#[cfg(feature = "obs")]
fn drain_engine_counters(shared: &Shared, session: &mut Session) {
    use priot_core::tensor::KernelKind;
    if let Some(c) = session.take_perf_counters() {
        shared.obs.merge_engine(
            c.kind == KernelKind::Tiled,
            c.kernels.calls(),
            c.kernels.macs,
            c.kernels.gemv_hits,
            c.theta_fallbacks,
            c.kernels.scratch_high_water_bytes,
        );
    }
}

/// With `obs` compiled out the engine counts nothing: the drain is a
/// no-op (host-side timings stay on regardless).
#[cfg(not(feature = "obs"))]
fn drain_engine_counters(_shared: &Shared, _session: &mut Session) {}

/// Assemble the durable snapshot of one device around its live session.
pub(super) fn device_snapshot(session: &Session, device: &str,
                              train: &Arc<Dataset>, test: &Arc<Dataset>,
                              epochs_done: u64, angle: Option<u32>)
                              -> Result<DeviceSnapshot> {
    Ok(DeviceSnapshot {
        device: device.to_string(),
        session: session.snapshot()?,
        train: Arc::clone(train),
        test: Arc::clone(test),
        epochs_done,
        angle,
    })
}

/// What a worker found when it claimed a ready device.
enum Claim {
    /// Session + highest-priority item checked out — execute it.
    /// (Boxed: a `Session` inlines the engine workspace, which would
    /// dwarf the other variants.)
    Run {
        session: Box<Session>,
        item: Item,
        lane: usize,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
    },
    /// The device's first unit: build/resume its session.
    Register(Item),
    /// Registered but evicted: rehydrate from the store first.
    Rehydrate,
    /// An evictor is mid-flush on this device: step aside and retry.
    Defer,
}

pub(super) fn worker(shared: &Shared) {
    loop {
        // Wait for a ready device (or shutdown).
        let device = {
            let mut q = shared.ready.lock().expect("serve ready queue");
            loop {
                if let Some(d) = q.pop_front() {
                    break d;
                }
                if shared.done.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready_cv.wait(q).expect("serve ready queue");
            }
        };
        // Claim the device's next unit.  The device is in the ready
        // queue at most once, so nobody else touches its session while
        // we hold this turn.
        let claim = {
            let mut reg = shared.registry.lock().expect("serve registry");
            reg.tick += 1;
            let tick = reg.tick;
            let st = reg.map.get_mut(&device).expect("ready device registered");
            if st.evicting {
                Claim::Defer
            } else {
                let lane = (0..Priority::COUNT)
                    .find(|&l| !st.lanes[l].is_empty())
                    .expect("ready device has work");
                let head_is_register = matches!(
                    st.lanes[lane].front().expect("non-empty lane").work,
                    Work::Register { .. }
                );
                if head_is_register {
                    Claim::Register(
                        st.lanes[lane].pop_front().expect("non-empty lane"),
                    )
                } else if st.resident.is_none() {
                    Claim::Rehydrate
                } else {
                    st.last_used = tick;
                    let item =
                        st.lanes[lane].pop_front().expect("non-empty lane");
                    let res = st.resident.as_mut().expect("resident device");
                    Claim::Run {
                        session: Box::new(
                            res.session
                                .take()
                                .expect("ready device owns its session"),
                        ),
                        item,
                        lane,
                        train: Arc::clone(&res.train),
                        test: Arc::clone(&res.test),
                    }
                }
            }
        };
        match claim {
            Claim::Defer => {
                // Re-queue and retry once the evictor clears the flag.
                // The short sleep keeps the retry loop from burning a
                // core while the flush (a bounded disk write) finishes.
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device);
                std::thread::sleep(Duration::from_micros(500));
            }
            Claim::Rehydrate => {
                match rehydrate_device(shared, &device) {
                    Ok(()) => {
                        // Now resident; re-queue so the pending item runs
                        // (possibly on another worker).
                        shared
                            .ready
                            .lock()
                            .expect("serve ready queue")
                            .push_back(device.clone());
                        shared.ready_cv.notify_one();
                        enforce_resident_cap(shared);
                    }
                    Err(e) => fail_head_item(shared, &device, e),
                }
            }
            Claim::Register(item) => {
                run_register(shared, &device, item);
                enforce_resident_cap(shared);
            }
            Claim::Run { session, item, lane, train, test } => {
                run_op(shared, &device, *session, item, lane, &train, &test);
                enforce_resident_cap(shared);
            }
        }
    }
}

/// Execute one claimed non-register unit, persist on completion of a
/// state-mutating request, check the session back in, and respond.
fn run_op(shared: &Shared, device: &str, mut session: Session, item: Item,
          lane: usize, train: &Arc<Dataset>, test: &Arc<Dataset>) {
    let Item { id, reply, mut work, enqueued } = item;
    // Lane-wait span: enqueue (or the last epoch's re-queue) → now.
    let queue_wait_us = Timer::since(enqueued).elapsed_us();
    shared.obs.record_queue_wait(lane, queue_wait_us);
    let op = match &work {
        Work::Register { .. } => Op::Register,
        Work::Train { .. } => Op::Train,
        Work::Predict { .. } => Op::Predict,
        Work::Evaluate => Op::Evaluate,
        Work::Drift { .. } => Op::Drift,
    };
    // A panicking op (method plugins are an open extension point) must
    // not kill the worker: the `outstanding` count would never drain
    // and `join()` would hang.  Convert the panic into an error
    // response; engine/score buffers are plain integers, so the
    // checked-back-in session is memory-safe.  Its method state may be
    // mid-step, and memory is authoritative: the device stays dirty and
    // the partial state persists at the next flush (a durable reset /
    // deregister op is a ROADMAP item — today the operator clears the
    // device's store directory to start it over).
    let exec = Timer::start();
    let unit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || run_unit(&mut session, &mut work, train, test,
                    shared.eval_batch, shared.limit),
    ))
    .unwrap_or_else(|payload| {
        Err(anyhow!("op panicked: {}", panic_message(payload.as_ref())))
    });
    // One executed unit — for a multi-epoch `Train`, one epoch
    // (`exec/train_epoch` measures epochs, not whole requests).
    let execute_us = exec.elapsed_us();
    shared.obs.record_exec(op, execute_us);
    drain_engine_counters(shared, &mut session);
    // Did this unit (or its failed attempt) touch durable state?
    let mutated = match (&work, &unit) {
        (Work::Predict { .. } | Work::Evaluate, _) => false,
        (_, Ok(UnitOut::TrainDone { epochs: 0, .. })) => false,
        _ => true,
    };
    let drift_angle = match &work {
        Work::Drift { angle, .. } => *angle,
        _ => None,
    };
    // Persist-before-respond: a completed state-mutating request writes
    // the device's snapshot first, so any state a client has been told
    // about survives a crash (the restart-resume contract).  A failed
    // write keeps the device dirty; eviction and join() retry it.
    let mut persisted = false;
    if let Some(store) = &shared.store {
        let flush = match &unit {
            Ok(UnitOut::TrainDone { epochs, .. }) if *epochs > 0 => {
                Some((train, test, *epochs as u64, false))
            }
            Ok(UnitOut::Drifted { train: tr, test: te }) => {
                Some((tr, te, 0, true))
            }
            _ => None,
        };
        if let Some((tr, te, new_epochs, is_drift)) = flush {
            let (base_epochs, cur_angle) = {
                let reg = shared.registry.lock().expect("serve registry");
                let st = reg.map.get(device).expect("device still registered");
                (st.epochs_done, st.angle)
            };
            let angle = if is_drift { drift_angle } else { cur_angle };
            let t = Timer::start();
            let put = device_snapshot(&session, device, tr, te,
                                      base_epochs + new_epochs, angle)
                .and_then(|snap| store.put(&snap));
            shared.obs.persist.record(t.elapsed_us());
            match put {
                Ok(()) => persisted = true,
                Err(e) => eprintln!(
                    "[serve] persisting {device}: {e:#} — state kept in \
                     memory (flushed again at eviction or join)"
                ),
            }
        }
    }
    // Check the session back in and emit the response (if the request
    // completed) *before* re-queuing the device, so a device's
    // responses leave in execution order.
    let mut responded = false;
    {
        let mut reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get_mut(device).expect("device still registered");
        st.resident
            .as_mut()
            .expect("resident while op in flight")
            .session = Some(session);
        // Per-device telemetry rides the registry lock we already hold.
        st.ops_done = st.ops_done.saturating_add(1);
        st.queue_wait_us = st.queue_wait_us.saturating_add(queue_wait_us);
        st.execute_us = st.execute_us.saturating_add(execute_us);
        let response = match unit {
            Ok(UnitOut::Continue) => {
                // Back to the front of its lane: the request resumes
                // at the device's next turn, after any
                // higher-priority work cuts in.  `enqueued` resets so
                // the next epoch measures its own lane wait.
                st.lanes[lane].push_front(Item {
                    id,
                    reply: reply.clone(),
                    work,
                    enqueued: Instant::now(),
                });
                None
            }
            Ok(UnitOut::TrainDone { epochs, steps, train_accuracy }) => {
                st.epochs_done += epochs as u64;
                Some(Response::TrainDone {
                    device: device.to_string(),
                    epochs,
                    steps,
                    train_accuracy,
                })
            }
            Ok(UnitOut::Prediction(class)) => Some(Response::Prediction {
                device: device.to_string(),
                class,
            }),
            Ok(UnitOut::Evaluation { accuracy, n }) => {
                Some(Response::Evaluation {
                    device: device.to_string(),
                    accuracy,
                    n,
                })
            }
            Ok(UnitOut::Drifted { train, test }) => {
                let res =
                    st.resident.as_mut().expect("resident while op in flight");
                res.train = train;
                res.test = test;
                st.angle = drift_angle;
                Some(Response::Drifted { device: device.to_string() })
            }
            // A failed Train drops its remaining epochs with it: one
            // Error closes out the whole request — it neither trains
            // on for nothing nor emits a TrainDone after its Error.
            Err(e) => Some(Response::Error {
                device: device.to_string(),
                kind: ErrorKind::Request,
                message: format!("{e:#}"),
            }),
        };
        st.dirty = (st.dirty || mutated) && !persisted;
        if let Some(resp) = response {
            st.pending -= 1;
            respond(shared, &reply, id, resp);
            responded = true;
        }
        if st.has_work() {
            shared
                .ready
                .lock()
                .expect("serve ready queue")
                .push_back(device.to_string());
            shared.ready_cv.notify_one();
        } else {
            st.queued = false;
        }
    }
    if responded {
        note_done(shared, 1);
    }
}

/// Classified register failure: what the client is told and how.
struct RegisterFail {
    kind: ErrorKind,
    err: anyhow::Error,
}

fn store_fail(err: anyhow::Error) -> RegisterFail {
    RegisterFail { kind: ErrorKind::Store, err }
}

fn request_fail(err: anyhow::Error) -> RegisterFail {
    RegisterFail { kind: ErrorKind::Request, err }
}

/// Execute a register unit on the worker pool: resume the device from
/// the store when it is known there, otherwise validate + build a fresh
/// session and persist its initial snapshot *before* acknowledging.
fn run_register(shared: &Shared, device: &str, item: Item) {
    let Item { id, reply, work, enqueued } = item;
    let Work::Register { seed, method, train, test, angle } = work else {
        unreachable!("run_register on a non-register item");
    };
    // Register units always ride the head (interactive) lane.
    let queue_wait_us = Timer::since(enqueued).elapsed_us();
    shared.obs.record_queue_wait(0, queue_wait_us);
    // A queued resume handshake: a register that raced the device's
    // original registration.  The original register unit always precedes
    // it in the head lane, so by the time this runs the device is
    // registered (identity was already matched at dispatch) — ack the
    // resume without building anything.  (Had the original failed, this
    // item would have been drained with the entry.)
    {
        let mut reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get_mut(device).expect("registering device present");
        if st.registered {
            st.pending -= 1;
            st.ops_done = st.ops_done.saturating_add(1);
            st.queue_wait_us = st.queue_wait_us.saturating_add(queue_wait_us);
            respond(shared, &reply, id, Response::Registered {
                device: device.to_string(),
                resumed: true,
            });
            if st.has_work() {
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device.to_string());
                shared.ready_cv.notify_one();
            } else {
                st.queued = false;
            }
            drop(reg);
            note_done(shared, 1);
            return;
        }
    }
    type Built = (Session, Arc<Dataset>, Arc<Dataset>, u64, Option<u32>, bool);
    let exec = Timer::start();
    let heavy: std::result::Result<Built, RegisterFail> = (|| {
        if let Some(store) = &shared.store {
            let stored = store
                .get(device)
                .with_context(|| format!("device {device}: reading stored \
                                          state"))
                .map_err(store_fail)?;
            if let Some(snap) = stored {
                if snap.session.seed != seed || snap.session.method != method {
                    return Err(request_fail(anyhow!(
                        "device {device} exists in the state store with a \
                         different method or seed"
                    )));
                }
                let session = Session::rehydrate(&shared.backbone,
                                                 &snap.session)
                    .with_context(|| format!("device {device}: rehydrating \
                                              stored state"))
                    .map_err(store_fail)?;
                return Ok((session, snap.train, snap.test, snap.epochs_done,
                           snap.angle, true));
            }
        }
        crate::data::validate(&train, &shared.backbone.spec)
            .with_context(|| format!("registering {device}: train set"))
            .map_err(request_fail)?;
        crate::data::validate(&test, &shared.backbone.spec)
            .with_context(|| format!("registering {device}: test set"))
            .map_err(request_fail)?;
        let session = Session::builder()
            .backbone(Arc::clone(&shared.backbone))
            .method_boxed(method.plugin())
            .seed(seed)
            .limit(shared.limit)
            .eval_batch(shared.eval_batch)
            .track_pruning(false)
            .build()
            .with_context(|| format!("registering {device}"))
            .map_err(request_fail)?;
        // Static soundness gate (`crate::audit`): refuse or flag method
        // specs whose accumulators cannot be proven overflow-free under
        // this backbone + scale table — before any state is persisted.
        // Resumed registers skip this: they were audited when originally
        // registered and carry bit-identical state.
        if shared.audit != AuditPolicy::Off {
            let report = crate::audit::audit_backbone(&shared.backbone,
                                                      &method,
                                                      session.masks())
                .with_context(|| format!("registering {device}: audit"))
                .map_err(request_fail)?;
            if !report.sound() {
                if shared.audit == AuditPolicy::Reject {
                    return Err(request_fail(anyhow!(
                        "registering {device}: statically unsound: {}",
                        report.summary()
                    )));
                }
                eprintln!("[serve] audit warning for {device}: {}",
                          report.summary());
            }
            // Memory-fit gate (`crate::audit::mem`): with a device
            // profile configured, also require the (backbone, method)
            // plan to fit the target's SRAM/flash — priced at the
            // device protocol's batch-1 evaluation, with the session's
            // concrete masks for exact PRIOT-S state counts.
            if let Some(profile) = &shared.device_profile {
                let mem = crate::audit::mem::audit_mem_backbone(
                    &shared.backbone,
                    &method,
                    session.masks(),
                    1,
                    profile,
                )
                .with_context(|| format!("registering {device}: memory \
                                          audit"))
                .map_err(request_fail)?;
                if !mem.fits() {
                    if shared.audit == AuditPolicy::Reject {
                        return Err(request_fail(anyhow!(
                            "registering {device}: {}",
                            mem.summary()
                        )));
                    }
                    eprintln!("[serve] memory audit warning for {device}: {}",
                              mem.summary());
                }
            }
        }
        // Durable registration: the initial snapshot lands before the
        // ack, so a crash right after it can still resume the device.
        if let Some(store) = &shared.store {
            let t = Timer::start();
            let put =
                device_snapshot(&session, device, &train, &test, 0, angle)
                    .and_then(|snap| store.put(&snap));
            shared.obs.persist.record(t.elapsed_us());
            put.with_context(|| format!("device {device}: persisting \
                                         initial state"))
                .map_err(store_fail)?;
        }
        Ok((session, train, test, 0, angle, false))
    })();
    // The register execute span covers the whole build/resume (its
    // initial persist is also broken out into the `persist` stage).
    let execute_us = exec.elapsed_us();
    shared.obs.record_exec(Op::Register, execute_us);
    match heavy {
        Ok((mut session, train, test, epochs_done, angle, resumed)) => {
            if resumed {
                shared.rehydrations.fetch_add(1, Ordering::Relaxed);
            }
            drain_engine_counters(shared, &mut session);
            let mut reg = shared.registry.lock().expect("serve registry");
            reg.resident += 1;
            reg.tick += 1;
            let tick = reg.tick;
            let st =
                reg.map.get_mut(device).expect("registering device present");
            st.resident = Some(Resident {
                session: Some(session),
                train,
                test,
            });
            st.registered = true;
            st.epochs_done = epochs_done;
            st.angle = angle;
            st.dirty = false;
            st.last_used = tick;
            st.pending -= 1;
            st.ops_done = st.ops_done.saturating_add(1);
            st.queue_wait_us = st.queue_wait_us.saturating_add(queue_wait_us);
            st.execute_us = st.execute_us.saturating_add(execute_us);
            respond(shared, &reply, id, Response::Registered {
                device: device.to_string(),
                resumed,
            });
            if st.has_work() {
                shared
                    .ready
                    .lock()
                    .expect("serve ready queue")
                    .push_back(device.to_string());
                shared.ready_cv.notify_one();
            } else {
                st.queued = false;
            }
            drop(reg);
            note_done(shared, 1);
        }
        Err(RegisterFail { kind, err }) => {
            // The provisional entry disappears, and every request already
            // pipelined behind the failed register is answered too.
            let stray = {
                let mut reg = shared.registry.lock().expect("serve registry");
                let mut st = reg
                    .map
                    .remove(device)
                    .expect("registering device present");
                let stray: Vec<Item> = st
                    .lanes
                    .iter_mut()
                    .flat_map(|l| l.drain(..))
                    .collect();
                respond(shared, &reply, id, Response::Error {
                    device: device.to_string(),
                    kind,
                    message: format!("{err:#}"),
                });
                for s in &stray {
                    respond(shared, &s.reply, s.id, Response::Error {
                        device: device.to_string(),
                        kind: ErrorKind::Request,
                        message: format!(
                            "device {device}: register failed, request \
                             dropped"
                        ),
                    });
                }
                stray
            };
            note_done(shared, 1 + stray.len());
        }
    }
}

/// Rebuild an evicted device's session from the store (on the worker
/// pool — the caller holds the device's scheduling turn).
fn rehydrate_device(shared: &Shared, device: &str) -> Result<()> {
    let store = shared.store.as_ref().ok_or_else(|| {
        anyhow!("device {device} is not resident and no state store is \
                 configured")
    })?;
    let (seed, method) = {
        let reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get(device).expect("ready device registered");
        (st.seed, st.method.clone())
    };
    let snap = store
        .get(device)?
        .ok_or_else(|| anyhow!("device {device}: stored state is missing"))?;
    if snap.session.seed != seed || snap.session.method != method {
        bail!("device {device}: stored state does not match the registered \
               identity");
    }
    let session = Session::rehydrate(&shared.backbone, &snap.session)
        .with_context(|| format!("device {device}: rehydrating"))?;
    let mut reg = shared.registry.lock().expect("serve registry");
    reg.resident += 1;
    reg.tick += 1;
    let tick = reg.tick;
    let st = reg.map.get_mut(device).expect("device still registered");
    st.resident = Some(Resident {
        session: Some(session),
        train: snap.train,
        test: snap.test,
    });
    st.epochs_done = snap.epochs_done;
    st.angle = snap.angle;
    st.dirty = false;
    st.last_used = tick;
    shared.rehydrations.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Answer (and drop) the head pending item of a device whose session
/// could not be rehydrated — each queued item retries rehydration on its
/// own turn, so a transient store failure fails requests one at a time
/// instead of wedging the device.
fn fail_head_item(shared: &Shared, device: &str, e: anyhow::Error) {
    {
        let mut reg = shared.registry.lock().expect("serve registry");
        let st = reg.map.get_mut(device).expect("ready device registered");
        let lane = (0..Priority::COUNT)
            .find(|&l| !st.lanes[l].is_empty())
            .expect("ready device has work");
        let item = st.lanes[lane].pop_front().expect("non-empty lane");
        st.pending -= 1;
        respond(shared, &item.reply, item.id, Response::Error {
            device: device.to_string(),
            kind: ErrorKind::Store,
            message: format!("{e:#}"),
        });
        if st.has_work() {
            shared
                .ready
                .lock()
                .expect("serve ready queue")
                .push_back(device.to_string());
            shared.ready_cv.notify_one();
        } else {
            st.queued = false;
        }
    }
    note_done(shared, 1);
}
