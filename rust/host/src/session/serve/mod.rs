//! `priot::serve` — a long-lived fleet service behind the
//! [`crate::proto`] wire boundary.
//!
//! [`Fleet`](super::Fleet) runs a *closed* roster of devices to
//! completion; this module is the open-ended counterpart: a service that
//! owns one shared `Arc<`[`Backbone`]`>` plus a registry of per-device
//! [`Session`](super::Session)s and consumes a **stream** of
//! [`Request`](crate::proto::Request) frames from any number of
//! connected [`FleetClient`]s — register a device, train it some epochs,
//! classify an image, evaluate, or swap its local data when the
//! distribution drifts.
//!
//! Clients connect through a [`Transport`]: in-process over
//! [`FleetServer::local_client`] (mpsc frames) or over TCP via
//! [`FleetServer::listen`] + [`FleetClient::connect`].  Both paths run
//! the same codec and dispatch machinery, so responses are bit-identical
//! whichever transport carries them.
//!
//! The implementation is split by concern, with the concurrency
//! invariants documented at each seam: `registry` (the shared
//! scheduler state and its lock order), `ingress` (connection pumps
//! and the dispatcher), `workers` (the pool that executes ops and
//! persists state), `evict` (the resident-session LRU), and `trace`
//! (scripted request traces).  This file keeps the public surface:
//! [`ServeBuilder`], [`FleetServer`], [`ServeReport`], [`AuditPolicy`].
//!
//! ## Scheduling
//!
//! Work is *priority-laned* and *epoch-granular*:
//!
//! * Every queued unit is one operation of one device (one training
//!   epoch, one prediction, one evaluation).  A device with pending work
//!   re-queues at the back after each unit, so a device mid-adaptation
//!   never monopolizes a worker while other devices wait.
//! * Within a device, pending requests drain by
//!   [`Priority`](crate::proto::Priority) (predict > evaluate > train,
//!   FIFO within a class): an interactive prediction submitted behind a
//!   long `Train` is answered between training epochs instead of after
//!   all of them.  A multi-epoch `Train` materializes one epoch at a
//!   time, so it can be preempted at every epoch boundary.  `Drift`
//!   rides the training lane, preserving train → drift → train
//!   submission order.
//! * The dispatcher enforces a bounded per-device **inflight window**
//!   ([`ServeBuilder::window`]): a device with too many unanswered
//!   requests gets an immediate `Error` response instead of an unbounded
//!   backlog.
//! * **Heavy work never runs on the dispatcher thread.**  `Register` —
//!   dataset validation, session construction, store lookups — executes
//!   on the worker pool like everything else (the dispatcher only
//!   creates the registry entry and queues the register unit at the
//!   head of the device's lanes, so it is guaranteed to run before any
//!   op pipelined behind it).  One slow register therefore cannot stall
//!   dispatch for other connections.
//!
//! Operations of one device never run concurrently, so per-device
//! results are bit-identical to a standalone session executing the same
//! operations in the same order.  A synchronous client (one request in
//! flight) therefore sees exactly standalone behavior; pipelined clients
//! opt into priority reordering (pin everything to
//! [`Priority::Background`](crate::proto::Priority::Background) to keep
//! strict submission order).
//!
//! Evaluation goes through the batched forward path
//! ([`Session::evaluate_batch`](super::Session::evaluate_batch)) —
//! bit-identical to per-sample, faster.
//!
//! ## Durable state and the LRU of resident sessions
//!
//! With a [`StateStore`] attached ([`ServeBuilder::store`] /
//! [`ServeBuilder::state_dir`]), every device's state is **durable**:
//!
//! * Each completed state-mutating request (`Train`, `Drift`, the
//!   initial `Register`) writes the device's
//!   [`DeviceSnapshot`](crate::store::DeviceSnapshot) — exact-i32
//!   scores/masks/weights, step counter, datasets, epoch progress,
//!   drift-angle provenance — *before* its response is emitted, so any
//!   state a client has been told about survives a crash.
//! * [`ServeBuilder::resident_cap`]`(N)` bounds **live** sessions: the
//!   registry becomes an LRU over the store.  When more than `N`
//!   devices are resident, the least-recently-used *idle* device (no
//!   pending requests — eviction happens at op-queue idle points, never
//!   mid-request) is flushed and dropped from memory.  Any later
//!   request to an evicted device lazily rehydrates it on the worker
//!   pool — bit-identically, so an evicted-and-rehydrated device's
//!   responses are byte-equal to an always-resident one's.
//! * A `Register` for a device the server already knows — live,
//!   evicted, or recovered from a previous process (`priot serve
//!   --state-dir` rescans the store at startup, reading only snapshot
//!   *headers* — no dataset blob is materialized until a device
//!   actually rehydrates) — is a **resume**: state is kept, the
//!   supplied datasets are ignored, and the response says
//!   `resumed: true`, making reconnecting clients first-class.
//! * [`FleetServer::join`] flushes all dirty state; a restarted server
//!   over the same store resumes every device where it left off.
//!   Startup and shutdown also sweep unreferenced dataset blobs
//!   ([`StateStore::gc_blobs`]) — both are quiesced points, so the
//!   sweep can never race a writer.
//!
//! ```no_run
//! use priot::proto::{FleetClient, MethodSpec};
//! use priot::session::{Backbone, FleetServer};
//!
//! let backbone = Backbone::load("artifacts".as_ref(), "tinycnn")?;
//! # let (train, test): (std::sync::Arc<priot::serial::Dataset>,
//! #                     std::sync::Arc<priot::serial::Dataset>) = todo!();
//! let mut server = FleetServer::builder(backbone)
//!     .threads(4)
//!     .state_dir("fleet-state")?   // durable; restart-resumable
//!     .resident_cap(64)            // LRU-bound live sessions
//!     .build();
//! let addr = server.listen("127.0.0.1:0")?;   // or server.local_client()
//! let mut client = FleetClient::connect(addr)?;
//! client.register("dev-00", 1, MethodSpec::priot(), train, test)?;
//! client.train("dev-00", 2)?;
//! client.evaluate("dev-00")?;
//! drop(client);                    // close the connection...
//! let report = server.join()?;     // ...then drain + flush + shut down
//! println!("{}", report.summary());
//! # anyhow::Ok(())
//! ```
//!
//! The `priot serve` CLI subcommand drives a server from a scripted
//! request trace ([`parse_trace`]; [`DEMO_TRACE`] is a worked sample) or
//! listens on TCP (`--listen`, with `--state-dir`/`--resident-cap` for
//! durability); `priot client` replays a trace against a remote server.

mod evict;
mod ingress;
mod registry;
mod trace;
mod workers;

pub use trace::{parse_trace, replay_trace, TraceCmd, DEMO_TRACE};

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::StatsSnapshot;
use crate::proto::{
    ChannelTransport, FleetClient, Response, TcpTransport, Transport,
};
use crate::store::{DiskStore, MemStore, StateStore};

use super::Backbone;

use ingress::{dispatch, spawn_connection, Inbound};
use registry::{Clock, DeviceState, Registry, Shared};
use workers::{device_snapshot, worker};

/// Register-time static-soundness policy (see [`crate::audit`]): what to
/// do when a fresh `Register`'s (backbone, scales, method) combination
/// cannot be statically proven overflow-free.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AuditPolicy {
    /// No register-time audit (the default).
    #[default]
    Off,
    /// Audit and log unsound registrations to stderr, but accept them.
    Warn,
    /// Refuse unsound registrations with a request error.
    Reject,
}

/// One coherent reading of a live server's telemetry: the lock-free
/// [`crate::obs::ServeObs`] counters/histograms plus the per-device
/// totals kept under the registry lock.  Devices come out sorted by
/// name, so two snapshots of identical state render identically.
pub(super) fn stats_snapshot(shared: &Shared) -> StatsSnapshot {
    let mut snap = shared.obs.snapshot();
    {
        let reg = shared.registry.lock().expect("serve registry");
        snap.devices = reg
            .map
            .iter()
            .map(|(device, st)| crate::obs::DeviceStats {
                device: device.clone(),
                ops_done: st.ops_done,
                queue_wait_us: st.queue_wait_us,
                execute_us: st.execute_us,
            })
            .collect();
    }
    snap.devices.sort_by(|a, b| a.device.cmp(&b.device));
    snap
}

/// A cheap handle for reading a live server's telemetry from another
/// thread (`priot serve --listen --stats-interval N` dumps through one
/// while the server runs).  Obtained via [`FleetServer::stats_handle`];
/// reads never block request traffic.
#[derive(Clone)]
pub struct StatsHandle(Arc<Shared>);

impl StatsHandle {
    /// The server's telemetry right now (see
    /// [`crate::obs::StatsSnapshot`]).
    pub fn snapshot(&self) -> StatsSnapshot {
        stats_snapshot(&self.0)
    }
}

// ---------------------------------------------------------------------------
// The server handle
// ---------------------------------------------------------------------------

/// Builder for [`FleetServer`].
pub struct ServeBuilder {
    backbone: Arc<Backbone>,
    threads: usize,
    limit: usize,
    eval_batch: usize,
    window: usize,
    record: bool,
    store: Option<Arc<dyn StateStore>>,
    resident_cap: usize,
    audit: AuditPolicy,
    device_profile: Option<crate::audit::mem::DeviceProfile>,
}

impl ServeBuilder {
    /// Worker thread count (0 = available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Per-epoch / per-evaluation sample cap handed to every session
    /// (0 = all).
    pub fn limit(mut self, limit: usize) -> Self {
        self.limit = limit;
        self
    }

    /// Samples per forward in evaluation (bit-identical to per-sample;
    /// default 8).
    pub fn eval_batch(mut self, batch: usize) -> Self {
        self.eval_batch = batch;
        self
    }

    /// Per-device inflight window: the maximum accepted-but-unanswered
    /// requests one device may have queued.  Submissions beyond it are
    /// answered with an immediate `Error` instead of growing the backlog
    /// (0 = unbounded; default 64).
    pub fn window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Keep every response for the final [`ServeReport`] (default on).
    /// Turn it off for a long-lived listener that never `join()`s —
    /// responses still reach their clients, but the server no longer
    /// accumulates a copy of each one for the whole process lifetime.
    pub fn record(mut self, on: bool) -> Self {
        self.record = on;
        self
    }

    /// Attach a durable [`StateStore`]: device snapshots are written
    /// through on every completed state-mutating request, known devices
    /// found in the store at startup are resumable, and a `Register`
    /// for a stored device resumes it.
    pub fn store(mut self, store: Arc<dyn StateStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Convenience: attach a [`DiskStore`] rooted at `dir` (created if
    /// missing) — what `priot serve --state-dir DIR` uses.
    pub fn state_dir(self, dir: impl Into<std::path::PathBuf>)
                     -> Result<Self> {
        Ok(self.store(Arc::new(DiskStore::open(dir)?)))
    }

    /// Bound **live** sessions: at most `cap` devices keep their session
    /// (scores, masks, activation buffers) in memory; the least-recently-
    /// used idle devices beyond it are evicted to the store and lazily
    /// rehydrated on their next request — bit-identically.  0 (the
    /// default) = unbounded.  Setting a cap without a store attaches a
    /// [`MemStore`] automatically (eviction needs somewhere to put
    /// state).
    pub fn resident_cap(mut self, cap: usize) -> Self {
        self.resident_cap = cap;
        self
    }

    /// Register-time static-soundness policy (default
    /// [`AuditPolicy::Off`]): with [`AuditPolicy::Reject`] a fresh
    /// `Register` whose method spec cannot be statically proven
    /// overflow-free under this backbone's weights and scale table is
    /// answered with a request error instead of creating a device —
    /// what `priot serve --audit reject` sets.
    pub fn audit(mut self, policy: AuditPolicy) -> Self {
        self.audit = policy;
        self
    }

    /// Register-time memory-fit target (default none): with a profile
    /// set and the audit policy not [`AuditPolicy::Off`], a fresh
    /// `Register` whose (backbone, method) statically exceeds the
    /// device's SRAM or flash budget — per `priot::audit::mem`, at the
    /// device protocol's batch-1 evaluation — is refused (Reject) or
    /// logged (Warn) exactly like an unsound one — what
    /// `priot serve --device rp2040` sets.
    pub fn device_profile(
        mut self,
        profile: crate::audit::mem::DeviceProfile,
    ) -> Self {
        self.device_profile = Some(profile);
        self
    }

    /// Spawn the dispatcher + worker pool and return the live handle.
    pub fn build(self) -> FleetServer {
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        let store = self.store.or_else(|| {
            (self.resident_cap > 0).then(|| {
                Arc::new(MemStore::new()) as Arc<dyn StateStore>
            })
        });
        let resident_cap = if self.resident_cap == 0 {
            usize::MAX
        } else {
            self.resident_cap
        };
        // Restart-resume: every device the store already knows becomes a
        // registered (evicted) entry, so a `Train` straight after a
        // restart rehydrates lazily and a `Register` resumes.  The scan
        // reads snapshot *headers* only — recovering a thousand-device
        // fleet materializes zero dataset blobs.
        let mut registry =
            Registry { map: HashMap::new(), resident: 0, tick: 0 };
        if let Some(store) = &store {
            match store.devices() {
                Ok(devices) => {
                    for device in devices {
                        match store.get_body(&device) {
                            Ok(Some(body))
                                if body.session.model == self.backbone.model =>
                            {
                                registry.map.insert(
                                    device,
                                    DeviceState::from_body(&body),
                                );
                            }
                            Ok(Some(body)) => eprintln!(
                                "[serve] skipping stored device {device}: \
                                 snapshot is for model {}, serving {}",
                                body.session.model, self.backbone.model
                            ),
                            Ok(None) => {}
                            Err(e) => eprintln!(
                                "[serve] skipping stored device {device}: \
                                 {e:#}"
                            ),
                        }
                    }
                }
                Err(e) => {
                    eprintln!("[serve] scanning the state store: {e:#}");
                }
            }
            // No workers exist yet, so nothing can race the sweep of
            // blobs orphaned by removes or by a crash between a blob
            // write and its body write.  Non-fatal: serving works fine
            // with dead blobs on disk.
            if let Err(e) = store.gc_blobs() {
                eprintln!("[serve] startup blob GC: {e:#}");
            }
        }
        let shared = Arc::new(Shared {
            backbone: self.backbone,
            limit: self.limit,
            eval_batch: self.eval_batch,
            window: if self.window == 0 { usize::MAX } else { self.window },
            audit: self.audit,
            device_profile: self.device_profile,
            store,
            resident_cap,
            registry: Mutex::new(registry),
            ready: Mutex::new(VecDeque::new()),
            ready_cv: Condvar::new(),
            done: AtomicBool::new(false),
            outstanding: Mutex::new(0),
            idle_cv: Condvar::new(),
            requests: AtomicU64::new(0),
            rehydrations: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            record: Mutex::new(Vec::new()),
            record_enabled: self.record,
            clock: Mutex::new(Clock::default()),
            accepting: AtomicBool::new(true),
            conns: Mutex::new(Vec::new()),
            obs: crate::obs::ServeObs::default(),
        });
        let (itx, irx) = channel::<Inbound>();
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || dispatch(&shared, irx))
        };
        let workers = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker(&shared))
            })
            .collect();
        FleetServer {
            shared,
            ingress: Some(itx),
            dispatcher: Some(dispatcher),
            workers,
            acceptor: None,
            threads,
        }
    }
}

/// The long-lived fleet service: one shared backbone, a registry of
/// per-device sessions (optionally LRU-bounded over a durable
/// [`StateStore`]), a dispatcher thread feeding priority-laned
/// per-device queues, and a worker pool draining them.  Clients talk to
/// it exclusively through [`FleetClient`] — see the module docs.
pub struct FleetServer {
    shared: Arc<Shared>,
    ingress: Option<Sender<Inbound>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    acceptor: Option<JoinHandle<()>>,
    threads: usize,
}

impl FleetServer {
    pub fn builder(backbone: Arc<Backbone>) -> ServeBuilder {
        ServeBuilder {
            backbone,
            threads: 0,
            limit: 0,
            eval_batch: 8,
            window: 64,
            record: true,
            store: None,
            resident_cap: 0,
            audit: AuditPolicy::Off,
            device_profile: None,
        }
    }

    /// Connect an in-process client over a [`ChannelTransport`] — the
    /// successor of the old raw `mpsc::Sender<Request>` front door, now
    /// running the same codec and dispatch path as TCP connections.
    ///
    /// **Lifetime contract:** the dispatcher only shuts down once every
    /// connection has closed.  [`Self::join`] waits for that — so drop
    /// all clients (ending their connections) before calling `join`, or
    /// it will block until they are gone.
    pub fn local_client(&self) -> FleetClient {
        let (client_end, server_end) = ChannelTransport::pair();
        let (stx, srx) = server_end.into_parts();
        let ingress = self.ingress.as_ref().expect("server joined").clone();
        spawn_connection(
            &self.shared,
            ingress,
            move |frame| stx.send(frame).is_ok(),
            move || Ok(srx.recv().ok()),
        );
        FleetClient::over(client_end)
    }

    /// The server's telemetry right now — the same
    /// [`StatsSnapshot`] a [`crate::proto::Request::GetStats`] returns.
    pub fn stats(&self) -> StatsSnapshot {
        stats_snapshot(&self.shared)
    }

    /// A clonable telemetry handle usable from other threads while the
    /// server runs (see [`StatsHandle`]).
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle(Arc::clone(&self.shared))
    }

    /// Accept TCP clients on `addr` (e.g. `"127.0.0.1:0"` for an
    /// ephemeral loopback port).  Returns the bound address; connect
    /// with [`FleetClient::connect`].
    pub fn listen(&mut self, addr: &str) -> Result<SocketAddr> {
        if self.acceptor.is_some() {
            bail!("server is already listening");
        }
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding fleet listener on {addr}"))?;
        let local = listener.local_addr()?;
        // Non-blocking accept so the acceptor can observe shutdown.
        listener
            .set_nonblocking(true)
            .context("configuring the fleet listener")?;
        let shared = Arc::clone(&self.shared);
        let ingress = self.ingress.as_ref().expect("server joined").clone();
        self.acceptor = Some(std::thread::spawn(move || {
            while shared.accepting.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // Accepted sockets must not inherit the
                        // listener's non-blocking mode.
                        let _ = stream.set_nonblocking(false);
                        let wstream = match stream.try_clone() {
                            Ok(s) => s,
                            // Connection unusable before it started.
                            Err(_) => continue,
                        };
                        let mut wt = TcpTransport::from_stream(wstream);
                        let mut rt = TcpTransport::from_stream(stream);
                        spawn_connection(
                            &shared,
                            ingress.clone(),
                            move |frame| wt.send(frame).is_ok(),
                            move || rt.recv(),
                        );
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        }));
        Ok(local)
    }

    /// Graceful shutdown: stop accepting connections, finish every
    /// accepted request, stop the pool, **flush all dirty device state
    /// to the store**, and return everything the run produced.
    ///
    /// Blocks until every connection has closed — drop your
    /// [`FleetClient`]s first (see [`Self::local_client`]).
    pub fn join(mut self) -> Result<ServeReport> {
        self.ingress.take(); // our own ingress handle
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            a.join().map_err(|_| anyhow!("serve acceptor panicked"))?;
        }
        // The dispatcher exits once every connection reader has dropped
        // its ingress handle (i.e. every client disconnected).
        if let Some(d) = self.dispatcher.take() {
            d.join().map_err(|_| anyhow!("serve dispatcher panicked"))?;
        }
        {
            let mut out =
                self.shared.outstanding.lock().expect("serve outstanding");
            while *out > 0 {
                out = self.shared.idle_cv.wait(out).expect("serve outstanding");
            }
        }
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow!("serve worker panicked"))?;
        }
        // Flush whatever the write-through path could not persist (a
        // device is only dirty here if an earlier store write failed),
        // so a restarted server resumes exactly this state.
        if let Some(store) = &self.shared.store {
            {
                let reg = self.shared.registry.lock().expect("serve registry");
                for (device, st) in reg.map.iter() {
                    if !st.dirty {
                        continue;
                    }
                    let Some(res) = &st.resident else { continue };
                    let Some(session) = &res.session else { continue };
                    let flushed = device_snapshot(session, device, &res.train,
                                                  &res.test, st.epochs_done,
                                                  st.angle)
                        .and_then(|snap| store.put(&snap));
                    if let Err(e) = flushed {
                        eprintln!("[serve] final flush of {device}: {e:#}");
                    }
                }
            }
            // Workers are joined and dirty state is flushed: a quiesced
            // point, so the blob sweep cannot race a writer.  Non-fatal,
            // like the flush itself.
            if let Err(e) = store.gc_blobs() {
                eprintln!("[serve] shutdown blob GC: {e:#}");
            }
        }
        // Connection pumps exit once their peer is gone and their queued
        // responses are flushed (all Reply handles were dropped above).
        let conns: Vec<JoinHandle<()>> = {
            let mut c = self.shared.conns.lock().expect("serve connections");
            c.drain(..).collect()
        };
        for c in conns {
            c.join().map_err(|_| anyhow!("serve connection pump panicked"))?;
        }
        let responses =
            std::mem::take(&mut *self.shared.record.lock().expect("record"));
        let clock = self.shared.clock.lock().expect("serve clock");
        let wall_secs = match (clock.first_request, clock.last_response) {
            (Some(t0), Some(t1)) => {
                t1.saturating_duration_since(t0).as_secs_f64()
            }
            _ => 0.0,
        };
        drop(clock);
        // Telemetry reads last, after every worker/pump has joined, so
        // the report's snapshot covers the complete run.
        let stats = stats_snapshot(&self.shared);
        Ok(ServeReport {
            responses,
            requests: self.shared.requests.load(Ordering::Relaxed),
            rehydrations: self.shared.rehydrations.load(Ordering::Relaxed),
            evictions: self.shared.evictions.load(Ordering::Relaxed),
            wall_secs,
            threads: self.threads,
            queue_high_water: stats.queue_high_water,
            stats,
        })
    }
}

impl Drop for FleetServer {
    /// Abort path (no [`Self::join`]): stop accepting, let the pool
    /// drain what is already queued, and reap what can be reaped without
    /// blocking on live clients.  The dispatcher and per-connection
    /// pumps exit on their own once every client disconnects, so they
    /// are *detached*, not joined — dropping a server with a client
    /// still attached must not hang the dropping thread.  Requests
    /// submitted after the drop are answered with an `Error` by the
    /// detached dispatcher; a request racing the drop itself may go
    /// unanswered (an aborting server makes no delivery promises).  No
    /// final store flush runs — but the write-through path has already
    /// persisted every state a client was told about, so a store-backed
    /// fleet still resumes to the last acknowledged state.
    /// No-op after `join()` (which consumed the handles already).
    fn drop(&mut self) {
        self.ingress.take();
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Detach the dispatcher: it exits once every connection reader
        // has dropped its ingress handle (i.e. every client is gone).
        self.dispatcher.take();
        self.shared.signal_done();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Connection pumps are likewise detached; their handles are
        // freed with `Shared` when the last thread holding it exits.
    }
}

/// Everything one server run produced.
pub struct ServeReport {
    /// Responses in completion order (per device: execution order).
    pub responses: Vec<Response>,
    pub requests: u64,
    /// Sessions rebuilt from the state store (lazy rehydrations of
    /// evicted devices + resumed registers).
    pub rehydrations: u64,
    /// Idle devices flushed out of memory under `resident_cap` pressure.
    pub evictions: u64,
    /// First request received → last response emitted.  Idle time before
    /// traffic arrives does not count against requests/sec.
    pub wall_secs: f64,
    pub threads: usize,
    /// Most accepted-but-unanswered requests ever outstanding at once
    /// (also in [`Self::stats`]; surfaced here because it pairs with
    /// the throughput numbers).
    pub queue_high_water: u64,
    /// The run's full telemetry snapshot: per-op request counts,
    /// lifecycle-stage latency histograms, engine perf counters, and
    /// per-device totals (see [`crate::obs::StatsSnapshot`]).
    pub stats: StatsSnapshot,
}

impl ServeReport {
    /// Requests per second of serving wall time.  A run whose serving
    /// clock never spanned anything (no request was ever answered, or
    /// the span was below clock resolution) reports 0.0 — never an
    /// inf/NaN division artifact.
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_secs < 1e-9 {
            0.0
        } else {
            self.requests as f64 / self.wall_secs
        }
    }

    /// Rehydrations per second of serving wall time (the LRU churn rate
    /// under eviction pressure — what the `serve` bench tracks).  Guarded
    /// like [`Self::requests_per_sec`].
    pub fn rehydrations_per_sec(&self) -> f64 {
        if self.wall_secs < 1e-9 {
            0.0
        } else {
            self.rehydrations as f64 / self.wall_secs
        }
    }

    pub fn errors(&self) -> usize {
        self.responses.iter().filter(|r| r.is_error()).count()
    }

    /// This device's responses, in its execution order.
    pub fn for_device<'a>(&'a self, device: &str) -> Vec<&'a Response> {
        self.responses.iter().filter(|r| r.device() == device).collect()
    }

    /// One-paragraph run summary.
    pub fn summary(&self) -> String {
        let mut kinds: HashMap<&'static str, usize> = HashMap::new();
        for r in &self.responses {
            let k = match r {
                Response::Registered { .. } => "registered",
                Response::TrainDone { .. } => "train-done",
                Response::Prediction { .. } => "predictions",
                Response::Evaluation { .. } => "evaluations",
                Response::Drifted { .. } => "drifts",
                Response::Stats { .. } => "stats",
                Response::Error { .. } => "errors",
            };
            *kinds.entry(k).or_insert(0) += 1;
        }
        let mut parts: Vec<String> =
            kinds.iter().map(|(k, v)| format!("{v} {k}")).collect();
        parts.sort();
        let mut out = format!(
            "{} requests in {:.2}s on {} threads — {:.1} requests/s ({})",
            self.requests,
            self.wall_secs,
            self.threads,
            self.requests_per_sec(),
            parts.join(", ")
        );
        if self.rehydrations > 0 || self.evictions > 0 {
            out.push_str(&format!(
                "; {} rehydrations, {} evictions",
                self.rehydrations, self.evictions
            ));
        }
        out
    }
}
