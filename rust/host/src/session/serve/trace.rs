//! Scripted request traces — the `priot serve` / `priot client`
//! front-ends.  A trace is a deterministic, human-writable script of
//! fleet requests; replaying one synchronously produces a result stream
//! that is bit-identical across transports and to a standalone session
//! executing the same operations.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Method;
use crate::proto::{FleetClient, MethodSpec, Response};
use crate::serial::Dataset;

/// One line of a scripted request trace.  Datasets stay symbolic (an
/// `angle` into the artifact data) — the CLI resolves them to files.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceCmd {
    Register { device: String, seed: u32, method: MethodSpec, angle: u32 },
    Train { device: String, epochs: usize },
    /// Classify sample `sample` of the device's current test set.
    Predict { device: String, sample: usize },
    Evaluate { device: String },
    Drift { device: String, angle: u32 },
}

/// A worked sample trace (also what `priot serve` runs when no `--trace`
/// file is given): two devices with different methods and local drifts —
/// including an arbitrary-angle drift (60°), which the CLI resolves by
/// generating the dataset in-process when no artifact exists
/// ([`crate::data::DataSource`]).
pub const DEMO_TRACE: &str = "\
# priot serve demo trace: <verb> <device> [key=value]...
register dev-a seed=1 method=priot angle=30
register dev-b seed=2 method=priot-s frac=0.1 selection=weight angle=45
train dev-a epochs=2
train dev-b epochs=2
predict dev-a sample=0
predict dev-b sample=3
evaluate dev-a
evaluate dev-b
drift dev-a 45           # drift takes its angle positionally too
train dev-a epochs=1
evaluate dev-a
drift dev-b 60           # any angle: no 60-degree artifact is ever built
train dev-b epochs=1
evaluate dev-b
";

/// Parse a request trace: one command per line, `# comments` and blank
/// lines ignored.  Grammar per line: `<verb> <device> [key=value]...`
/// with verbs `register | train | predict | evaluate | drift`; `drift`
/// also accepts its angle positionally (`drift dev0 60`).
pub fn parse_trace(text: &str) -> Result<Vec<TraceCmd>> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        out.push(parse_trace_line(line)
            .with_context(|| format!("trace line {}: {line}", ln + 1))?);
    }
    Ok(out)
}

fn parse_trace_line(line: &str) -> Result<TraceCmd> {
    let mut it = line.split_whitespace();
    let verb = it.next().expect("non-empty line");
    let device = it
        .next()
        .ok_or_else(|| anyhow!("missing device name"))?
        .to_string();
    let mut kv: HashMap<&str, &str> = HashMap::new();
    let mut positional: Vec<&str> = Vec::new();
    for tok in it {
        match tok.split_once('=') {
            Some((k, v)) => {
                kv.insert(k, v);
            }
            None => positional.push(tok),
        }
    }
    if verb != "drift" && !positional.is_empty() {
        bail!("unexpected value {} (expected key=value)", positional[0]);
    }
    let get_usize = |kv: &HashMap<&str, &str>, k: &str, d: usize| -> Result<usize> {
        match kv.get(k) {
            None => Ok(d),
            Some(v) => v.parse().with_context(|| format!("{k}={v}")),
        }
    };
    Ok(match verb {
        "register" => {
            let method = Method::parse(kv.get("method").copied().unwrap_or("priot"))?;
            let selection = crate::config::Selection::parse(
                kv.get("selection").copied().unwrap_or("weight"))?;
            let frac_scored = match kv.get("frac") {
                None => 0.1,
                Some(v) => v.parse().with_context(|| format!("frac={v}"))?,
            };
            let theta = match kv.get("theta") {
                None => None,
                Some(v) => {
                    Some(v.parse().with_context(|| format!("theta={v}"))?)
                }
            };
            TraceCmd::Register {
                device,
                seed: get_usize(&kv, "seed", 1)? as u32,
                method: MethodSpec { method, frac_scored, selection, theta },
                angle: get_usize(&kv, "angle", 30)? as u32,
            }
        }
        "train" => TraceCmd::Train {
            device,
            epochs: get_usize(&kv, "epochs", 1)?,
        },
        "predict" => TraceCmd::Predict {
            device,
            sample: get_usize(&kv, "sample", 0)?,
        },
        "evaluate" => TraceCmd::Evaluate { device },
        "drift" => {
            // Arbitrary drift angles, positionally or as angle=N — no
            // hardcoded 30°/45° pair.
            let angle = match (positional.as_slice(), kv.get("angle")) {
                ([], None) => 45,
                ([], Some(v)) => {
                    v.parse().with_context(|| format!("angle={v}"))?
                }
                ([one], None) => one
                    .parse()
                    .with_context(|| format!("drift angle {one}"))?,
                ([_], Some(_)) => {
                    bail!("drift angle given both positionally and as angle=")
                }
                (more, _) => bail!("too many values: {}", more.join(" ")),
            };
            TraceCmd::Drift { device, angle }
        }
        other => bail!("unknown trace verb {other} \
                        (want register|train|predict|evaluate|drift)"),
    })
}

/// Replay a parsed trace over a connected client, one synchronous
/// request at a time (so per-device order is submission order and the
/// result stream is deterministic — bit-identical across transports and
/// to a standalone [`Session`](crate::session::Session) executing the
/// same operations).  `pair_for` resolves a symbolic drift angle to its
/// datasets; the angle travels with `Register`/`Drift` as provenance, so
/// durable snapshots record which rotation a device's data came from.
pub fn replay_trace(
    client: &mut FleetClient,
    cmds: &[TraceCmd],
    pair_for: &mut dyn FnMut(u32) -> Result<(Arc<Dataset>, Arc<Dataset>)>,
) -> Result<Vec<Response>> {
    let mut device_test: HashMap<String, Arc<Dataset>> = HashMap::new();
    let mut out = Vec::with_capacity(cmds.len());
    for cmd in cmds {
        let resp = match cmd.clone() {
            TraceCmd::Register { device, seed, method, angle } => {
                let (train, test) = pair_for(angle)?;
                device_test.insert(device.clone(), Arc::clone(&test));
                client.register_at(&device, seed, method, train, test,
                                   Some(angle))?
            }
            TraceCmd::Train { device, epochs } => {
                client.train(&device, epochs)?
            }
            TraceCmd::Predict { device, sample } => {
                let test = device_test.get(&device).ok_or_else(|| anyhow!(
                    "trace predicts on unregistered device {device}"))?;
                if test.n == 0 {
                    bail!("trace predicts on device {device}, whose test \
                           set is empty");
                }
                let image = test.image(sample % test.n).to_vec();
                client.predict(&device, image)?
            }
            TraceCmd::Evaluate { device } => client.evaluate(&device)?,
            TraceCmd::Drift { device, angle } => {
                let (train, test) = pair_for(angle)?;
                device_test.insert(device.clone(), Arc::clone(&test));
                client.drift_at(&device, train, test, Some(angle))?
            }
        };
        out.push(resp);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Selection;

    #[test]
    fn parse_trace_demo_roundtrip() {
        let cmds = parse_trace(DEMO_TRACE).unwrap();
        assert_eq!(cmds.len(), 14);
        assert_eq!(cmds[0], TraceCmd::Register {
            device: "dev-a".into(),
            seed: 1,
            method: MethodSpec {
                method: Method::Priot,
                frac_scored: 0.1,
                selection: Selection::WeightBased,
                theta: None,
            },
            angle: 30,
        });
        assert_eq!(cmds[2], TraceCmd::Train { device: "dev-a".into(), epochs: 2 });
        assert_eq!(cmds[8], TraceCmd::Drift { device: "dev-a".into(), angle: 45 });
    }

    #[test]
    fn parse_trace_rejects_garbage() {
        assert!(parse_trace("launch dev-a").is_err(), "unknown verb");
        assert!(parse_trace("train").is_err(), "missing device");
        assert!(parse_trace("train dev-a epochs").is_err(), "bare key");
        assert!(parse_trace("train dev-a epochs=three").is_err(), "bad value");
        assert!(parse_trace("register d method=sgd").is_err(), "bad method");
        let err = parse_trace("ok-line dev\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn parse_trace_drift_takes_arbitrary_angles() {
        // Positional, keyed, and defaulted forms; no hardcoded 30/45 pair.
        let cmds =
            parse_trace("drift d0 60\ndrift d1 angle=135\ndrift d2").unwrap();
        assert_eq!(cmds[0], TraceCmd::Drift { device: "d0".into(), angle: 60 });
        assert_eq!(cmds[1], TraceCmd::Drift { device: "d1".into(), angle: 135 });
        assert_eq!(cmds[2], TraceCmd::Drift { device: "d2".into(), angle: 45 });

        assert!(parse_trace("drift d0 60 angle=45").is_err(),
                "positional + keyed angle is ambiguous");
        assert!(parse_trace("drift d0 60 70").is_err(), "two positionals");
        assert!(parse_trace("drift d0 sixty").is_err(), "non-numeric angle");
        // Positional values stay drift-only.
        assert!(parse_trace("train d0 3").is_err(),
                "train takes epochs=N, not a positional");
    }

    #[test]
    fn method_spec_builds_plugins() {
        let m = MethodSpec {
            method: Method::PriotS,
            frac_scored: 0.2,
            selection: Selection::Random,
            theta: Some(-5),
        };
        assert_eq!(m.plugin().name(), "priot-s");
        let m = MethodSpec::niti_static();
        assert_eq!(m.plugin().name(), "static-niti");
    }
}
