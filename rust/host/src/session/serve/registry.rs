//! Shared scheduler state: the per-device registry, its priority lanes,
//! and the counters every other serve module reports through.
//!
//! Everything sits behind one [`Shared`] per server, used by the
//! dispatcher ([`super::ingress`]), the worker pool
//! ([`super::workers`]), the evictor ([`super::evict`]), and the
//! connection pumps.  The invariants the whole module tree leans on:
//!
//! * **Lock order:** `registry` before `ready`/`outstanding`/`record`/
//!   `clock`; none of those four is ever held while taking another of
//!   them or `registry`.
//! * **One turn per device:** a device appears in the ready queue at
//!   most once ([`DeviceState::queued`]), so two workers can never run
//!   ops of the same device concurrently — the property that keeps a
//!   served device's results bit-identical to a standalone session
//!   executing the same ops in the same order.
//! * **Lanes drain by priority:** pending items sit in
//!   [`Priority::COUNT`] FIFO lanes; schedulers always pop the
//!   lowest-numbered non-empty lane (predict > evaluate > train).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::proto::{MethodSpec, Priority, Response};
use crate::serial::Dataset;
use crate::session::{Backbone, Session};
use crate::store::{codec::SnapshotBody, StateStore};

use super::ingress::Reply;
use super::AuditPolicy;

/// The pending work of one accepted request.  A multi-epoch `Train` is a
/// single item that yields one epoch per turn at the device — the unit
/// the priority lanes preempt at.
pub(super) enum Work {
    /// Build (or resume) the device's session — always the device's
    /// first unit, executed on the worker pool (never the dispatcher).
    Register {
        seed: u32,
        method: MethodSpec,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
        angle: Option<u32>,
    },
    Train { remaining: usize, done: usize, steps: u64 },
    Predict { image: Vec<u8> },
    Evaluate,
    Drift { train: Arc<Dataset>, test: Arc<Dataset>, angle: Option<u32> },
}

/// One queued request: its id, reply route, and pending work.
pub(super) struct Item {
    pub(super) id: u64,
    pub(super) reply: Reply,
    pub(super) work: Work,
    /// When the item (re-)entered its lane — the queue-wait span start.
    /// A multi-epoch `Train` resets it on every re-queue, so each epoch
    /// measures its own lane wait.
    pub(super) enqueued: Instant,
}

/// A device's in-memory presence: its live session (taken by the worker
/// executing its current op) and its current datasets.  `None` on the
/// [`DeviceState`] = the device is evicted (state lives in the store).
pub(super) struct Resident {
    /// `None` while a worker has the session checked out.
    pub(super) session: Option<Session>,
    pub(super) train: Arc<Dataset>,
    pub(super) test: Arc<Dataset>,
}

pub(super) struct DeviceState {
    /// Live state, or `None` for an evicted / not-yet-rehydrated device.
    pub(super) resident: Option<Resident>,
    /// Registration identity — a later `Register` must match to resume.
    pub(super) seed: u32,
    pub(super) method: MethodSpec,
    /// False until the register unit completes (the entry is provisional
    /// and its lanes start with the register item, which runs first).
    pub(super) registered: bool,
    /// True while an evictor is flushing this device to the store; a
    /// worker that pops the device meanwhile steps aside and retries.
    pub(super) evicting: bool,
    /// Pending items by [`Priority`] lane; FIFO within a lane.  A device
    /// appears in the ready queue iff `queued` — never twice, so its ops
    /// can never run concurrently.
    pub(super) lanes: [VecDeque<Item>; Priority::COUNT],
    pub(super) queued: bool,
    /// Accepted, unanswered requests (the inflight-window count).
    pub(super) pending: usize,
    /// Completed training epochs over the device's lifetime.
    pub(super) epochs_done: u64,
    /// Data provenance of the current datasets, when the client said.
    pub(super) angle: Option<u32>,
    /// In-memory state is newer than the store (a failed write-through
    /// leaves this set; eviction and `join()` retry the flush).
    pub(super) dirty: bool,
    /// LRU clock value of the device's last checkout.
    pub(super) last_used: u64,
    /// Telemetry: completed worker units (epochs count individually).
    /// Accumulated under the registry lock the workers already hold.
    pub(super) ops_done: u64,
    /// Telemetry: total lane-wait microseconds across this device's
    /// units.
    pub(super) queue_wait_us: u64,
    /// Telemetry: total execute microseconds across this device's units.
    pub(super) execute_us: u64,
}

impl DeviceState {
    pub(super) fn new(seed: u32, method: MethodSpec) -> Self {
        Self {
            resident: None,
            seed,
            method,
            registered: false,
            evicting: false,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            queued: false,
            pending: 0,
            epochs_done: 0,
            angle: None,
            dirty: false,
            last_used: 0,
            ops_done: 0,
            queue_wait_us: 0,
            execute_us: 0,
        }
    }

    /// A registered-but-evicted entry recovered from the store at
    /// startup: requests rehydrate it lazily; a `Register` resumes it.
    /// Takes the snapshot *body* — the startup scan never materializes
    /// dataset blobs ([`StateStore::get_body`]).
    pub(super) fn from_body(body: &SnapshotBody) -> Self {
        let mut st = Self::new(body.session.seed, body.session.method.clone());
        st.registered = true;
        st.epochs_done = body.epochs_done;
        st.angle = body.angle;
        st
    }

    pub(super) fn has_work(&self) -> bool {
        self.lanes.iter().any(|l| !l.is_empty())
    }
}

/// The device registry plus its LRU bookkeeping, under one lock.
pub(super) struct Registry {
    pub(super) map: HashMap<String, DeviceState>,
    /// Devices with `resident.is_some()` (the LRU size).
    pub(super) resident: usize,
    /// Monotonic LRU clock.
    pub(super) tick: u64,
}

/// Serving clock: requests/sec covers first request → last response, not
/// idle time before traffic arrives.
#[derive(Default)]
pub(super) struct Clock {
    pub(super) first_request: Option<Instant>,
    pub(super) last_response: Option<Instant>,
}

pub(super) struct Shared {
    pub(super) backbone: Arc<Backbone>,
    pub(super) limit: usize,
    pub(super) eval_batch: usize,
    pub(super) window: usize,
    /// Register-time static-soundness policy (fresh registers only;
    /// resumes were audited at original registration).
    pub(super) audit: AuditPolicy,
    /// Register-time memory-fit target: with `Some(profile)` and
    /// `audit != Off`, fresh registers whose static memory plan
    /// (`crate::audit::mem`, batch-1 eval) exceeds the profile are
    /// refused/flagged under the same policy as unsound ones.
    pub(super) device_profile: Option<crate::audit::mem::DeviceProfile>,
    /// Durable snapshot store; `None` = memory-only serving (no
    /// eviction, no resume).
    pub(super) store: Option<Arc<dyn StateStore>>,
    /// Maximum resident sessions (`usize::MAX` = unbounded).
    pub(super) resident_cap: usize,
    /// Devices + LRU state.  Lock order: `registry` before
    /// `ready`/`outstanding`/`record`/`clock`; none of those four is
    /// ever held while taking another of them or `registry`.
    pub(super) registry: Mutex<Registry>,
    /// Devices with pending work, round-robin.
    pub(super) ready: Mutex<VecDeque<String>>,
    pub(super) ready_cv: Condvar,
    pub(super) done: AtomicBool,
    /// Accepted op-requests not yet answered (drives graceful shutdown).
    pub(super) outstanding: Mutex<usize>,
    pub(super) idle_cv: Condvar,
    pub(super) requests: AtomicU64,
    /// Sessions rebuilt from the store (lazy rehydrations + resumed
    /// registers).
    pub(super) rehydrations: AtomicU64,
    /// Idle devices flushed out of memory under `resident_cap` pressure.
    pub(super) evictions: AtomicU64,
    /// Every response the run produced, completion order (the
    /// [`super::ServeReport`] source — per-connection streams are routed
    /// separately via [`Reply`]).
    pub(super) record: Mutex<Vec<Response>>,
    /// Recording off = a long-lived server (`priot serve --listen`) that
    /// never `join()`s does not grow `record` without bound.
    pub(super) record_enabled: bool,
    pub(super) clock: Mutex<Clock>,
    pub(super) accepting: AtomicBool,
    pub(super) conns: Mutex<Vec<JoinHandle<()>>>,
    /// Request-lifecycle telemetry (see [`crate::obs`]): every serve
    /// module records through this — lock-free counters and histograms,
    /// snapshot on demand.
    pub(super) obs: crate::obs::ServeObs,
}

impl Shared {
    /// Tell the worker pool to exit.  The store must synchronize through
    /// the `ready` mutex: a worker that saw `done == false` keeps the
    /// mutex until it is parked inside `ready_cv.wait`, so passing
    /// through the lock before notifying guarantees the wakeup is not
    /// lost between its check and its wait.
    pub(super) fn signal_done(&self) {
        self.done.store(true, Ordering::SeqCst);
        drop(self.ready.lock().expect("serve ready queue"));
        self.ready_cv.notify_all();
    }
}

/// Record a response (when recording is on) and route it to its
/// connection.
pub(super) fn respond(shared: &Shared, reply: &Reply, id: u64, resp: Response) {
    shared.obs.note_response(resp.is_error());
    shared.clock.lock().expect("serve clock").last_response =
        Some(Instant::now());
    if shared.record_enabled {
        shared.record.lock().expect("serve record").push(resp.clone());
    }
    let _ = reply.0.send((id, resp));
}

/// Count one received request and start the serving clock on the first.
pub(super) fn note_request(shared: &Shared) {
    shared.requests.fetch_add(1, Ordering::Relaxed);
    let mut clock = shared.clock.lock().expect("serve clock");
    if clock.first_request.is_none() {
        clock.first_request = Some(Instant::now());
    }
}

/// Close out one answered op-request (graceful shutdown accounting).
pub(super) fn note_done(shared: &Shared, n: usize) {
    let mut out = shared.outstanding.lock().expect("serve outstanding");
    *out -= n;
    if *out == 0 {
        shared.idle_cv.notify_all();
    }
}
