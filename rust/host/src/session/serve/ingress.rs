//! Ingress: per-connection frame pumps and the dispatcher thread.
//!
//! Every connection flavor — in-process channel or TCP socket — gets the
//! same pair of pump threads (a reader decoding request frames, a writer
//! encoding responses) feeding the single dispatcher.  The invariants
//! enforced at this seam:
//!
//! * **The dispatcher stays light.**  It only does registry map surgery
//!   and lane pushes; heavy work (dataset validation, session builds,
//!   store IO) always runs on the worker pool, so one slow register
//!   cannot stall dispatch for every other connection.
//! * **The inflight window is enforced at accept time**: a device with
//!   `window` accepted-but-unanswered requests gets an immediate error
//!   response instead of an unbounded backlog
//!   ([`super::ServeBuilder::window`]).
//! * **Register runs first.**  A register unit is queued at the *head*
//!   (interactive) lane of a fresh provisional entry, so it is
//!   guaranteed to execute before any op pipelined behind it.
//! * **A malformed frame never desyncs a connection**: framing is
//!   length-delimited, so the bad payload is answered with an error
//!   (carrying the id salvaged from the frame header) and the stream
//!   keeps serving.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::obs::{Op, Timer};
use crate::proto::{codec, ErrorKind, Priority, Request, Response};

use super::registry::{note_request, respond, DeviceState, Item, Shared, Work};

/// Reply route of one connection: the worker that completes a request
/// sends `(request id, response)` here; the connection's writer pump
/// encodes and ships it.
#[derive(Clone)]
pub(super) struct Reply(pub(super) Sender<(u64, Response)>);

/// One accepted request: decoded frame + its reply route.
pub(super) struct Inbound {
    pub(super) id: u64,
    pub(super) priority: Priority,
    pub(super) req: Request,
    pub(super) reply: Reply,
}

/// Decode loop shared by every connection flavor: frames in, [`Inbound`]s
/// out.  A malformed frame is answered — and reported — like any other
/// failed request: an `Error` response carrying the frame's own request
/// id (salvaged from the fixed header, so a synchronous client waiting
/// on that id sees the error instead of hanging), counted and recorded
/// via [`respond`].  The connection keeps serving — framing is
/// length-delimited, so one bad payload does not desync the stream.
fn read_loop(shared: &Shared,
             mut recv: impl FnMut() -> Result<Option<Vec<u8>>>,
             ingress: &Sender<Inbound>, reply: &Reply) {
    loop {
        let frame = match recv() {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => break, // peer closed / connection error
        };
        let t = Timer::start();
        let decoded = codec::decode_request(&frame);
        shared.obs.decode.record(t.elapsed_us());
        match decoded {
            Ok((id, priority, req)) => {
                let inb = Inbound { id, priority, req, reply: reply.clone() };
                if ingress.send(inb).is_err() {
                    break; // server shutting down
                }
            }
            Err(e) => {
                note_request(shared);
                respond(shared, reply, codec::frame_request_id(&frame),
                        Response::Error {
                            device: String::new(),
                            kind: ErrorKind::Request,
                            message: format!("bad request frame: {e:#}"),
                        });
            }
        }
    }
}

/// Wire up one connection, whatever carries its frames: a writer pump
/// encoding responses into `send_frame` and a reader pump feeding
/// decoded requests to the dispatcher.
pub(super) fn spawn_connection(
    shared: &Arc<Shared>,
    ingress: Sender<Inbound>,
    mut send_frame: impl FnMut(Vec<u8>) -> bool + Send + 'static,
    recv_frame: impl FnMut() -> Result<Option<Vec<u8>>> + Send + 'static,
) {
    let (otx, orx) = channel::<(u64, Response)>();
    let writer = {
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            for (id, resp) in orx {
                let t = Timer::start();
                let frame = codec::encode_response(id, &resp);
                shared.obs.encode.record(t.elapsed_us());
                if !send_frame(frame) {
                    break;
                }
            }
        })
    };
    let reply = Reply(otx);
    let reader = {
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            read_loop(&shared, recv_frame, &ingress, &reply);
        })
    };
    track_conn(shared, reader, writer);
}

/// Track a connection's pump threads, reaping the handles of pumps that
/// already finished (long-lived servers see many connections come and
/// go; their handles must not accumulate until `join()`).
fn track_conn(shared: &Shared, reader: JoinHandle<()>, writer: JoinHandle<()>) {
    let mut conns = shared.conns.lock().expect("serve connections");
    conns.retain(|h| !h.is_finished());
    conns.push(reader);
    conns.push(writer);
}

pub(super) fn dispatch(shared: &Shared, rx: Receiver<Inbound>) {
    for inb in rx {
        note_request(shared);
        shared.obs.note_request(op_kind(&inb.req));
        let device = inb.req.device().to_string();
        let (id, reply) = (inb.id, inb.reply.clone());
        // After an abort (`Drop` without `join`: worker pool stopped,
        // dispatcher detached) the server must still *answer* — with an
        // error — or a synchronous client that submits after the drop
        // would wait forever on a request nothing will ever run.
        if shared.done.load(Ordering::SeqCst) {
            respond(shared, &reply, id, Response::Error {
                device,
                kind: ErrorKind::Shutdown,
                message: "fleet server is shut down".into(),
            });
            continue;
        }
        if let Err(e) = handle_request(shared, inb) {
            respond(shared, &reply, id, Response::Error {
                device,
                kind: ErrorKind::Request,
                message: format!("{e:#}"),
            });
        }
    }
}

/// The telemetry op class of a request (see [`crate::obs::Op`]).
fn op_kind(req: &Request) -> Op {
    match req {
        Request::Register { .. } => Op::Register,
        Request::Train { .. } => Op::Train,
        Request::Predict { .. } => Op::Predict,
        Request::Evaluate { .. } => Op::Evaluate,
        Request::Drift { .. } => Op::Drift,
        Request::GetStats => Op::GetStats,
    }
}

fn handle_request(shared: &Shared, inb: Inbound) -> Result<()> {
    let Inbound { id, priority, req, reply } = inb;
    match req {
        // Register is *routed* here but *executed* on the worker pool:
        // dataset validation, session construction, and store lookups
        // are heavy, and heavy work never runs on the dispatcher (a
        // slow register must not stall dispatch for every connection).
        // The dispatcher only does map surgery: create a provisional
        // entry and queue the register unit at the head lane, so it is
        // guaranteed to run before any op pipelined behind it.
        Request::Register { device, seed, method, train, test, angle } => {
            // Canonicalize the method description up front: snapshots
            // store canonical specs (read back from the live plugin), so
            // resume identity checks must compare canonical forms — a
            // register with an unset θ must match a stored device whose
            // snapshot spells out the method's default θ.
            let method = method.canonical();
            let mut reg = shared.registry.lock().expect("serve registry");
            if let Some(st) = reg.map.get_mut(&device) {
                if st.seed != seed || st.method != method {
                    bail!("device {device} is already registered with a \
                           different method or seed");
                }
                if st.registered {
                    // Known device (live or evicted): a resume handshake.
                    // Its state is kept, the supplied datasets are
                    // ignored, and rehydration stays lazy until real
                    // work arrives.
                    drop(reg);
                    respond(shared, &reply, id,
                            Response::Registered { device, resumed: true });
                    return Ok(());
                }
                // Same identity while the original register is still
                // building on the pool (reconnects can race a slow
                // register): queue the handshake behind it in the head
                // lane — acked as a resume once the build lands, or
                // answered with the register failure if it does not.
                if st.pending >= shared.window {
                    bail!(
                        "device {device}: inflight window full ({} of {} \
                         requests pending)",
                        st.pending, shared.window
                    );
                }
                st.pending += 1;
                st.lanes[0].push_back(Item {
                    id,
                    reply,
                    work: Work::Register { seed, method, train, test, angle },
                    enqueued: Instant::now(),
                });
                bump_outstanding(shared);
                if !st.queued {
                    st.queued = true;
                    shared
                        .ready
                        .lock()
                        .expect("serve ready queue")
                        .push_back(device);
                    shared.ready_cv.notify_one();
                }
                return Ok(());
            }
            let mut st = DeviceState::new(seed, method.clone());
            st.pending = 1;
            st.queued = true;
            st.lanes[0].push_back(Item {
                id,
                reply,
                work: Work::Register { seed, method, train, test, angle },
                enqueued: Instant::now(),
            });
            reg.map.insert(device.clone(), st);
            bump_outstanding(shared);
            shared
                .ready
                .lock()
                .expect("serve ready queue")
                .push_back(device);
            shared.ready_cv.notify_one();
            Ok(())
        }
        Request::Train { device, epochs } => enqueue(shared, &device, priority,
            Item {
                id,
                reply,
                work: Work::Train { remaining: epochs, done: 0, steps: 0 },
                enqueued: Instant::now(),
            }),
        Request::Predict { device, image } => enqueue(shared, &device, priority,
            Item {
                id,
                reply,
                work: Work::Predict { image },
                enqueued: Instant::now(),
            }),
        Request::Evaluate { device } => enqueue(shared, &device, priority,
            Item {
                id,
                reply,
                work: Work::Evaluate,
                enqueued: Instant::now(),
            }),
        Request::Drift { device, train, test, angle } => {
            // Validation runs with the op on the worker pool, like
            // Register's.
            enqueue(shared, &device, priority, Item {
                id,
                reply,
                work: Work::Drift { train, test, angle },
                enqueued: Instant::now(),
            })
        }
        // An admin read, answered inline: no device entry, no lane, no
        // outstanding count — so a counter read never queues behind (or
        // perturbs) device work, and `join()`'s idle wait ignores it.
        Request::GetStats => {
            respond(shared, &reply, id, Response::Stats {
                json: super::stats_snapshot(shared).to_json(),
            });
            Ok(())
        }
    }
}

/// Count one more accepted-but-unanswered request and feed the result to
/// the queue high-water gauge (recorded *after* the increment, under the
/// same lock, so the gauge never misses a momentary peak).
fn bump_outstanding(shared: &Shared) {
    let mut out = shared.outstanding.lock().expect("serve outstanding");
    *out += 1;
    shared.obs.queue_high_water.record(*out as u64);
}

fn enqueue(shared: &Shared, device: &str, priority: Priority, item: Item)
           -> Result<()> {
    let mut reg = shared.registry.lock().expect("serve registry");
    let st = reg
        .map
        .get_mut(device)
        .ok_or_else(|| anyhow!("unknown device {device} (register first)"))?;
    if st.pending >= shared.window {
        bail!(
            "device {device}: inflight window full ({} of {} requests \
             pending — drain responses before submitting more)",
            st.pending,
            shared.window
        );
    }
    st.pending += 1;
    st.lanes[priority.lane()].push_back(item);
    bump_outstanding(shared);
    if !st.queued {
        st.queued = true;
        shared
            .ready
            .lock()
            .expect("serve ready queue")
            .push_back(device.to_string());
        shared.ready_cv.notify_one();
    }
    Ok(())
}
