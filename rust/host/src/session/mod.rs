//! The public Session/Fleet API: the one construction path for on-device
//! training runs.
//!
//! * [`Backbone`] — the deployed read-only model (spec + int8 weights +
//!   static scales), loaded once and shared across sessions via `Arc`.
//! * [`SessionBuilder`] / [`Session`] — a fluent builder yielding one
//!   adapting device: a [`crate::methods::MethodPlugin`] bound to an
//!   execution backend ([`Backend::Engine`] or [`Backend::Pjrt`]), with
//!   `train_epoch` / `predict` / `evaluate` / `save` / `restore`.
//! * [`Fleet`] — many concurrent sessions over one shared backbone
//!   (see [`fleet`]); work is scheduled at epoch granularity across the
//!   worker pool.
//! * [`FleetServer`] — the long-lived, request-driven front-end: clients
//!   connect through the [`crate::proto`] wire boundary (in-process
//!   [`FleetServer::local_client`] or TCP via [`FleetServer::listen`])
//!   and speak typed [`Request`]/[`Response`] frames (see [`serve`]).
//!
//! ```no_run
//! use priot::session::Session;
//! use priot::methods::PriotS;
//! use priot::config::Selection;
//!
//! let mut session = Session::builder()
//!     .artifacts("artifacts")
//!     .model("tinycnn")
//!     .method(PriotS::new(0.1, Selection::WeightBased))
//!     .seed(7)
//!     .epochs(10)
//!     .build()?;
//! # anyhow::Ok(())
//! ```

pub mod fleet;
pub mod serve;

pub use fleet::{DeviceReport, Fleet, FleetBuilder, FleetReport};
pub use serve::{
    AuditPolicy, FleetServer, ServeBuilder, ServeReport, StatsHandle,
};

pub use crate::proto::{FleetClient, Request, Response};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::{
    evaluate_batched, predict_batched, run_training, train_one_epoch,
    RunOptions,
};

pub use crate::coordinator::EpochReport;
use crate::engine::{Engine, PruneState, StepOut};
use crate::methods::{plugin_for, MethodPlugin, Priot, StepBackend};
use crate::metrics::RunMetrics;
use crate::quant::Scales;
use crate::serial::{load_weights, save_weights, Dataset};
use crate::spec::NetSpec;
use crate::store::{PluginState, SessionSnapshot};
use crate::tensor::Mat;

/// Execution backend for a session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// The pure-Rust integer engine (the device implementation).
    #[default]
    Engine,
    /// PJRT execution of the AOT HLO artifacts (requires the `pjrt`
    /// feature and `make artifacts`).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "engine" => Backend::Engine,
            "pjrt" => Backend::Pjrt,
            other => bail!("unknown backend {other} (want engine|pjrt)"),
        })
    }
}

/// The deployed read-only model: spec + int8 weights + static scale table.
///
/// Weights and scales live behind `Arc` so every [`Session`] built from
/// one `Backbone` shares a single copy — a [`Fleet`] of N devices holds
/// the backbone once, not N times.
pub struct Backbone {
    pub model: String,
    pub spec: NetSpec,
    pub weights: Arc<Vec<Mat>>,
    pub scales: Arc<Scales>,
}

impl Backbone {
    /// Load `<model>.weights.bin` + `<model>.scales.txt` from an artifacts
    /// directory (produced by `make artifacts`).
    pub fn load(artifacts: &Path, model: &str) -> Result<Arc<Self>> {
        let spec = NetSpec::by_name(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let tensors =
            load_weights(&artifacts.join(format!("{model}.weights.bin")))?;
        let weights: Vec<Mat> = tensors
            .iter()
            .zip(spec.layers.iter())
            .map(|(t, l)| {
                let (r, c) = l.weight_shape();
                Mat::from_vec(r, c, t.to_i32())
            })
            .collect();
        let scales = crate::quant::load_scales(
            &artifacts.join(format!("{model}.scales.txt")))?;
        Ok(Self::from_parts(model, spec, weights, scales))
    }

    /// Deterministic random-weight backbone (default scales) for any
    /// model spec — the artifact-free stand-in shared by the test
    /// suites, the `serve`/`fleet` benches and the CLI fallback
    /// ([`Self::load_or_synthetic`]).  Untrained: useful wherever the
    /// *machinery* (scheduling, wire protocol, throughput) is under test
    /// rather than accuracy.
    pub fn synthetic(model: &str, seed: u64) -> Result<Arc<Self>> {
        let spec = NetSpec::by_name(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))?;
        let mut rng = crate::prng::XorShift64::new(seed);
        let weights: Vec<Mat> = spec
            .layers
            .iter()
            .map(|l| {
                let (r, c) = l.weight_shape();
                let data: Vec<i32> =
                    (0..r * c).map(|_| rng.int_in(-127, 127)).collect();
                Mat::from_vec(r, c, data)
            })
            .collect();
        let scales = Scales::default_for(spec.layers.len());
        Ok(Self::from_parts(model, spec, weights, scales))
    }

    /// [`Self::load`] when the artifacts exist, otherwise a
    /// [`Self::synthetic`] fallback (with a note on stderr) — what lets
    /// `priot serve` / `priot fleet` and the benches run from a bare
    /// checkout.
    pub fn load_or_synthetic(artifacts: &Path, model: &str, seed: u64)
                             -> Result<Arc<Self>> {
        if artifacts.join(format!("{model}.weights.bin")).exists() {
            return Self::load(artifacts, model);
        }
        eprintln!(
            "[backbone] no {model} artifacts under {} — using a synthetic \
             random-weight backbone (deterministic, seed {seed}); run \
             `make artifacts` for the pre-trained one",
            artifacts.display()
        );
        Self::synthetic(model, seed)
    }

    /// Assemble a backbone from in-memory parts (tests, synthetic
    /// deployments).
    pub fn from_parts(model: &str, spec: NetSpec, weights: Vec<Mat>,
                      scales: Scales) -> Arc<Self> {
        Arc::new(Self {
            model: model.to_string(),
            spec,
            weights: Arc::new(weights),
            scales: Arc::new(scales),
        })
    }
}

/// The engine-side executor: engine + plugin + step counter.  Implements
/// [`StepBackend`] so the coordinator can drive it interchangeably with
/// the PJRT executor.
pub struct EngineExecutor {
    pub engine: Engine,
    plugin: Box<dyn MethodPlugin>,
    step: u32,
    label: String,
    /// Worker threads for batched evaluation (1 = serial).  Parallel
    /// evaluation shards each batch across private engines over the
    /// shared backbone — inference only, bit-identical.
    eval_threads: usize,
}

impl EngineExecutor {
    pub fn new(engine: Engine, plugin: Box<dyn MethodPlugin>) -> Self {
        let label = format!("engine/{}", plugin.name());
        Self { engine, plugin, step: 0, label, eval_threads: 1 }
    }

    pub fn plugin(&self) -> &dyn MethodPlugin {
        self.plugin.as_ref()
    }

    /// Training steps executed so far (the counter NITI's stochastic
    /// rounding consumes).
    pub fn steps(&self) -> u32 {
        self.step
    }

    /// Worker threads for [`StepBackend::predict_batch`] (clamped to ≥ 1).
    pub fn set_eval_threads(&mut self, threads: usize) {
        self.eval_threads = threads.max(1);
    }

    /// Shard `imgs` across `eval_threads` scoped worker threads, each with
    /// a private [`Engine::shared`] clone (cheap: `Arc` handles on the
    /// weights/scales, fresh workspace) over this executor's *read-only*
    /// pruning state.  Bit-identical to the serial path: inference mutates
    /// no plugin state, so every row is independent.
    ///
    /// Returns `None` when the plugin's pruning view is not expressible as
    /// a [`PruneState`] (scores/masks/θ partially present) — the caller
    /// then takes the serial plugin path, which stays the source of truth.
    fn predict_batch_parallel(&mut self, imgs: &Mat) -> Option<Vec<usize>> {
        let prune_parts = match (
            self.plugin.scores(), self.plugin.masks(), self.plugin.theta(),
        ) {
            (Some(s), Some(m), Some(t)) => Some((s, m, t)),
            (None, None, None) => None,
            _ => return None,
        };
        let threads = self.eval_threads.min(imgs.rows);
        let rows_per = imgs.rows.div_ceil(threads);
        let spec = &self.engine.spec;
        let weights = &self.engine.weights;
        let scales = &self.engine.scales;
        let mut preds = vec![0usize; imgs.rows];
        std::thread::scope(|scope| {
            let mut rest: &mut [usize] = &mut preds;
            let mut lo = 0usize;
            while lo < imgs.rows {
                let hi = (lo + rows_per).min(imgs.rows);
                let (chunk, tail) = rest.split_at_mut(hi - lo);
                rest = tail;
                let shard = Mat::from_vec(
                    hi - lo,
                    imgs.cols,
                    imgs.data[lo * imgs.cols..hi * imgs.cols].to_vec(),
                );
                scope.spawn(move || {
                    let mut e = Engine::shared(
                        spec.clone(), Arc::clone(weights), Arc::clone(scales),
                    )
                    .expect("backbone shapes validated at session build");
                    let prune = prune_parts.map(|(scores, masks, theta)| {
                        PruneState { scores, masks, theta }
                    });
                    chunk.copy_from_slice(
                        &e.predict_batch(&shard, prune.as_ref()),
                    );
                });
                lo = hi;
            }
        });
        Some(preds)
    }
}

impl StepBackend for EngineExecutor {
    fn train_step(&mut self, img: &[i32], label: usize) -> StepOut {
        let out = self.plugin.train_step(&mut self.engine, img, label, self.step);
        self.step += 1;
        out
    }

    fn predict(&mut self, img: &[i32]) -> usize {
        self.plugin.predict(&mut self.engine, img)
    }

    fn predict_batch(&mut self, imgs: &Mat) -> Vec<usize> {
        if self.eval_threads > 1 && imgs.rows > 1 {
            if let Some(preds) = self.predict_batch_parallel(imgs) {
                return preds;
            }
        }
        self.plugin.predict_batch(&mut self.engine, imgs)
    }

    fn train_chunk(&mut self, imgs: &Mat, labels: &[usize]) -> Vec<StepOut> {
        assert_eq!(imgs.rows, labels.len(), "train_chunk: labels != rows");
        let mut outs = Vec::with_capacity(imgs.rows);
        match self.plugin.train_chunk(
            &mut self.engine, imgs, labels, self.step, &mut outs,
        ) {
            Some(consumed) => {
                self.step += consumed as u32;
                // θ-crossing (or short chunk): the batched tape is stale
                // past `consumed` — finish this chunk per sample, exactly
                // as the sequential loop would.
                for bi in consumed..imgs.rows {
                    outs.push(self.train_step(imgs.row(bi), labels[bi]));
                }
            }
            // Method without a chunked path (NITI): the per-sample loop
            // *is* the protocol.
            None => {
                for bi in 0..imgs.rows {
                    outs.push(self.train_step(imgs.row(bi), labels[bi]));
                }
            }
        }
        outs
    }

    fn scores(&self) -> Option<&[Vec<i32>]> {
        self.plugin.scores()
    }

    fn masks(&self) -> Option<&[Vec<i32>]> {
        self.plugin.masks()
    }

    fn theta(&self) -> Option<i32> {
        self.plugin.theta()
    }

    fn name(&self) -> &str {
        &self.label
    }

    fn save_state(&self, path: &Path) -> Result<()> {
        let tensors = match self.plugin.checkpoint_state() {
            Some(t) => t,
            // Methods without plugin state (NITI) checkpoint the trained
            // engine weights instead.
            None => crate::methods::weight_checkpoint_tensors(
                &self.engine.spec,
                self.engine.weights.iter().map(|m| m.data.as_slice()),
            ),
        };
        save_weights(path, &tensors)
    }

    fn load_state(&mut self, path: &Path) -> Result<()> {
        let tensors = load_weights(path)?;
        if self.plugin.restore_state(&tensors)? {
            return Ok(());
        }
        // Weight-state method: restore engine weights (copy-on-write, so a
        // fleet sibling's shared view is never touched).
        let weights = Arc::make_mut(&mut self.engine.weights);
        crate::methods::restore_weight_tensors(
            &self.engine.spec,
            &tensors,
            weights.iter_mut().map(|m| &mut m.data),
        )
    }
}

enum Exec {
    Engine(EngineExecutor),
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::PjrtBackend),
}

/// One adapting device: an execution backend bound to a method plugin,
/// plus the run options the epoch loop consumes.
pub struct Session {
    exec: Exec,
    opts: RunOptions,
    /// The backbone's architecture, kept so the data-facing entry points
    /// can reject geometry-mismatched datasets with a clean error instead
    /// of panicking deep inside the engine.
    spec: NetSpec,
    /// The seed this session was built with, retained so
    /// [`Session::snapshot`] can record it (rehydration replays plugin
    /// `init` with it before restoring exact state).
    seed: u32,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Build directly from an [`ExperimentConfig`] (the config/CLI bridge).
    pub fn from_experiment(cfg: &ExperimentConfig) -> Result<Self> {
        SessionBuilder::from_experiment(cfg)?.build()
    }

    fn driver(&mut self) -> &mut dyn StepBackend {
        match &mut self.exec {
            Exec::Engine(e) => e,
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p,
        }
    }

    fn driver_ref(&self) -> &dyn StepBackend {
        match &self.exec {
            Exec::Engine(e) => e,
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p,
        }
    }

    /// Backend/method label, e.g. `engine/priot-s`.
    pub fn name(&self) -> &str {
        self.driver_ref().name()
    }

    pub fn options(&self) -> &RunOptions {
        &self.opts
    }

    pub fn options_mut(&mut self) -> &mut RunOptions {
        &mut self.opts
    }

    /// Direct engine access (calibration, analysis); `None` on the PJRT
    /// backend.
    pub fn engine_mut(&mut self) -> Option<&mut Engine> {
        match &mut self.exec {
            Exec::Engine(e) => Some(&mut e.engine),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(_) => None,
        }
    }

    /// Read-and-reset the engine perf counters accumulated since the last
    /// take (serve workers drain these into the fleet [`crate::obs`]
    /// snapshot after every unit of work); `None` on the PJRT backend.
    #[cfg(feature = "obs")]
    pub fn take_perf_counters(
        &mut self,
    ) -> Option<priot_core::engine::EngineCounters> {
        self.engine_mut().map(|e| e.take_counters())
    }

    /// One training step (batch 1).  Most callers want [`Self::train`] or
    /// [`Self::train_epoch`]; this is the micro-benchmark/parity hook.
    pub fn train_step(&mut self, img: &[i32], label: usize) -> StepOut {
        self.driver().train_step(img, label)
    }

    /// Reject datasets whose geometry or labels don't fit the backbone —
    /// the Session/Fleet/serve contract is a clean `Err`, never a panic
    /// deep inside the engine.
    fn check_data(&self, ds: &Dataset) -> Result<()> {
        crate::data::validate(ds, &self.spec)
    }

    /// One pass over (a cap of) the training set; returns step statistics.
    /// Shares [`train_one_epoch`] with the coordinator's full run loop.
    /// Honors the session's `train_batch` option (chunked batched-forward
    /// training, bit-identical to the sequential loop).
    pub fn train_epoch(&mut self, train: &Dataset) -> Result<EpochReport> {
        self.check_data(train)?;
        let limit = self.opts.limit;
        let chunk = self.opts.train_batch;
        Ok(train_one_epoch(self.driver(), train, limit, chunk))
    }

    /// The full epoch loop with per-epoch evaluation (the paper's run
    /// protocol) — drives [`run_training`] over this session's backend.
    /// The returned metrics include the *executed* step count per epoch
    /// ([`RunMetrics::total_steps`]), which fleet/serve throughput
    /// reporting divides by.
    pub fn train(&mut self, train: &Dataset, test: &Dataset)
                 -> Result<RunMetrics> {
        self.check_data(train)?;
        self.check_data(test)?;
        let opts = self.opts.clone();
        Ok(run_training(self.driver(), train, test, &opts))
    }

    /// Inference for one image.
    pub fn predict(&mut self, img: &[i32]) -> usize {
        self.driver().predict(img)
    }

    /// Predictions over (a cap of) a dataset, in batched forwards of the
    /// session's `eval_batch` option (bit-identical to per-sample
    /// prediction).  Labels are not read, so an inference-only dataset
    /// with sentinel labels is accepted (only image geometry/payload is
    /// validated).
    pub fn predict_batch(&mut self, ds: &Dataset, limit: usize)
                         -> Result<Vec<usize>> {
        crate::data::validate_images(ds, &self.spec)?;
        let batch = self.opts.eval_batch;
        Ok(predict_batched(self.driver(), ds, limit, batch))
    }

    /// Top-1 accuracy over (a cap of) a dataset, respecting the session's
    /// `limit` and `eval_batch` options.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<f64> {
        let batch = self.opts.eval_batch;
        self.evaluate_batch(ds, batch)
    }

    /// Top-1 accuracy with an explicit evaluation batch size: samples are
    /// run through the engine `batch` at a time (extra GEMM columns — see
    /// [`crate::engine::Engine::forward_batch`]), bit-identical to
    /// per-sample evaluation for every method plugin.
    pub fn evaluate_batch(&mut self, ds: &Dataset, batch: usize)
                          -> Result<f64> {
        self.check_data(ds)?;
        let limit = self.opts.limit;
        Ok(evaluate_batched(self.driver(), ds, limit, batch))
    }

    /// Checkpoint the trained state (scores+masks, or NITI weights).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.driver_ref().save_state(path)
    }

    /// Restore a checkpoint produced by [`Self::save`] (same method and
    /// model).
    pub fn restore(&mut self, path: &Path) -> Result<()> {
        self.driver().load_state(path)
    }

    pub fn scores(&self) -> Option<&[Vec<i32>]> {
        self.driver_ref().scores()
    }

    pub fn masks(&self) -> Option<&[Vec<i32>]> {
        self.driver_ref().masks()
    }

    pub fn theta(&self) -> Option<i32> {
        self.driver_ref().theta()
    }

    /// Training steps executed so far (the counter NITI's stochastic
    /// rounding consumes; 0 on the PJRT backend, which tracks its own).
    pub fn steps(&self) -> u32 {
        match &self.exec {
            Exec::Engine(e) => e.step,
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(_) => 0,
        }
    }

    /// Capture the session's exact mutable state as a
    /// [`SessionSnapshot`] — the lossless counterpart of [`Self::save`]
    /// (which narrows to portable int8 checkpoints).  A session
    /// rehydrated from the snapshot produces **byte-identical**
    /// predict/evaluate/train trajectories to this one: the snapshot
    /// carries the serializable method description, the seed, the
    /// executed-step counter, and the exact i32 plugin state (scores +
    /// masks, or trained weights for weight-state methods).
    ///
    /// Errors when the method cannot be described as a
    /// [`crate::proto::MethodSpec`] (e.g. ablation-only knobs) or the
    /// session runs on the PJRT backend.
    pub fn snapshot(&self) -> Result<SessionSnapshot> {
        let e = match &self.exec {
            Exec::Engine(e) => e,
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(_) => {
                bail!("snapshot requires the engine backend")
            }
        };
        let method = e.plugin.method_spec().ok_or_else(|| {
            anyhow!(
                "method {} has no serializable MethodSpec — snapshot \
                 unsupported",
                e.plugin.name()
            )
        })?;
        let state = match (e.plugin.scores(), e.plugin.masks()) {
            (Some(scores), Some(masks)) => PluginState::Scores {
                scores: scores.to_vec(),
                masks: masks.to_vec(),
            },
            // Weight-state method (NITI): the trained state lives in the
            // executor's weights.
            _ => PluginState::Weights(
                e.engine.weights.iter().map(|w| w.data.clone()).collect(),
            ),
        };
        Ok(SessionSnapshot {
            model: self.spec.name.clone(),
            seed: self.seed,
            method,
            step: e.step,
            eval_batch: self.opts.eval_batch,
            limit: self.opts.limit,
            state,
        })
    }

    /// Rebuild a session from a [`SessionSnapshot`] over a shared
    /// backbone — the exact inverse of [`Self::snapshot`].  The plugin is
    /// rebuilt from the snapshot's method spec, initialized with the
    /// recorded seed, then every mutable value (scores, masks, weights,
    /// step counter) is overwritten with the snapshot's exact i32 state,
    /// so the rehydrated session's trajectories are byte-identical to the
    /// original's.
    ///
    /// Presentation-only options (`epochs`, `verbose`, `track_pruning`)
    /// are not part of a snapshot; adjust them via
    /// [`Self::options_mut`] after rehydrating if needed.
    pub fn rehydrate(backbone: &Arc<Backbone>, snap: &SessionSnapshot)
                     -> Result<Session> {
        if snap.model != backbone.model {
            bail!(
                "snapshot is for model {}, backbone is {}",
                snap.model, backbone.model
            );
        }
        let mut session = Session::builder()
            .backbone(Arc::clone(backbone))
            .method_boxed(snap.method.plugin())
            .seed(snap.seed)
            .eval_batch(snap.eval_batch)
            .limit(snap.limit)
            .track_pruning(false)
            .build()?;
        let e = match &mut session.exec {
            Exec::Engine(e) => e,
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(_) => unreachable!("rehydrate builds engine sessions"),
        };
        e.step = snap.step;
        match &snap.state {
            PluginState::Scores { scores, masks } => {
                let dst = e.plugin.scores_mut().ok_or_else(|| {
                    anyhow!(
                        "snapshot carries score state but method {} keeps \
                         none",
                        snap.method.method.name()
                    )
                })?;
                copy_layers("scores", dst, scores)?;
                let dst = e.plugin.masks_mut().ok_or_else(|| {
                    anyhow!(
                        "snapshot carries masks but method {} keeps none",
                        snap.method.method.name()
                    )
                })?;
                copy_layers("masks", dst, masks)?;
            }
            PluginState::Weights(saved) => {
                if e.plugin.scores().is_some() {
                    bail!(
                        "snapshot carries weight state but method {} keeps \
                         scores",
                        snap.method.method.name()
                    );
                }
                // Copy-on-write: a fleet sibling's shared view is never
                // touched.
                let weights = Arc::make_mut(&mut e.engine.weights);
                if saved.len() != weights.len() {
                    bail!(
                        "snapshot has {} weight tensors, backbone has {}",
                        saved.len(), weights.len()
                    );
                }
                for (li, (w, s)) in
                    weights.iter_mut().zip(saved.iter()).enumerate()
                {
                    if s.len() != w.data.len() {
                        bail!(
                            "snapshot weights layer {li}: {} values, \
                             want {}",
                            s.len(), w.data.len()
                        );
                    }
                    w.data.copy_from_slice(s);
                }
            }
        }
        Ok(session)
    }
}

/// Overwrite per-layer state with snapshot layers, validating counts and
/// lengths so a mismatched snapshot is a contextful error, not a panic.
fn copy_layers(what: &str, dst: &mut [Vec<i32>], src: &[Vec<i32>])
               -> Result<()> {
    if dst.len() != src.len() {
        bail!(
            "snapshot {what}: {} layers, session has {}",
            src.len(), dst.len()
        );
    }
    for (li, (d, s)) in dst.iter_mut().zip(src.iter()).enumerate() {
        if d.len() != s.len() {
            bail!(
                "snapshot {what} layer {li}: {} values, want {}",
                s.len(), d.len()
            );
        }
        d.copy_from_slice(s);
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn build_pjrt(artifacts: &Path, backbone: &Backbone,
              plugin: Box<dyn MethodPlugin>) -> Result<Exec> {
    let rt = crate::runtime::Runtime::new(artifacts)?;
    Ok(Exec::Pjrt(crate::runtime::PjrtBackend::new(&rt, backbone, plugin)?))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt(_artifacts: &Path, _backbone: &Backbone,
              _plugin: Box<dyn MethodPlugin>) -> Result<Exec> {
    bail!("backend 'pjrt' requires building with `--features pjrt` \
           (AOT artifacts + XLA runtime)")
}

/// Fluent builder for [`Session`] — see the module docs for an example.
pub struct SessionBuilder {
    artifacts: PathBuf,
    model: String,
    backend: Backend,
    method: Option<Box<dyn MethodPlugin>>,
    backbone: Option<Arc<Backbone>>,
    seed: u32,
    epochs: usize,
    limit: usize,
    track_pruning: bool,
    verbose: bool,
    eval_batch: usize,
    train_batch: usize,
    eval_threads: usize,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            model: "tinycnn".to_string(),
            backend: Backend::Engine,
            method: None,
            backbone: None,
            seed: 1,
            epochs: 30,
            limit: 0,
            track_pruning: true,
            verbose: false,
            eval_batch: 1,
            train_batch: 1,
            eval_threads: 1,
        }
    }
}

impl SessionBuilder {
    /// Artifacts directory (default `artifacts`).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    /// Model name (default `tinycnn`).  Ignored when a [`Self::backbone`]
    /// is supplied.
    pub fn model(mut self, name: &str) -> Self {
        self.model = name.to_string();
        self
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Training method (default: [`Priot`] with the paper's θ).
    pub fn method(self, plugin: impl MethodPlugin + 'static) -> Self {
        self.method_boxed(Box::new(plugin))
    }

    pub fn method_boxed(mut self, plugin: Box<dyn MethodPlugin>) -> Self {
        self.method = Some(plugin);
        self
    }

    /// Share an already-loaded backbone instead of reading artifacts from
    /// disk (the [`Fleet`] path; also enables artifact-free tests).
    pub fn backbone(mut self, backbone: Arc<Backbone>) -> Self {
        self.model = backbone.model.clone();
        self.backbone = Some(backbone);
        self
    }

    /// Seed for the method's score/mask streams (default 1).
    pub fn seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    pub fn epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }

    /// Cap on train/test samples per epoch (0 = all).
    pub fn limit(mut self, limit: usize) -> Self {
        self.limit = limit;
        self
    }

    /// Record per-layer pruned fractions + mask flips each epoch (costs a
    /// full scores scan; default on).
    pub fn track_pruning(mut self, on: bool) -> Self {
        self.track_pruning = on;
        self
    }

    pub fn verbose(mut self, on: bool) -> Self {
        self.verbose = on;
        self
    }

    /// Samples per forward in dataset evaluation (default 1 = per-sample;
    /// batched evaluation is bit-identical, just faster — the fleet and
    /// serve front-ends default to a batched width).
    pub fn eval_batch(mut self, batch: usize) -> Self {
        self.eval_batch = batch;
        self
    }

    /// Samples per *training* chunk (default 1 = the paper's strictly
    /// sequential loop).  Chunked training batches the forward passes
    /// through the tiled kernels while every score/weight update stays a
    /// sequential batch-1 step — bit-identical for the PRIOT methods
    /// (θ-crossings fall back to per-sample replay for the chunk
    /// remainder); methods without a chunked path (NITI) run per sample
    /// regardless.
    pub fn train_batch(mut self, batch: usize) -> Self {
        self.train_batch = batch;
        self
    }

    /// Worker threads for batched evaluation (default 1 = serial).  Each
    /// thread runs a private engine over the shared backbone, so parallel
    /// evaluation is inference-only and bit-identical to the serial path.
    pub fn eval_threads(mut self, threads: usize) -> Self {
        self.eval_threads = threads.max(1);
        self
    }

    /// Pre-populate the builder from an [`ExperimentConfig`].
    pub fn from_experiment(cfg: &ExperimentConfig) -> Result<Self> {
        Ok(Session::builder()
            .artifacts(cfg.artifacts_dir.clone())
            .model(&cfg.model)
            .backend(Backend::parse(&cfg.backend)?)
            .method_boxed(plugin_for(cfg)?)
            .seed(cfg.seed)
            .epochs(cfg.epochs)
            .limit(cfg.limit)
            .eval_batch(cfg.eval_batch)
            .train_batch(cfg.train_batch)
            .eval_threads(cfg.eval_threads)
            .track_pruning(cfg.track_pruning))
    }

    pub fn build(self) -> Result<Session> {
        let backbone = match self.backbone {
            Some(b) => b,
            None => Backbone::load(&self.artifacts, &self.model)?,
        };
        let mut plugin = self
            .method
            .unwrap_or_else(|| Box::new(Priot::new()) as Box<dyn MethodPlugin>);
        plugin.init(&backbone.spec, &backbone.weights, self.seed)?;
        let opts = RunOptions {
            epochs: self.epochs,
            limit: self.limit,
            track_pruning: self.track_pruning,
            verbose: self.verbose,
            eval_batch: self.eval_batch,
            train_batch: self.train_batch,
        };
        let spec = backbone.spec.clone();
        let exec = match self.backend {
            Backend::Engine => {
                let engine = Engine::shared(
                    backbone.spec.clone(),
                    Arc::clone(&backbone.weights),
                    Arc::clone(&backbone.scales),
                )?;
                let mut e = EngineExecutor::new(engine, plugin);
                e.set_eval_threads(self.eval_threads);
                Exec::Engine(e)
            }
            Backend::Pjrt => build_pjrt(&self.artifacts, &backbone, plugin)?,
        };
        Ok(Session { exec, opts, spec, seed: self.seed })
    }
}
