//! Fleet: many concurrent [`Session`]s over one shared [`Backbone`].
//!
//! The paper's pitch is per-device adaptation at fleet scale; this module
//! is the host-side simulation of that deployment.  Every device session
//! shares the read-only backbone weights/scales through `Arc` (no
//! per-session copy — asserted by `rust/cli/tests/session.rs`), owns its
//! method state, and runs on a pool of worker threads.
//!
//! Scheduling is **epoch-granular**: the work queue holds one epoch of one
//! device at a time, and a device re-queues at the back after each epoch,
//! so a device with many epochs never monopolizes a worker while the rest
//! of the fleet waits.  Per-device results are bit-identical to running
//! each session alone — device state never crosses the queue boundary.
//! Epoch-boundary evaluation goes through the batched forward path
//! (`eval_batch`, default 8 samples per forward).
//!
//! The Table I seed sweep ([`crate::coordinator::sweep_seeds`]) and the
//! `priot fleet` multi-device simulation are both built on this type; the
//! `fleet` bench measures its sessions/sec and steps/sec.  For the
//! request-driven (long-lived) front-end see [`super::serve`].

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{RunOptions, TrainProgress};
use crate::data::DataSource;
use crate::methods::MethodPlugin;
use crate::metrics::RunMetrics;
use crate::serial::Dataset;

use super::{Backbone, Session};

/// A device's local dataset: borrowed from the caller
/// ([`FleetBuilder::device`], zero-copy) or shared/owned
/// ([`FleetBuilder::device_shared`] / [`FleetBuilder::device_at`], where
/// the builder resolves data itself).
enum DeviceData<'a> {
    Borrowed(&'a Dataset),
    Shared(Arc<Dataset>),
}

impl DeviceData<'_> {
    fn get(&self) -> &Dataset {
        match self {
            DeviceData::Borrowed(d) => d,
            DeviceData::Shared(a) => a,
        }
    }
}

/// One planned device: a name, a seed, a method plugin, and the local
/// train/test data it adapts on.
struct Device<'a> {
    name: String,
    seed: u32,
    plugin: Box<dyn MethodPlugin>,
    train: DeviceData<'a>,
    test: DeviceData<'a>,
}

/// Builder for a [`Fleet`]; add devices with [`FleetBuilder::device`]
/// (caller-provided data), [`FleetBuilder::device_shared`]
/// (`Arc`-shared data) or [`FleetBuilder::device_at`] (data resolved per
/// angle through the builder's [`DataSource`]).
pub struct FleetBuilder<'a> {
    backbone: Arc<Backbone>,
    opts: RunOptions,
    threads: usize,
    devices: Vec<Device<'a>>,
    source: DataSource,
    dataset: String,
    /// [`Self::device_at`] resolution cache, keyed by (dataset, angle)
    /// and cleared when the source changes — devices sharing a
    /// distribution share one dataset copy.
    pairs: HashMap<(String, u32), (Arc<Dataset>, Arc<Dataset>)>,
}

/// A set of concurrent adaptation sessions sharing one backbone.
pub struct Fleet<'a> {
    backbone: Arc<Backbone>,
    opts: RunOptions,
    threads: usize,
    devices: Vec<Device<'a>>,
}

/// Result of one device's run.
pub struct DeviceReport {
    pub name: String,
    pub seed: u32,
    pub metrics: RunMetrics,
    /// Training steps actually **executed** (threaded back from the epoch
    /// loop via [`RunMetrics::total_steps`]) — not the planned
    /// `epochs × capped(n)`, which overstates throughput for empty
    /// datasets or early-exit runs.
    pub steps: u64,
}

/// Aggregate result of a fleet run.
pub struct FleetReport {
    pub devices: Vec<DeviceReport>,
    pub wall_secs: f64,
    pub threads: usize,
}

impl FleetReport {
    pub fn total_steps(&self) -> u64 {
        self.devices.iter().map(|d| d.steps).sum()
    }

    /// Completed sessions per wall-clock second.
    pub fn sessions_per_sec(&self) -> f64 {
        self.devices.len() as f64 / self.wall_secs.max(1e-9)
    }

    /// Aggregate executed training steps per wall-clock second.
    pub fn steps_per_sec(&self) -> f64 {
        self.total_steps() as f64 / self.wall_secs.max(1e-9)
    }

    pub fn best_accuracies(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.metrics.best_accuracy()).collect()
    }

    /// Markdown summary: one row per device plus the throughput line.
    pub fn summary(&self) -> String {
        let mut out = String::from("| device | seed | best | final | steps |\n");
        out.push_str("|---|---|---|---|---|\n");
        for d in &self.devices {
            out.push_str(&format!(
                "| {} | {} | {:.2}% | {:.2}% | {} |\n",
                d.name,
                d.seed,
                d.metrics.best_accuracy() * 100.0,
                d.metrics.final_accuracy() * 100.0,
                d.steps
            ));
        }
        out.push_str(&format!(
            "\n{} sessions on {} threads in {:.2}s — {:.2} sessions/s, \
             {:.0} steps/s\n",
            self.devices.len(),
            self.threads,
            self.wall_secs,
            self.sessions_per_sec(),
            self.steps_per_sec()
        ));
        out
    }
}

/// A device checked out of the queue mid-run: its session, data, progress,
/// and the epochs still owed.
struct Job<'a> {
    idx: usize,
    name: String,
    seed: u32,
    session: Session,
    train: DeviceData<'a>,
    test: DeviceData<'a>,
    progress: TrainProgress,
    remaining: usize,
}

/// One unit of queued work: start a device (build + epoch-0 evaluation) or
/// run the next epoch of an already-started one.
enum Task<'a> {
    Start(usize, Device<'a>),
    Epoch(Job<'a>),
}

impl<'a> Fleet<'a> {
    /// Defaults match [`super::SessionBuilder`] except evaluation, which is
    /// batched (8 samples per forward — bit-identical, faster): 1 epoch,
    /// no sample cap, pruning tracking on, auto thread count.
    pub fn builder(backbone: Arc<Backbone>) -> FleetBuilder<'a> {
        FleetBuilder {
            backbone,
            opts: RunOptions {
                epochs: 1,
                limit: 0,
                track_pruning: true,
                verbose: false,
                eval_batch: 8,
                train_batch: 1,
            },
            threads: 0,
            devices: Vec::new(),
            source: DataSource::generated(),
            dataset: "digits".to_string(),
            pairs: HashMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Run every device to completion across the worker pool, one epoch at
    /// a time (round-robin over ready devices).  Device reports come back
    /// in the order the devices were added.
    pub fn run(self) -> Result<FleetReport> {
        let n_devices = self.devices.len();
        let threads = if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(n_devices.max(1))
        } else {
            self.threads.min(n_devices.max(1))
        };
        let t0 = Instant::now();
        let queue: Mutex<VecDeque<Task>> = Mutex::new(
            self.devices
                .into_iter()
                .enumerate()
                .map(|(idx, dev)| Task::Start(idx, dev))
                .collect(),
        );
        let results: Mutex<Vec<(usize, Result<DeviceReport>)>> =
            Mutex::new(Vec::with_capacity(n_devices));
        let backbone = &self.backbone;
        let opts = &self.opts;
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let task =
                        queue.lock().expect("fleet queue poisoned").pop_front();
                    let Some(task) = task else { break };
                    let next = match task {
                        Task::Start(idx, dev) => {
                            match start_device(backbone, opts, idx, dev) {
                                Ok(job) => job,
                                Err(e) => {
                                    results
                                        .lock()
                                        .expect("fleet results poisoned")
                                        .push((idx, Err(e)));
                                    continue;
                                }
                            }
                        }
                        Task::Epoch(mut job) => {
                            job.progress.step_epoch(job.session.driver(),
                                                    job.train.get(),
                                                    job.test.get(), opts);
                            job.remaining -= 1;
                            job
                        }
                    };
                    if next.remaining == 0 {
                        let report = DeviceReport {
                            name: next.name,
                            seed: next.seed,
                            steps: next.progress.metrics().total_steps(),
                            metrics: next.progress.finish(),
                        };
                        results
                            .lock()
                            .expect("fleet results poisoned")
                            .push((next.idx, Ok(report)));
                    } else {
                        queue
                            .lock()
                            .expect("fleet queue poisoned")
                            .push_back(Task::Epoch(next));
                    }
                });
            }
        });
        let mut collected = results.into_inner().expect("fleet results poisoned");
        collected.sort_by_key(|(idx, _)| *idx);
        let mut devices = Vec::with_capacity(n_devices);
        for (_, res) in collected {
            devices.push(res?);
        }
        Ok(FleetReport { devices, wall_secs: t0.elapsed().as_secs_f64(), threads })
    }
}

/// Build a device's session (validating its data against the backbone) and
/// run the epoch-0 evaluation.
fn start_device<'a>(backbone: &Arc<Backbone>, opts: &RunOptions, idx: usize,
                    dev: Device<'a>) -> Result<Job<'a>> {
    crate::data::validate(dev.train.get(), &backbone.spec)
        .with_context(|| format!("fleet device {}: train set", dev.name))?;
    crate::data::validate(dev.test.get(), &backbone.spec)
        .with_context(|| format!("fleet device {}: test set", dev.name))?;
    let mut session = Session::builder()
        .backbone(Arc::clone(backbone))
        .method_boxed(dev.plugin)
        .seed(dev.seed)
        .epochs(opts.epochs)
        .limit(opts.limit)
        .eval_batch(opts.eval_batch)
        .track_pruning(opts.track_pruning)
        .verbose(opts.verbose)
        .build()?;
    let progress = TrainProgress::start(session.driver(), dev.test.get(), opts);
    Ok(Job {
        idx,
        name: dev.name,
        seed: dev.seed,
        session,
        train: dev.train,
        test: dev.test,
        progress,
        remaining: opts.epochs,
    })
}

impl<'a> FleetBuilder<'a> {
    /// Run options applied to every device.
    pub fn options(mut self, opts: RunOptions) -> Self {
        self.opts = opts;
        self
    }

    pub fn epochs(mut self, epochs: usize) -> Self {
        self.opts.epochs = epochs;
        self
    }

    pub fn limit(mut self, limit: usize) -> Self {
        self.opts.limit = limit;
        self
    }

    pub fn track_pruning(mut self, on: bool) -> Self {
        self.opts.track_pruning = on;
        self
    }

    /// Samples per forward in epoch-boundary evaluation (bit-identical to
    /// per-sample; default 8).
    pub fn eval_batch(mut self, batch: usize) -> Self {
        self.opts.eval_batch = batch;
        self
    }

    /// Worker thread count (0 = available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Dataset source consulted by [`Self::device_at`] (default: purely
    /// generated data — artifact-free; pass [`DataSource::auto`] to
    /// prefer artifact files).  Changing the source drops pairs already
    /// resolved through the old one.
    pub fn source(mut self, source: DataSource) -> Self {
        if source != self.source {
            self.pairs.clear();
        }
        self.source = source;
        self
    }

    /// Dataset family resolved by [`Self::device_at`] (default `digits`).
    pub fn dataset(mut self, name: &str) -> Self {
        self.dataset = name.to_string();
        self
    }

    /// Add one device to the fleet (caller-provided data, zero-copy).
    pub fn device(mut self, name: impl Into<String>, seed: u32,
                  plugin: Box<dyn MethodPlugin>, train: &'a Dataset,
                  test: &'a Dataset) -> Self {
        self.devices.push(Device {
            name: name.into(),
            seed,
            plugin,
            train: DeviceData::Borrowed(train),
            test: DeviceData::Borrowed(test),
        });
        self
    }

    /// Add one device over `Arc`-shared datasets (the wire/serve shape).
    pub fn device_shared(mut self, name: impl Into<String>, seed: u32,
                         plugin: Box<dyn MethodPlugin>, train: Arc<Dataset>,
                         test: Arc<Dataset>) -> Self {
        self.devices.push(Device {
            name: name.into(),
            seed,
            plugin,
            train: DeviceData::Shared(train),
            test: DeviceData::Shared(test),
        });
        self
    }

    /// Add one device adapting to its local distribution at `angle`,
    /// resolving the train/test pair through the builder's
    /// [`DataSource`] (see [`Self::source`] / [`Self::dataset`]).  Pairs
    /// are cached per angle, so devices sharing a distribution share one
    /// dataset copy.
    pub fn device_at(mut self, name: impl Into<String>, seed: u32,
                     plugin: Box<dyn MethodPlugin>, angle: u32)
                     -> Result<Self> {
        let key = (self.dataset.clone(), angle);
        if !self.pairs.contains_key(&key) {
            let pair = self
                .source
                .pair(&self.dataset, angle)
                .with_context(|| format!(
                    "resolving {} data at {angle}°", self.dataset))?;
            self.pairs.insert(
                key.clone(), (Arc::new(pair.train), Arc::new(pair.test)));
        }
        let (train, test) = self.pairs[&key].clone();
        Ok(self.device_shared(name, seed, plugin, train, test))
    }

    pub fn build(self) -> Fleet<'a> {
        Fleet {
            backbone: self.backbone,
            opts: self.opts,
            threads: self.threads,
            devices: self.devices,
        }
    }

    /// Build and run in one call.
    pub fn run(self) -> Result<FleetReport> {
        self.build().run()
    }
}
