//! Framed transports: how encoded [`codec`](super::codec) frames move
//! between a [`FleetClient`](super::FleetClient) and a
//! [`FleetServer`](crate::session::FleetServer).
//!
//! A [`Transport`] is one bidirectional connection carrying whole frames.
//! The two implementations carry the *same* encoded bytes, so responses
//! are bit-identical whichever one a client connects through:
//!
//! * [`ChannelTransport`] — in-process, frames over a pair of mpsc
//!   channels (the successor of the old raw `mpsc::Sender<Request>`
//!   front door; [`FleetServer::local_client`] hands one out).
//! * [`TcpTransport`] — frames over a socket, each prefixed with a
//!   little-endian u32 length.  The length prefix is sanity-bounded
//!   before it sizes any allocation, mirroring `serial`'s checked-length
//!   discipline.
//!
//! [`FleetServer::local_client`]: crate::session::FleetServer::local_client

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use anyhow::{anyhow, bail, Context, Result};

use super::codec::MAX_FRAME_LEN;

/// One framed, bidirectional connection.  `&mut self` everywhere — a
/// transport belongs to one thread (the server pumps its side of a
/// connection on dedicated reader/writer threads).
pub trait Transport: Send {
    /// Send one encoded frame to the peer.  Takes the frame by value:
    /// encoders produce owned buffers, and the in-process transport
    /// forwards them without a copy.
    fn send(&mut self, frame: Vec<u8>) -> Result<()>;

    /// Blocking receive of the next frame.  `Ok(None)` = the peer closed
    /// the connection cleanly (no partial frame pending).
    fn recv(&mut self) -> Result<Option<Vec<u8>>>;

    /// Non-blocking receive: `Ok(None)` = no complete frame available
    /// right now (or the peer is gone — a later [`Transport::recv`]
    /// reports that definitively).
    fn try_recv(&mut self) -> Result<Option<Vec<u8>>>;
}

// ---------------------------------------------------------------------------
// In-process transport
// ---------------------------------------------------------------------------

/// In-process transport: frames over a crossed pair of mpsc channels.
/// mpsc messages are already delimited, so a frame is simply one message.
pub struct ChannelTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl ChannelTransport {
    /// A connected pair of endpoints: whatever one sends, the other
    /// receives.
    pub fn pair() -> (ChannelTransport, ChannelTransport) {
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        (
            ChannelTransport { tx: atx, rx: brx },
            ChannelTransport { tx: btx, rx: arx },
        )
    }

    /// Assemble an endpoint from raw halves (the server side of a
    /// connection pumps the two halves on different threads).
    pub fn from_parts(tx: Sender<Vec<u8>>, rx: Receiver<Vec<u8>>) -> Self {
        Self { tx, rx }
    }

    /// Split back into raw halves.
    pub fn into_parts(self) -> (Sender<Vec<u8>>, Receiver<Vec<u8>>) {
        (self.tx, self.rx)
    }
}

impl Transport for ChannelTransport {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        // The frame budget is a *protocol* limit, not a TCP artifact:
        // every transport enforces it, so a request behaves identically
        // in-process and over a socket.
        if frame.len() > MAX_FRAME_LEN {
            bail!("frame of {} bytes exceeds MAX_FRAME_LEN", frame.len());
        }
        self.tx
            .send(frame)
            .map_err(|_| anyhow!("channel transport: peer closed"))
    }

    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        Ok(self.rx.recv().ok())
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        match self.rx.try_recv() {
            Ok(frame) => Ok(Some(frame)),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                Ok(None)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// TCP transport: each frame on the wire is `u32 length (LE)` + payload.
/// Keeps an internal receive buffer so non-blocking polls can accumulate
/// partial frames across calls.
pub struct TcpTransport {
    stream: TcpStream,
    rbuf: Vec<u8>,
    /// Tracked blocking mode, so the per-frame hot path skips the
    /// `fcntl` when the socket is already in the right mode.
    nonblocking: bool,
}

impl TcpTransport {
    /// Connect to a listening [`FleetServer`](crate::session::FleetServer).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .context("connecting to the fleet server")?;
        Ok(Self::from_stream(stream))
    }

    /// Wrap an accepted / connected stream.
    pub fn from_stream(stream: TcpStream) -> Self {
        // Frames are request/response sized; latency beats batching.
        let _ = stream.set_nodelay(true);
        // Normalize to blocking so the tracked mode starts out true.
        let _ = stream.set_nonblocking(false);
        Self { stream, rbuf: Vec::new(), nonblocking: false }
    }

    /// Switch the socket's blocking mode, skipping the syscall when it
    /// is already set.
    fn set_mode(&mut self, nonblocking: bool) -> Result<()> {
        if self.nonblocking != nonblocking {
            self.stream
                .set_nonblocking(nonblocking)
                .context("switching socket blocking mode")?;
            self.nonblocking = nonblocking;
        }
        Ok(())
    }

    /// Pop one complete frame off the receive buffer, if present.
    fn extract(rbuf: &mut Vec<u8>) -> Result<Option<Vec<u8>>> {
        if rbuf.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes([rbuf[0], rbuf[1], rbuf[2], rbuf[3]]) as usize;
        if len > MAX_FRAME_LEN {
            bail!(
                "peer announced a {len}-byte frame (max {MAX_FRAME_LEN}) — \
                 corrupt length prefix?"
            );
        }
        if rbuf.len() < 4 + len {
            return Ok(None);
        }
        let frame = rbuf[4..4 + len].to_vec();
        rbuf.drain(..4 + len);
        Ok(Some(frame))
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        if frame.len() > MAX_FRAME_LEN {
            bail!("frame of {} bytes exceeds MAX_FRAME_LEN", frame.len());
        }
        self.stream
            .write_all(&(frame.len() as u32).to_le_bytes())
            .and_then(|()| self.stream.write_all(&frame))
            .context("writing frame to peer")
    }

    fn recv(&mut self) -> Result<Option<Vec<u8>>> {
        self.set_mode(false)?;
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if let Some(frame) = Self::extract(&mut self.rbuf)? {
                return Ok(Some(frame));
            }
            let n = match self.stream.read(&mut chunk) {
                Ok(n) => n,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("reading frame from peer"),
            };
            if n == 0 {
                if self.rbuf.is_empty() {
                    return Ok(None); // clean close at a frame boundary
                }
                bail!(
                    "connection closed mid-frame ({} buffered bytes)",
                    self.rbuf.len()
                );
            }
            self.rbuf.extend_from_slice(&chunk[..n]);
        }
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        if let Some(frame) = Self::extract(&mut self.rbuf)? {
            return Ok(Some(frame));
        }
        self.set_mode(true)?;
        let mut chunk = [0u8; 16 * 1024];
        let result = loop {
            match self.stream.read(&mut chunk) {
                // 0 = peer closed; report "nothing now" and let the next
                // blocking recv() surface the close (or the mid-frame
                // truncation) definitively.
                Ok(0) => break Ok(None),
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    match Self::extract(&mut self.rbuf) {
                        Ok(Some(frame)) => break Ok(Some(frame)),
                        Ok(None) => continue,
                        Err(e) => break Err(e),
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break Ok(None),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => break Err(e).context("reading frame from peer"),
            }
        };
        // Restore blocking mode before surfacing any result, so a later
        // recv() behaves.
        self.set_mode(false)?;
        result
    }
}
