//! `priot::proto` — the versioned wire protocol between fleet clients and
//! a [`FleetServer`](crate::session::FleetServer).
//!
//! PR 2's serve front-end took requests over a bare in-process mpsc
//! channel; real fleets of Pico-class devices talk over sockets and
//! serial links, so the protocol now has a first-class boundary:
//!
//! * [`Request`] / [`Response`] — plain-data message types.  A `Register`
//!   carries a [`MethodSpec`] (the serializable description of a training
//!   method) and its datasets by value; everything else is scalars.
//! * [`codec`] — the length-delimited binary codec: every frame starts
//!   with a protocol version byte and decodes with the same
//!   checked-length / exact-payload discipline as [`crate::serial`]
//!   (truncated, trailing-byte, and bad-version frames are contextful
//!   errors, never panics or garbage).
//! * [`Transport`] — one framed, bidirectional connection.  Two
//!   implementations: [`ChannelTransport`] (in-process, over mpsc — the
//!   successor of the old raw-channel front door) and [`TcpTransport`]
//!   (length-prefixed frames over a socket).  Both carry the *same*
//!   encoded bytes, so responses are bit-identical across transports.
//! * [`FleetClient`] — the typed client: `register` / `train` /
//!   `predict` / `evaluate` / `drift` synchronous calls, plus
//!   `submit`/`wait`/`poll` for pipelined use.  This is the only public
//!   way to talk to a `FleetServer`.
//!
//! Every request carries a [`Priority`].  The server schedules a
//! device's pending work highest-priority-first (predict > evaluate >
//! train), so an interactive prediction is answered between training
//! epochs instead of waiting behind them; see
//! [`crate::session::serve`] for the scheduling rules.
//!
//! Protocol v2 (the durable-state revision) makes reconnecting clients
//! first-class: a `Register` for a device the server already knows is a
//! **resume** (acknowledged with `Registered { resumed: true }`),
//! errors carry an [`ErrorKind`] so store faults are distinguishable
//! from bad requests, and `Register`/`Drift` can carry drift-angle
//! provenance that ends up in the device's durable snapshot
//! ([`crate::store`]).
//!
//! Protocol v3 (the observability revision) adds the [`Request::GetStats`]
//! admin request: any transport can ask the server for its current
//! [`crate::obs::StatsSnapshot`], answered inline by the dispatcher as a
//! [`Response::Stats`] carrying the snapshot's versioned JSON form — so
//! counter reads never queue behind device work.

pub mod codec;
pub mod transport;

mod client;

pub use client::FleetClient;
pub use transport::{ChannelTransport, TcpTransport, Transport};

use std::sync::Arc;

use crate::serial::Dataset;

// The serializable method description is plain data plus plugin
// materialization, so it lives in the `no_std` core crate
// (`priot_core::methods`); re-exported here because the wire protocol is
// its natural home for callers, and its codec (`codec::put_method` /
// `Reader::method`) stays host-side with the rest of the framing.
pub use priot_core::methods::MethodSpec;

/// Scheduling class of a request.  Lower lane = served first: a device's
/// pending work drains interactive → batch → background, FIFO within a
/// lane.  Every request kind has a natural default
/// ([`Request::priority`]); clients may override it (e.g. a trace replay
/// pins everything to [`Priority::Background`] to preserve strict
/// submission order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive: single-image predictions.
    Interactive = 0,
    /// Bounded batch work: dataset evaluations.
    Batch = 1,
    /// Long-running work: training, drift (data swaps ride with the
    /// training stream so train → drift → train order is preserved).
    Background = 2,
}

impl Priority {
    /// Number of scheduling lanes.
    pub const COUNT: usize = 3;

    /// Lane index (0 = served first).
    pub fn lane(self) -> usize {
        self as usize
    }

    pub(crate) fn to_u8(self) -> u8 {
        self as u8
    }

    pub(crate) fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(Priority::Interactive),
            1 => Some(Priority::Batch),
            2 => Some(Priority::Background),
            _ => None,
        }
    }
}

/// Failure class of a [`Response::Error`], so clients can distinguish a
/// bad request from an infrastructure fault without parsing messages.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ErrorKind {
    /// The request itself failed: unknown device, invalid data, a method
    /// error mid-op, a malformed frame, a full inflight window.
    #[default]
    Request,
    /// The durable state layer failed: a snapshot was missing, corrupt,
    /// or could not be read/written (see [`crate::store`]).
    Store,
    /// The server is shut down; nothing will execute this request.
    Shutdown,
}

impl ErrorKind {
    pub(crate) fn to_u8(self) -> u8 {
        match self {
            ErrorKind::Request => 0,
            ErrorKind::Store => 1,
            ErrorKind::Shutdown => 2,
        }
    }

    pub(crate) fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(ErrorKind::Request),
            1 => Some(ErrorKind::Store),
            2 => Some(ErrorKind::Shutdown),
            _ => None,
        }
    }
}

/// One message into the fleet service.  Datasets travel as `Arc` so
/// *building* and cloning requests is cheap on the client side; on the
/// wire they are serialized by value — every transport, including the
/// in-process channel, carries the same encoded bytes by design (that
/// uniformity is what makes responses bit-identical across transports).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Add a device: the server builds a session over its shared backbone
    /// after validating the device's data against the backbone spec.
    ///
    /// A `Register` for a device the server already knows — resident,
    /// evicted to its state store, or recovered from a previous process —
    /// is a **resume handshake**: the server keeps the device's state,
    /// ignores the supplied datasets, and acknowledges with
    /// [`Response::Registered`]`{ resumed: true }` (identity — seed and
    /// method — must match, otherwise the register errors).  That makes
    /// reconnecting clients first-class: replaying a trace's register
    /// line after a connection drop or a server restart is safe.
    Register {
        device: String,
        seed: u32,
        method: MethodSpec,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
        /// Data provenance, when the client knows it (e.g. the trace's
        /// symbolic rotation angle).  Recorded in the device's durable
        /// snapshot; never interpreted by the server.
        angle: Option<u32>,
    },
    /// Adapt for `epochs` epochs on the device's local train set.
    Train { device: String, epochs: usize },
    /// Classify one raw u8 image (the on-device `p >> 1` pixel mapping is
    /// applied server-side).
    Predict { device: String, image: Vec<u8> },
    /// Top-1 accuracy over the device's local test set (batched forward).
    Evaluate { device: String },
    /// The device's local distribution drifted: swap its datasets.  Rides
    /// the background lane, so it takes effect after the device's
    /// previously queued training, preserving submission order.
    Drift {
        device: String,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
        /// Provenance of the drifted data, when known (see
        /// [`Request::Register::angle`]).
        angle: Option<u32>,
    },
    /// Admin: fetch the server's current [`crate::obs::StatsSnapshot`].
    /// Addresses no device and never queues — the dispatcher answers it
    /// inline with a [`Response::Stats`], so the read is cheap and cannot
    /// perturb device scheduling.
    GetStats,
}

impl Request {
    /// The device a request addresses (empty for admin requests, which
    /// address the server itself).
    pub fn device(&self) -> &str {
        match self {
            Request::Register { device, .. }
            | Request::Train { device, .. }
            | Request::Predict { device, .. }
            | Request::Evaluate { device }
            | Request::Drift { device, .. } => device,
            Request::GetStats => "",
        }
    }

    /// The default scheduling class: predict > evaluate > train/drift.
    pub fn priority(&self) -> Priority {
        match self {
            Request::Predict { .. } | Request::GetStats => {
                Priority::Interactive
            }
            Request::Evaluate { .. } => Priority::Batch,
            Request::Register { .. }
            | Request::Train { .. }
            | Request::Drift { .. } => Priority::Background,
        }
    }
}

/// One message out of the fleet service.  Accuracies are carried as exact
/// f64 bits, so a response decoded off a socket compares bit-identical to
/// one produced in-process.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// One completed [`Request::Register`].  `resumed` is the resume
    /// acknowledgment: `true` means the device already existed (live in
    /// the registry or rehydratable from the state store) and kept its
    /// adapted state — the supplied datasets were ignored.
    Registered { device: String, resumed: bool },
    /// One completed [`Request::Train`]: epochs and **executed** steps.
    TrainDone {
        device: String,
        epochs: usize,
        steps: u64,
        train_accuracy: f64,
    },
    Prediction { device: String, class: usize },
    Evaluation { device: String, accuracy: f64, n: usize },
    Drifted { device: String },
    /// One answered [`Request::GetStats`]: the server's current
    /// [`crate::obs::StatsSnapshot`] in its versioned JSON form (parse
    /// with [`crate::obs::StatsSnapshot::from_json`]).
    Stats { json: String },
    Error { device: String, kind: ErrorKind, message: String },
}

impl Response {
    pub fn device(&self) -> &str {
        match self {
            Response::Registered { device, .. }
            | Response::TrainDone { device, .. }
            | Response::Prediction { device, .. }
            | Response::Evaluation { device, .. }
            | Response::Drifted { device }
            | Response::Error { device, .. } => device,
            Response::Stats { .. } => "",
        }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, Response::Error { .. })
    }
}
