//! The binary codec for [`Request`]/[`Response`] frames.
//!
//! A *frame* is one encoded message; transports delimit frames (mpsc
//! messages are frames, TCP prefixes each frame with a u32 length).  All
//! integers little-endian; floats travel as their exact IEEE-754 bits.
//!
//! Frame layout:
//!
//! ```text
//! u8  protocol version (= PROTO_VERSION)
//! u8  frame type       (0 = request, 1 = response)
//! u64 request id       (assigned by the client; echoed in the response)
//! -- request:  u8 priority, u8 variant tag, fields
//! -- response: u8 variant tag, fields
//! ```
//!
//! Strings are `u32 len + utf8 bytes`; byte blobs are `u32 len + raw`;
//! datasets are `u32 n,c,h,w` followed by the implied `n·c·h·w` image
//! bytes and `n` label bytes — decoded with the same overflow-checked
//! size / exact-payload discipline as [`crate::serial`], so truncated,
//! trailing-byte, and bad-version frames come back as contextful errors.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{Method, Selection};
use crate::serial::Dataset;

use super::{ErrorKind, MethodSpec, Priority, Request, Response};

/// Protocol revision spoken by this build.  Bump on any layout change;
/// decoders reject other versions with a clean error.
///
/// v2 (the durable-state revision): `Registered` carries a `resumed`
/// flag, `Error` carries an [`ErrorKind`] byte, and `Register`/`Drift`
/// carry an optional drift-angle provenance field.
///
/// v3 (the observability revision): the `GetStats` admin request (a bare
/// tag — no fields) and the `Stats` response carrying the snapshot JSON.
pub const PROTO_VERSION: u8 = 3;

/// The protocol-wide frame budget, enforced by **every** transport on
/// send and receive (so a too-large request fails identically in-process
/// and over a socket), and doubling as the sanity bound on length
/// prefixes read off an untrusted socket — a corrupt prefix must not
/// allocate garbage.
pub const MAX_FRAME_LEN: usize = 64 << 20;

const FRAME_REQUEST: u8 = 0;
const FRAME_RESPONSE: u8 = 1;

const REQ_REGISTER: u8 = 0;
const REQ_TRAIN: u8 = 1;
const REQ_PREDICT: u8 = 2;
const REQ_EVALUATE: u8 = 3;
const REQ_DRIFT: u8 = 4;
const REQ_GETSTATS: u8 = 5;

const RESP_REGISTERED: u8 = 0;
const RESP_TRAIN_DONE: u8 = 1;
const RESP_PREDICTION: u8 = 2;
const RESP_EVALUATION: u8 = 3;
const RESP_DRIFTED: u8 = 4;
const RESP_ERROR: u8 = 5;
const RESP_STATS: u8 = 6;

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u32(buf, b.len() as u32);
    buf.extend_from_slice(b);
}

/// Optional u32: a presence byte, then the value when present.
pub(crate) fn put_opt_u32(buf: &mut Vec<u8>, v: Option<u32>) {
    match v {
        None => buf.push(0),
        Some(x) => {
            buf.push(1);
            put_u32(buf, x);
        }
    }
}

pub(crate) fn put_dataset(buf: &mut Vec<u8>, ds: &Dataset) {
    put_u32(buf, ds.n as u32);
    put_u32(buf, ds.c as u32);
    put_u32(buf, ds.h as u32);
    put_u32(buf, ds.w as u32);
    buf.extend_from_slice(&ds.images);
    buf.extend_from_slice(&ds.labels);
}

pub(crate) fn put_method(buf: &mut Vec<u8>, m: &MethodSpec) {
    buf.push(match m.method {
        Method::StaticNiti => 0,
        Method::DynamicNiti => 1,
        Method::Priot => 2,
        Method::PriotS => 3,
    });
    put_f64(buf, m.frac_scored);
    buf.push(match m.selection {
        Selection::Random => 0,
        Selection::WeightBased => 1,
    });
    match m.theta {
        None => buf.push(0),
        Some(t) => {
            buf.push(1);
            put_u32(buf, t as u32);
        }
    }
}

/// Encode one request frame (version, type, id, priority, body).
pub fn encode_request(id: u64, priority: Priority, req: &Request) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.push(PROTO_VERSION);
    buf.push(FRAME_REQUEST);
    put_u64(&mut buf, id);
    buf.push(priority.to_u8());
    match req {
        Request::Register { device, seed, method, train, test, angle } => {
            buf.push(REQ_REGISTER);
            put_str(&mut buf, device);
            put_u32(&mut buf, *seed);
            put_method(&mut buf, method);
            put_dataset(&mut buf, train);
            put_dataset(&mut buf, test);
            put_opt_u32(&mut buf, *angle);
        }
        Request::Train { device, epochs } => {
            buf.push(REQ_TRAIN);
            put_str(&mut buf, device);
            put_u64(&mut buf, *epochs as u64);
        }
        Request::Predict { device, image } => {
            buf.push(REQ_PREDICT);
            put_str(&mut buf, device);
            put_bytes(&mut buf, image);
        }
        Request::Evaluate { device } => {
            buf.push(REQ_EVALUATE);
            put_str(&mut buf, device);
        }
        Request::Drift { device, train, test, angle } => {
            buf.push(REQ_DRIFT);
            put_str(&mut buf, device);
            put_dataset(&mut buf, train);
            put_dataset(&mut buf, test);
            put_opt_u32(&mut buf, *angle);
        }
        Request::GetStats => buf.push(REQ_GETSTATS),
    }
    buf
}

/// Encode one response frame (version, type, id, body).
pub fn encode_response(id: u64, resp: &Response) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.push(PROTO_VERSION);
    buf.push(FRAME_RESPONSE);
    put_u64(&mut buf, id);
    match resp {
        Response::Registered { device, resumed } => {
            buf.push(RESP_REGISTERED);
            put_str(&mut buf, device);
            buf.push(u8::from(*resumed));
        }
        Response::TrainDone { device, epochs, steps, train_accuracy } => {
            buf.push(RESP_TRAIN_DONE);
            put_str(&mut buf, device);
            put_u64(&mut buf, *epochs as u64);
            put_u64(&mut buf, *steps);
            put_f64(&mut buf, *train_accuracy);
        }
        Response::Prediction { device, class } => {
            buf.push(RESP_PREDICTION);
            put_str(&mut buf, device);
            put_u64(&mut buf, *class as u64);
        }
        Response::Evaluation { device, accuracy, n } => {
            buf.push(RESP_EVALUATION);
            put_str(&mut buf, device);
            put_f64(&mut buf, *accuracy);
            put_u64(&mut buf, *n as u64);
        }
        Response::Drifted { device } => {
            buf.push(RESP_DRIFTED);
            put_str(&mut buf, device);
        }
        Response::Stats { json } => {
            buf.push(RESP_STATS);
            put_str(&mut buf, json);
        }
        Response::Error { device, kind, message } => {
            buf.push(RESP_ERROR);
            put_str(&mut buf, device);
            buf.push(kind.to_u8());
            put_str(&mut buf, message);
        }
    }
    buf
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

/// Checked cursor over one frame: every read names what it is reading, so
/// a truncated frame yields "frame truncated reading X", never a panic.
/// Crate-visible so the [`crate::store`] snapshot codec decodes with the
/// same discipline.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "frame truncated reading {what} (need {n} bytes at offset {}, \
                 frame is {} bytes)",
                self.pos,
                self.buf.len()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub(crate) fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub(crate) fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// Optional u32 written by [`put_opt_u32`].
    pub(crate) fn opt_u32(&mut self, what: &str) -> Result<Option<u32>> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.u32(what)?)),
            other => bail!("bad {what} presence flag {other} (want 0|1)"),
        }
    }

    pub(crate) fn str(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .with_context(|| format!("{what} is not valid UTF-8"))
    }

    fn bytes(&mut self, what: &str) -> Result<Vec<u8>> {
        let len = self.u32(what)? as usize;
        Ok(self.take(len, what)?.to_vec())
    }

    pub(crate) fn dataset(&mut self, what: &str) -> Result<Arc<Dataset>> {
        let n = self.u32(what)? as usize;
        let c = self.u32(what)? as usize;
        let h = self.u32(what)? as usize;
        let w = self.u32(what)? as usize;
        // Same discipline as `serial::load_dataset`: the dims are
        // untrusted, so the product is overflow-checked and bounded
        // before it sizes any read.
        let total = [n, c, h, w]
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .filter(|&t| t <= 1 << 31)
            .with_context(|| {
                format!("{what}: implausible dims n={n} c={c} h={h} w={w}")
            })?;
        let images = self.take(total, what)?.to_vec();
        let labels = self.take(n, what)?.to_vec();
        Ok(Arc::new(Dataset { n, c, h, w, images, labels }))
    }

    pub(crate) fn method(&mut self) -> Result<MethodSpec> {
        let method = match self.u8("method tag")? {
            0 => Method::StaticNiti,
            1 => Method::DynamicNiti,
            2 => Method::Priot,
            3 => Method::PriotS,
            other => bail!("unknown method tag {other}"),
        };
        let frac_scored = self.f64("method frac_scored")?;
        let selection = match self.u8("method selection")? {
            0 => Selection::Random,
            1 => Selection::WeightBased,
            other => bail!("unknown selection tag {other}"),
        };
        let theta = match self.u8("method theta flag")? {
            0 => None,
            1 => Some(self.u32("method theta")? as i32),
            other => bail!("bad theta flag {other} (want 0|1)"),
        };
        Ok(MethodSpec { method, frac_scored, selection, theta })
    }

    /// Error unless the whole frame was consumed (frames are fixed-layout:
    /// trailing bytes mean a corrupt or mismatched encoder).
    pub(crate) fn finish(self, what: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "{} trailing bytes after {what}",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }

    /// Version + frame-type + id header shared by both frame kinds.
    fn header(&mut self, want_type: u8, what: &str) -> Result<u64> {
        let version = self.u8("protocol version")?;
        if version != PROTO_VERSION {
            bail!(
                "unsupported protocol version {version} \
                 (this build speaks version {PROTO_VERSION})"
            );
        }
        let ty = self.u8("frame type")?;
        if ty != want_type {
            bail!("expected a {what} frame, got frame type {ty}");
        }
        self.u64("request id")
    }
}

/// Best-effort request id of a frame that failed to decode: both frame
/// kinds carry the id at bytes 2..10, so a server can still answer a
/// malformed request *by id* (and a synchronous client waiting on that
/// id gets its error instead of hanging) as long as the fixed header is
/// intact.  Returns 0 — an id no client ever assigns — when the frame is
/// too short to carry one.
pub fn frame_request_id(frame: &[u8]) -> u64 {
    match frame.get(2..10) {
        Some(b) => u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]),
        None => 0,
    }
}

/// Decode one request frame into `(id, priority, request)`.
pub fn decode_request(frame: &[u8]) -> Result<(u64, Priority, Request)> {
    let mut r = Reader::new(frame);
    let id = r.header(FRAME_REQUEST, "request")?;
    let priority = {
        let v = r.u8("priority")?;
        Priority::from_u8(v)
            .with_context(|| format!("unknown priority {v} (want 0|1|2)"))?
    };
    let tag = r.u8("request tag")?;
    let req = match tag {
        REQ_REGISTER => {
            let device = r.str("register device")?;
            let seed = r.u32("register seed")?;
            let method = r.method()?;
            let train = r.dataset("register train set")?;
            let test = r.dataset("register test set")?;
            let angle = r.opt_u32("register angle")?;
            Request::Register { device, seed, method, train, test, angle }
        }
        REQ_TRAIN => Request::Train {
            device: r.str("train device")?,
            epochs: r.u64("train epochs")? as usize,
        },
        REQ_PREDICT => Request::Predict {
            device: r.str("predict device")?,
            image: r.bytes("predict image")?,
        },
        REQ_EVALUATE => Request::Evaluate { device: r.str("evaluate device")? },
        REQ_GETSTATS => Request::GetStats,
        REQ_DRIFT => {
            let device = r.str("drift device")?;
            let train = r.dataset("drift train set")?;
            let test = r.dataset("drift test set")?;
            let angle = r.opt_u32("drift angle")?;
            Request::Drift { device, train, test, angle }
        }
        other => bail!("unknown request tag {other}"),
    };
    r.finish("the request body")?;
    Ok((id, priority, req))
}

/// Decode one response frame into `(id, response)`.
pub fn decode_response(frame: &[u8]) -> Result<(u64, Response)> {
    let mut r = Reader::new(frame);
    let id = r.header(FRAME_RESPONSE, "response")?;
    let tag = r.u8("response tag")?;
    let resp = match tag {
        RESP_REGISTERED => Response::Registered {
            device: r.str("registered device")?,
            resumed: match r.u8("registered resumed flag")? {
                0 => false,
                1 => true,
                other => bail!("bad resumed flag {other} (want 0|1)"),
            },
        },
        RESP_TRAIN_DONE => Response::TrainDone {
            device: r.str("train-done device")?,
            epochs: r.u64("train-done epochs")? as usize,
            steps: r.u64("train-done steps")?,
            train_accuracy: r.f64("train-done accuracy")?,
        },
        RESP_PREDICTION => Response::Prediction {
            device: r.str("prediction device")?,
            class: r.u64("prediction class")? as usize,
        },
        RESP_EVALUATION => Response::Evaluation {
            device: r.str("evaluation device")?,
            accuracy: r.f64("evaluation accuracy")?,
            n: r.u64("evaluation n")? as usize,
        },
        RESP_DRIFTED => Response::Drifted { device: r.str("drifted device")? },
        RESP_STATS => Response::Stats { json: r.str("stats json")? },
        RESP_ERROR => Response::Error {
            device: r.str("error device")?,
            kind: {
                let v = r.u8("error kind")?;
                ErrorKind::from_u8(v)
                    .with_context(|| format!("unknown error kind {v}"))?
            },
            message: r.str("error message")?,
        },
        other => bail!("unknown response tag {other}"),
    };
    r.finish("the response body")?;
    Ok((id, resp))
}
