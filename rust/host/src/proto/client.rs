//! The typed fleet client — the public front door to a
//! [`FleetServer`](crate::session::FleetServer).

use std::collections::VecDeque;
use std::net::ToSocketAddrs;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::serial::Dataset;

use super::codec::{decode_response, encode_request};
use super::transport::{TcpTransport, Transport};
use super::{MethodSpec, Priority, Request, Response};

/// A connection to a fleet server over any [`Transport`].
///
/// Two usage styles, freely mixable on one connection:
///
/// * **Synchronous** — [`register`](Self::register) /
///   [`train`](Self::train) / [`predict`](Self::predict) /
///   [`evaluate`](Self::evaluate) / [`drift`](Self::drift) each send one
///   request and block until *its* response arrives.  Because at most one
///   request is then in flight, responses arrive in strict submission
///   order — the mode trace replays use for deterministic,
///   standalone-bit-identical results.
/// * **Pipelined** — [`submit`](Self::submit) /
///   [`submit_with`](Self::submit_with) return a request id immediately;
///   collect responses with [`wait`](Self::wait) (one id, blocking),
///   [`next_response`](Self::next_response) (stream order, blocking), or
///   [`poll`](Self::poll) (non-blocking).  Pipelined requests are where
///   the server's priority scheduling shows: a `Predict` submitted behind
///   a long `Train` on the same device is answered between training
///   epochs, not after them.
///
/// Dropping the client closes the connection; a server waiting in
/// `join()` sees the stream end and shuts down gracefully.
pub struct FleetClient {
    transport: Box<dyn Transport>,
    next_id: u64,
    /// Responses received while waiting for a different request id.
    inbox: VecDeque<(u64, Response)>,
}

impl FleetClient {
    /// Wrap an already-connected transport.
    pub fn over(transport: impl Transport + 'static) -> Self {
        Self {
            transport: Box::new(transport),
            next_id: 1,
            inbox: VecDeque::new(),
        }
    }

    /// Connect to a listening server over TCP
    /// (see [`FleetServer::listen`](crate::session::FleetServer::listen)).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(Self::over(TcpTransport::connect(addr)?))
    }

    /// Send one request at its default priority; returns its request id.
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        let priority = req.priority();
        self.submit_with(req, priority)
    }

    /// Send one request at an explicit [`Priority`]; returns its id.
    pub fn submit_with(&mut self, req: Request, priority: Priority)
                       -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = encode_request(id, priority, &req);
        self.transport
            .send(frame)
            .with_context(|| format!("sending request {id}"))?;
        Ok(id)
    }

    /// Block until the response for request `id` arrives.  Responses for
    /// other in-flight requests are buffered for [`Self::poll`] /
    /// [`Self::next_response`].
    pub fn wait(&mut self, id: u64) -> Result<Response> {
        if let Some(i) = self.inbox.iter().position(|(rid, _)| *rid == id) {
            return Ok(self.inbox.remove(i).expect("indexed entry").1);
        }
        loop {
            let frame = match self.transport.recv()? {
                Some(f) => f,
                None => bail!(
                    "connection closed while waiting for request {id}"
                ),
            };
            let (rid, resp) = decode_response(&frame)?;
            if rid == id {
                return Ok(resp);
            }
            self.inbox.push_back((rid, resp));
        }
    }

    /// Block for the next response in stream order (buffered first).
    /// `Ok(None)` = the connection closed with nothing pending.
    pub fn next_response(&mut self) -> Result<Option<(u64, Response)>> {
        if let Some(entry) = self.inbox.pop_front() {
            return Ok(Some(entry));
        }
        match self.transport.recv()? {
            Some(frame) => Ok(Some(decode_response(&frame)?)),
            None => Ok(None),
        }
    }

    /// Every response available right now, without blocking: buffered
    /// ones first, then whatever complete frames the transport has.
    ///
    /// Drains the transport *into the buffer* before handing anything
    /// out, so a transport or decode error mid-poll loses nothing:
    /// already-received responses stay buffered for the next call (or
    /// for [`Self::wait`]).
    pub fn poll(&mut self) -> Result<Vec<(u64, Response)>> {
        while let Some(frame) = self.transport.try_recv()? {
            let decoded = decode_response(&frame)?;
            self.inbox.push_back(decoded);
        }
        Ok(self.inbox.drain(..).collect())
    }

    // -- synchronous calls --------------------------------------------------

    fn call(&mut self, req: Request) -> Result<Response> {
        let id = self.submit(req)?;
        self.wait(id)
    }

    /// Register a device (synchronous).  Server-side failures come back
    /// as a [`Response::Error`] value, not an `Err` — transport and
    /// protocol failures are the `Err` path.
    ///
    /// Registering a device the server already knows (same seed and
    /// method) is a *resume*: the device keeps its adapted state and the
    /// response comes back with `resumed: true` — so re-sending the
    /// register after a reconnect or a server restart is safe.
    pub fn register(&mut self, device: &str, seed: u32, method: MethodSpec,
                    train: Arc<Dataset>, test: Arc<Dataset>)
                    -> Result<Response> {
        self.register_at(device, seed, method, train, test, None)
    }

    /// [`Self::register`] with explicit data provenance (e.g. the trace's
    /// drift angle), recorded in the device's durable snapshot.
    pub fn register_at(&mut self, device: &str, seed: u32, method: MethodSpec,
                       train: Arc<Dataset>, test: Arc<Dataset>,
                       angle: Option<u32>) -> Result<Response> {
        self.call(Request::Register {
            device: device.to_string(),
            seed,
            method,
            train,
            test,
            angle,
        })
    }

    /// Train `epochs` epochs on the device's local data (synchronous).
    pub fn train(&mut self, device: &str, epochs: usize) -> Result<Response> {
        self.call(Request::Train { device: device.to_string(), epochs })
    }

    /// Classify one raw u8 image (synchronous).
    pub fn predict(&mut self, device: &str, image: Vec<u8>)
                   -> Result<Response> {
        self.call(Request::Predict { device: device.to_string(), image })
    }

    /// Evaluate top-1 accuracy over the device's test set (synchronous).
    pub fn evaluate(&mut self, device: &str) -> Result<Response> {
        self.call(Request::Evaluate { device: device.to_string() })
    }

    /// Swap the device's local datasets (synchronous).
    pub fn drift(&mut self, device: &str, train: Arc<Dataset>,
                 test: Arc<Dataset>) -> Result<Response> {
        self.drift_at(device, train, test, None)
    }

    /// [`Self::drift`] with explicit data provenance (see
    /// [`Self::register_at`]).
    pub fn drift_at(&mut self, device: &str, train: Arc<Dataset>,
                    test: Arc<Dataset>, angle: Option<u32>)
                    -> Result<Response> {
        self.call(Request::Drift {
            device: device.to_string(),
            train,
            test,
            angle,
        })
    }

    /// Fetch the server's current stats snapshot (synchronous).  Answered
    /// inline by the dispatcher — it never queues behind device work —
    /// as a [`Response::Stats`] whose JSON body parses with
    /// [`crate::obs::StatsSnapshot::from_json`].
    pub fn get_stats(&mut self) -> Result<Response> {
        self.call(Request::GetStats)
    }
}
