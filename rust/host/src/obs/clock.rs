//! Wall-clock capture for host-side telemetry: the one deliberately
//! non-integer corner of `priot::obs`.
//!
//! Everything that reads a clock lives here — [`Timer`] for one span,
//! [`Stopwatch`] for repeated laps — so the record path in
//! [`super`] stays float-free and the rest of the tree has a single
//! timing source (the coordinator's epoch timing and the serve
//! lifecycle spans both go through [`Timer`]; the old
//! `metrics::Stopwatch` is deprecated in favor of [`Stopwatch`]).
//! Spans are captured as integer microseconds; float conversion happens
//! only at reporting seams (`elapsed_secs`, `stats_ms`).

use std::time::Instant;

use crate::metrics::MeanStd;

/// One running span: start it, read it (in integer microseconds for the
/// obs histograms, or float seconds for report-layer rates).
#[derive(Clone, Copy, Debug)]
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    /// Wrap an externally captured start instant (e.g. a queue item's
    /// enqueue time) so its span reads like any other [`Timer`].
    pub fn since(start: Instant) -> Self {
        Self(start)
    }

    /// Elapsed integer microseconds (saturating — a span cannot panic).
    pub fn elapsed_us(&self) -> u64 {
        u64::try_from(self.0.elapsed().as_micros()).unwrap_or(u64::MAX)
    }

    /// Elapsed seconds, for report-layer rate math only — never feed
    /// this back into a recording path.
    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Repeated-lap stopwatch over integer-microsecond spans (the
/// `metrics::Stopwatch` replacement: same start/lap/stats_ms surface,
/// integer laps underneath).
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps_us: Vec<u64>,
    started: Option<Timer>,
}

impl Stopwatch {
    pub fn start(&mut self) {
        self.started = Some(Timer::start());
    }

    /// Close the running span (if any) and return its length in
    /// microseconds.
    pub fn lap(&mut self) -> u64 {
        match self.started.take() {
            Some(t) => {
                let us = t.elapsed_us();
                self.laps_us.push(us);
                us
            }
            None => 0,
        }
    }

    pub fn count(&self) -> usize {
        self.laps_us.len()
    }

    pub fn laps_us(&self) -> &[u64] {
        &self.laps_us
    }

    /// Mean/std over laps in milliseconds (the Table II rendering).
    pub fn stats_ms(&self) -> MeanStd {
        let ms: Vec<f64> =
            self.laps_us.iter().map(|&us| us as f64 / 1e3).collect();
        MeanStd::of(&ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::default();
        assert_eq!(sw.lap(), 0, "lap without start is a no-op");
        sw.start();
        sw.lap();
        sw.start();
        sw.lap();
        assert_eq!(sw.count(), 2);
        assert_eq!(sw.stats_ms().n, 2);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_us();
        let b = t.elapsed_us();
        assert!(b >= a);
    }
}
