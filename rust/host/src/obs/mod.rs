//! priot::obs — fleet-wide telemetry primitives.
//!
//! The measurement layer under the serving stack: sharded atomic
//! [`Counter`]s, high-water [`Gauge`]s, and fixed power-of-two-bucket
//! integer latency [`Histogram`]s, composed into the request-lifecycle
//! span model ([`ServeObs`]) that every serve module records through and
//! exported as versioned, mergeable [`StatsSnapshot`]s (embedded in
//! `ServeReport`, answered over the wire via the proto `GetStats`
//! request, and dumped by `priot serve --stats-interval/--stats-json`).
//!
//! Design rules, enforced by `rust/cli/tests/layering.rs`:
//!
//! * **No floats on the record path.**  Everything in this file is
//!   integer arithmetic — histograms bucket by power of two, quantiles
//!   are integer bucket upper bounds — so recording can never perturb
//!   the deterministic integer engine and snapshots compare with `==`.
//!   Wall-clock *capture* (the one inherently host-side, non-integer
//!   act) lives apart in [`clock`].
//! * **Lock-free increments.**  [`Counter::add`], [`Gauge::record`] and
//!   [`Histogram::record`] are relaxed atomics; the only lock in the
//!   module is the engine-counter merge, taken once per executed unit.
//! * **Saturating arithmetic everywhere** — a counter wrap must not
//!   panic a serving fleet; the file carries the same
//!   `arithmetic_side_effects` lint wall as the core numeric modules.

#![deny(clippy::arithmetic_side_effects)]

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

pub mod clock;

pub use clock::{Stopwatch, Timer};

/// Version tag written into every [`StatsSnapshot`] JSON document.
pub const SNAPSHOT_SCHEMA: u32 = 1;

/// Number of power-of-two histogram buckets: bucket 0 holds the value 0,
/// bucket `i >= 1` holds `[2^(i-1), 2^i - 1]`, bucket 63 tops out at
/// `u64::MAX`.
pub const HIST_BUCKETS: usize = 64;

const COUNTER_SHARDS: usize = 8;
const COUNTER_SHARD_MASK: usize = COUNTER_SHARDS - 1;

/// Scheduling lanes mirrored from `proto::Priority` (obs stays below the
/// proto layer, so the width is pinned here and asserted at the serve
/// seam).
pub const LANES: usize = 3;
pub const LANE_NAMES: [&str; LANES] = ["interactive", "batch", "background"];

/// The bucket a value lands in: 0 → 0, otherwise one bucket per bit
/// width (64 - leading zeros), capped at the top bucket.
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS.saturating_sub(v.leading_zeros()) as usize)
        .min(HIST_BUCKETS.saturating_sub(1))
}

/// Largest value that lands in bucket `i` (the value quantiles report).
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= HIST_BUCKETS.saturating_sub(1) {
        u64::MAX
    } else {
        // 2^i - 1; i < 63 here, so the shift cannot overflow.
        1u64.wrapping_shl(i as u32).wrapping_sub(1)
    }
}

static NEXT_THREAD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Round-robin shard assignment at first use per thread: spreads
    /// concurrent increments across cache lines without hashing.
    static SHARD: usize =
        NEXT_THREAD.fetch_add(1, Ordering::Relaxed) & COUNTER_SHARD_MASK;
}

/// Sharded monotonic counter: each thread increments its own shard
/// (lock-free, no contended cache line); [`Counter::get`] folds all
/// shards.
#[derive(Debug, Default)]
pub struct Counter {
    shards: [AtomicU64; COUNTER_SHARDS],
}

impl Counter {
    pub fn add(&self, n: u64) {
        let i = SHARD.with(|s| *s);
        if let Some(s) = self.shards.get(i) {
            s.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.shards
            .iter()
            .fold(0u64, |acc, s| acc.saturating_add(s.load(Ordering::Relaxed)))
    }
}

/// High-water gauge: [`Gauge::record`] keeps the maximum value ever
/// seen (lock-free `fetch_max`).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn record(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed power-of-two-bucket integer latency histogram.  Recording is
/// three relaxed atomic RMWs (count/sum/max) plus one bucket increment —
/// no floats, no locks, no allocation.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // array seed only
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: [ZERO; HIST_BUCKETS],
        }
    }

    /// Record one integer observation (microseconds on the serve paths).
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        if let Some(b) = self.buckets.get(bucket_index(v)) {
            b.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push((i, n));
            }
        }
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Point-in-time copy of one [`Histogram`]: plain data, mergeable, with
/// integer quantiles (each quantile reports the upper bound of the
/// bucket its rank falls in, capped at the observed max).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    /// Sparse non-empty buckets, ascending: `(bucket index, count)`.
    pub buckets: Vec<(usize, u64)>,
}

impl HistSnapshot {
    /// Fold `other` into `self`.  Associative and commutative up to
    /// saturation, so multi-shard snapshots can merge in any order.
    pub fn merge(&mut self, other: &Self) {
        let mut dense = [0u64; HIST_BUCKETS];
        for &(i, n) in self.buckets.iter().chain(other.buckets.iter()) {
            if let Some(slot) = dense.get_mut(i) {
                *slot = slot.saturating_add(n);
            }
        }
        self.buckets = dense
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(i, &n)| (i, n))
            .collect();
        self.count = self.count.saturating_add(other.count);
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Integer quantile estimate: the bucket upper bound at which the
    /// cumulative count first reaches `num/den` of all observations,
    /// capped at the observed max.  Monotone in `num/den`.
    pub fn quantile(&self, num: u64, den: u64) -> u64 {
        if self.count == 0 || den == 0 {
            return 0;
        }
        let scaled = self.count.saturating_mul(num);
        // ceil(scaled / den), at least rank 1.
        let rank = scaled
            .saturating_add(den.saturating_sub(1))
            .checked_div(den)
            .unwrap_or(0)
            .max(1);
        let mut cum = 0u64;
        for &(i, n) in &self.buckets {
            cum = cum.saturating_add(n);
            if cum >= rank {
                return bucket_upper_bound(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(50, 100)
    }

    pub fn p90(&self) -> u64 {
        self.quantile(90, 100)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(99, 100)
    }

    /// Integer mean (floor), 0 when empty.
    pub fn mean(&self) -> u64 {
        self.sum.checked_div(self.count).unwrap_or(0)
    }
}

/// Request kinds observed at the serve boundary (mirrors
/// `proto::Request` without depending on it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Register,
    Train,
    Predict,
    Evaluate,
    Drift,
    GetStats,
}

const OPS: usize = 6;
/// Ops with a worker execute stage (`GetStats` is answered inline by the
/// dispatcher and has none).
const EXEC_OPS: usize = 5;
const EXEC_NAMES: [&str; EXEC_OPS] =
    ["register", "train_epoch", "predict", "evaluate", "drift"];

fn op_slot(op: Op) -> usize {
    match op {
        Op::Register => 0,
        Op::Train => 1,
        Op::Predict => 2,
        Op::Evaluate => 3,
        Op::Drift => 4,
        Op::GetStats => 5,
    }
}

/// Per-op request counts (plain data; the snapshot form of the sharded
/// request counters).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub register: u64,
    pub train: u64,
    pub predict: u64,
    pub evaluate: u64,
    pub drift: u64,
    pub get_stats: u64,
}

impl OpCounts {
    pub fn total(&self) -> u64 {
        self.register
            .saturating_add(self.train)
            .saturating_add(self.predict)
            .saturating_add(self.evaluate)
            .saturating_add(self.drift)
            .saturating_add(self.get_stats)
    }

    fn merge(&mut self, o: &Self) {
        self.register = self.register.saturating_add(o.register);
        self.train = self.train.saturating_add(o.train);
        self.predict = self.predict.saturating_add(o.predict);
        self.evaluate = self.evaluate.saturating_add(o.evaluate);
        self.drift = self.drift.saturating_add(o.drift);
        self.get_stats = self.get_stats.saturating_add(o.get_stats);
    }
}

/// Deterministic integer perf counters drained from `priot-core` engines
/// after every executed unit (all zeros when the `obs` cargo feature is
/// compiled out).  MACs are *counted* multiply-accumulates, so
/// throughput derived from them is exact, not estimated.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    pub scalar_calls: u64,
    pub scalar_macs: u64,
    pub tiled_calls: u64,
    pub tiled_macs: u64,
    pub gemv_hits: u64,
    pub theta_fallbacks: u64,
    pub scratch_high_water_bytes: u64,
}

impl EngineStats {
    pub fn macs(&self) -> u64 {
        self.scalar_macs.saturating_add(self.tiled_macs)
    }

    pub fn merge(&mut self, o: &Self) {
        self.scalar_calls = self.scalar_calls.saturating_add(o.scalar_calls);
        self.scalar_macs = self.scalar_macs.saturating_add(o.scalar_macs);
        self.tiled_calls = self.tiled_calls.saturating_add(o.tiled_calls);
        self.tiled_macs = self.tiled_macs.saturating_add(o.tiled_macs);
        self.gemv_hits = self.gemv_hits.saturating_add(o.gemv_hits);
        self.theta_fallbacks =
            self.theta_fallbacks.saturating_add(o.theta_fallbacks);
        self.scratch_high_water_bytes = self
            .scratch_high_water_bytes
            .max(o.scratch_high_water_bytes);
    }
}

/// Per-device accumulated telemetry (kept under the serve registry lock
/// alongside the device's other bookkeeping — no extra locking).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeviceStats {
    pub device: String,
    /// Completed worker units (epochs count individually).
    pub ops_done: u64,
    pub queue_wait_us: u64,
    pub execute_us: u64,
}

/// The serve stack's live telemetry: every lifecycle stage of every
/// request records here — ingress decode → lane-queue wait → worker
/// execute (split per op) → snapshot persist → response encode — plus
/// request/response/error counters, the queue high-water gauge, and the
/// merged engine perf counters.
#[derive(Debug, Default)]
pub struct ServeObs {
    requests: [Counter; OPS],
    pub responses: Counter,
    pub errors: Counter,
    pub queue_high_water: Gauge,
    pub decode: Histogram,
    queue_wait: [Histogram; LANES],
    exec: [Histogram; EXEC_OPS],
    pub persist: Histogram,
    pub encode: Histogram,
    engine: Mutex<EngineStats>,
}

impl ServeObs {
    pub fn note_request(&self, op: Op) {
        if let Some(c) = self.requests.get(op_slot(op)) {
            c.inc();
        }
    }

    pub fn note_response(&self, is_error: bool) {
        self.responses.inc();
        if is_error {
            self.errors.inc();
        }
    }

    pub fn record_queue_wait(&self, lane: usize, us: u64) {
        if let Some(h) = self.queue_wait.get(lane) {
            h.record(us);
        }
    }

    /// Record one worker execute span (a no-op for `GetStats`, which
    /// never reaches a worker).
    pub fn record_exec(&self, op: Op, us: u64) {
        if let Some(h) = self.exec.get(op_slot(op)) {
            h.record(us);
        }
    }

    /// Fold one drained engine-counter reading in (called by workers
    /// after every executed unit, before the response is emitted — so a
    /// synchronous client's follow-up `GetStats` always sees the MACs of
    /// every response it has received).
    pub fn merge_engine(&self, tiled: bool, calls: u64, macs: u64,
                        gemv_hits: u64, theta_fallbacks: u64,
                        scratch_high_water_bytes: u64) {
        let mut e = self.engine.lock().expect("obs engine stats");
        if tiled {
            e.tiled_calls = e.tiled_calls.saturating_add(calls);
            e.tiled_macs = e.tiled_macs.saturating_add(macs);
        } else {
            e.scalar_calls = e.scalar_calls.saturating_add(calls);
            e.scalar_macs = e.scalar_macs.saturating_add(macs);
        }
        e.gemv_hits = e.gemv_hits.saturating_add(gemv_hits);
        e.theta_fallbacks =
            e.theta_fallbacks.saturating_add(theta_fallbacks);
        e.scratch_high_water_bytes =
            e.scratch_high_water_bytes.max(scratch_high_water_bytes);
    }

    pub fn op_counts(&self) -> OpCounts {
        let get = |i: usize| self.requests.get(i).map_or(0, Counter::get);
        OpCounts {
            register: get(0),
            train: get(1),
            predict: get(2),
            evaluate: get(3),
            drift: get(4),
            get_stats: get(5),
        }
    }

    /// Snapshot every stage.  All lifecycle stage keys are always
    /// present (with zero counts when unused), so schema validation can
    /// assert coverage instead of guessing.
    pub fn snapshot(&self) -> StatsSnapshot {
        let mut stages =
            vec![("decode".to_string(), self.decode.snapshot())];
        for (name, h) in LANE_NAMES.iter().zip(self.queue_wait.iter()) {
            stages.push((format!("queue_wait/{name}"), h.snapshot()));
        }
        for (name, h) in EXEC_NAMES.iter().zip(self.exec.iter()) {
            stages.push((format!("exec/{name}"), h.snapshot()));
        }
        stages.push(("persist".to_string(), self.persist.snapshot()));
        stages.push(("encode".to_string(), self.encode.snapshot()));
        StatsSnapshot {
            schema: SNAPSHOT_SCHEMA,
            requests: self.op_counts(),
            responses: self.responses.get(),
            errors: self.errors.get(),
            queue_high_water: self.queue_high_water.get(),
            stages,
            engine: *self.engine.lock().expect("obs engine stats"),
            devices: Vec::new(),
        }
    }
}

/// One coherent, versioned reading of a server's telemetry: plain data,
/// mergeable, serialized to/from the stable JSON schema
/// (`SNAPSHOT_SCHEMA`) that `--stats-json`, `GetStats`, and
/// `priot bench --suite serve` all share.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub schema: u32,
    pub requests: OpCounts,
    pub responses: u64,
    pub errors: u64,
    /// Most accepted-but-unanswered requests ever outstanding at once.
    pub queue_high_water: u64,
    /// Lifecycle stage histograms, in pipeline order: `decode`,
    /// `queue_wait/<lane>`, `exec/<op>`, `persist`, `encode`.
    pub stages: Vec<(String, HistSnapshot)>,
    pub engine: EngineStats,
    pub devices: Vec<DeviceStats>,
}

impl StatsSnapshot {
    pub fn stage(&self, name: &str) -> Option<&HistSnapshot> {
        self.stages.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Fold `other` in: counters add, stage histograms merge by name
    /// (union of keys), device rows merge by device id.
    pub fn merge(&mut self, other: &Self) {
        self.requests.merge(&other.requests);
        self.responses = self.responses.saturating_add(other.responses);
        self.errors = self.errors.saturating_add(other.errors);
        self.queue_high_water =
            self.queue_high_water.max(other.queue_high_water);
        for (name, h) in &other.stages {
            if let Some(mine) =
                self.stages.iter_mut().find(|(n, _)| n == name)
            {
                mine.1.merge(h);
            } else {
                self.stages.push((name.clone(), h.clone()));
            }
        }
        self.engine.merge(&other.engine);
        for d in &other.devices {
            if let Some(mine) =
                self.devices.iter_mut().find(|m| m.device == d.device)
            {
                mine.ops_done = mine.ops_done.saturating_add(d.ops_done);
                mine.queue_wait_us =
                    mine.queue_wait_us.saturating_add(d.queue_wait_us);
                mine.execute_us =
                    mine.execute_us.saturating_add(d.execute_us);
            } else {
                self.devices.push(d.clone());
            }
        }
    }

    /// Serialize to the versioned snapshot JSON schema (all values are
    /// integers; histogram buckets are sparse `[index, count]` pairs).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{{\n  \"schema\": {},\n", self.schema));
        let r = &self.requests;
        s.push_str(&format!(
            "  \"requests\": {{\"register\": {}, \"train\": {}, \
             \"predict\": {}, \"evaluate\": {}, \"drift\": {}, \
             \"get_stats\": {}}},\n",
            r.register, r.train, r.predict, r.evaluate, r.drift, r.get_stats
        ));
        s.push_str(&format!("  \"responses\": {},\n", self.responses));
        s.push_str(&format!("  \"errors\": {},\n", self.errors));
        s.push_str(&format!("  \"queue_high_water\": {},\n",
                            self.queue_high_water));
        s.push_str("  \"stages\": {\n");
        for (i, (name, h)) in self.stages.iter().enumerate() {
            let buckets: Vec<String> = h
                .buckets
                .iter()
                .map(|&(b, n)| format!("[{b}, {n}]"))
                .collect();
            s.push_str(&format!(
                "    \"{name}\": {{\"count\": {}, \"sum\": {}, \
                 \"max\": {}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \
                 \"buckets\": [{}]}}{}\n",
                h.count,
                h.sum,
                h.max,
                h.p50(),
                h.p90(),
                h.p99(),
                buckets.join(", "),
                if i.saturating_add(1) < self.stages.len() { "," } else { "" }
            ));
        }
        s.push_str("  },\n");
        let e = &self.engine;
        s.push_str(&format!(
            "  \"engine\": {{\"scalar_calls\": {}, \"scalar_macs\": {}, \
             \"tiled_calls\": {}, \"tiled_macs\": {}, \"gemv_hits\": {}, \
             \"theta_fallbacks\": {}, \"scratch_high_water_bytes\": {}}},\n",
            e.scalar_calls, e.scalar_macs, e.tiled_calls, e.tiled_macs,
            e.gemv_hits, e.theta_fallbacks, e.scratch_high_water_bytes
        ));
        s.push_str("  \"devices\": [\n");
        for (i, d) in self.devices.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"device\": {}, \"ops_done\": {}, \
                 \"queue_wait_us\": {}, \"execute_us\": {}}}{}\n",
                crate::report::bench::json_str(&d.device),
                d.ops_done,
                d.queue_wait_us,
                d.execute_us,
                if i.saturating_add(1) < self.devices.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse a snapshot back from its JSON form (the bench serve suite
    /// and the cross-transport tests read `GetStats` bodies this way).
    /// Quantiles are recomputed from the buckets, never trusted from the
    /// document.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        use anyhow::Context;

        use crate::report::bench::{get, Json};

        let v = Json::parse(text)?;
        let obj = v.as_obj().context("snapshot root is not an object")?;
        let u = |v: &Json, what: &str| -> anyhow::Result<u64> {
            Ok(v.as_f64()
                .with_context(|| format!("snapshot: {what} is not a number"))?
                as u64)
        };
        let schema = u(get(obj, "schema")?, "schema")? as u32;
        if schema != SNAPSHOT_SCHEMA {
            anyhow::bail!(
                "snapshot schema {schema} unsupported (want {SNAPSHOT_SCHEMA})"
            );
        }
        let rq = get(obj, "requests")?
            .as_obj()
            .context("requests is not an object")?;
        let requests = OpCounts {
            register: u(get(rq, "register")?, "register")?,
            train: u(get(rq, "train")?, "train")?,
            predict: u(get(rq, "predict")?, "predict")?,
            evaluate: u(get(rq, "evaluate")?, "evaluate")?,
            drift: u(get(rq, "drift")?, "drift")?,
            get_stats: u(get(rq, "get_stats")?, "get_stats")?,
        };
        let mut stages = Vec::new();
        for (name, sv) in get(obj, "stages")?
            .as_obj()
            .context("stages is not an object")?
        {
            let so = sv
                .as_obj()
                .with_context(|| format!("stage {name} is not an object"))?;
            let mut buckets = Vec::new();
            for pair in get(so, "buckets")?
                .as_arr()
                .with_context(|| format!("stage {name}: bad buckets"))?
            {
                let pair = pair
                    .as_arr()
                    .with_context(|| format!("stage {name}: bad bucket"))?;
                if pair.len() != 2 {
                    anyhow::bail!("stage {name}: malformed bucket pair");
                }
                buckets.push((
                    u(&pair[0], "bucket index")? as usize,
                    u(&pair[1], "bucket count")?,
                ));
            }
            stages.push((name.clone(), HistSnapshot {
                count: u(get(so, "count")?, "count")?,
                sum: u(get(so, "sum")?, "sum")?,
                max: u(get(so, "max")?, "max")?,
                buckets,
            }));
        }
        let eo = get(obj, "engine")?
            .as_obj()
            .context("engine is not an object")?;
        let engine = EngineStats {
            scalar_calls: u(get(eo, "scalar_calls")?, "scalar_calls")?,
            scalar_macs: u(get(eo, "scalar_macs")?, "scalar_macs")?,
            tiled_calls: u(get(eo, "tiled_calls")?, "tiled_calls")?,
            tiled_macs: u(get(eo, "tiled_macs")?, "tiled_macs")?,
            gemv_hits: u(get(eo, "gemv_hits")?, "gemv_hits")?,
            theta_fallbacks: u(get(eo, "theta_fallbacks")?,
                               "theta_fallbacks")?,
            scratch_high_water_bytes: u(
                get(eo, "scratch_high_water_bytes")?,
                "scratch_high_water_bytes",
            )?,
        };
        let mut devices = Vec::new();
        for dv in get(obj, "devices")?
            .as_arr()
            .context("devices is not an array")?
        {
            let d = dv.as_obj().context("device entry is not an object")?;
            devices.push(DeviceStats {
                device: get(d, "device")?
                    .as_str()
                    .context("device name")?
                    .to_string(),
                ops_done: u(get(d, "ops_done")?, "ops_done")?,
                queue_wait_us: u(get(d, "queue_wait_us")?, "queue_wait_us")?,
                execute_us: u(get(d, "execute_us")?, "execute_us")?,
            });
        }
        Ok(Self {
            schema,
            requests,
            responses: u(get(obj, "responses")?, "responses")?,
            errors: u(get(obj, "errors")?, "errors")?,
            queue_high_water: u(get(obj, "queue_high_water")?,
                                "queue_high_water")?,
            stages,
            engine,
            devices,
        })
    }

    /// Multi-line human rendering (the `--stats-interval` dump format):
    /// integer microseconds throughout.
    pub fn render(&self) -> String {
        let r = &self.requests;
        let mut s = format!(
            "[stats] requests {} (register {}, train {}, predict {}, \
             evaluate {}, drift {}, get_stats {}) responses {} errors {} \
             queue-high-water {}\n",
            r.total(), r.register, r.train, r.predict, r.evaluate, r.drift,
            r.get_stats, self.responses, self.errors, self.queue_high_water
        );
        for (name, h) in &self.stages {
            if h.count == 0 {
                continue;
            }
            s.push_str(&format!(
                "[stats]   {name}: n={} mean={}us p50={}us p90={}us \
                 p99={}us max={}us\n",
                h.count, h.mean(), h.p50(), h.p90(), h.p99(), h.max
            ));
        }
        let e = &self.engine;
        s.push_str(&format!(
            "[stats]   engine: {} macs (scalar {} calls / {} macs, tiled \
             {} calls / {} macs), gemv hits {}, theta fallbacks {}, \
             scratch high-water {} bytes\n",
            e.macs(), e.scalar_calls, e.scalar_macs, e.tiled_calls,
            e.tiled_macs, e.gemv_hits, e.theta_fallbacks,
            e.scratch_high_water_bytes
        ));
        for d in &self.devices {
            s.push_str(&format!(
                "[stats]   device {}: ops {} queue-wait {}us execute {}us\n",
                d.device, d.ops_done, d.queue_wait_us, d.execute_us
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_round_trip() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 63);
        for i in 0..HIST_BUCKETS {
            assert_eq!(bucket_index(bucket_upper_bound(i)), i,
                       "upper bound of bucket {i} must land in bucket {i}");
        }
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 5, 9, 100] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 116);
        assert_eq!(s.max, 100);
        assert!(s.p50() <= s.p90());
        assert!(s.p90() <= s.p99());
        assert!(s.p99() <= s.max);
        assert_eq!(s.quantile(1, 1), 100, "p100 is the observed max");
    }

    #[test]
    fn counter_shards_fold() {
        let c = Counter::default();
        c.add(3);
        c.inc();
        assert_eq!(c.get(), 4);
        let g = Gauge::default();
        g.record(7);
        g.record(2);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn serve_obs_snapshot_has_every_stage() {
        let obs = ServeObs::default();
        let snap = obs.snapshot();
        for want in [
            "decode", "queue_wait/interactive", "queue_wait/batch",
            "queue_wait/background", "exec/register", "exec/train_epoch",
            "exec/predict", "exec/evaluate", "exec/drift", "persist",
            "encode",
        ] {
            assert!(snap.stage(want).is_some(), "missing stage {want}");
        }
        assert_eq!(snap.schema, SNAPSHOT_SCHEMA);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let obs = ServeObs::default();
        obs.note_request(Op::Train);
        obs.note_request(Op::Train);
        obs.note_request(Op::Predict);
        obs.note_response(false);
        obs.queue_high_water.record(2);
        obs.record_exec(Op::Train, 1234);
        obs.record_queue_wait(1, 88);
        obs.merge_engine(true, 10, 5000, 2, 1, 4096);
        let mut snap = obs.snapshot();
        snap.devices.push(DeviceStats {
            device: "dev-a".into(),
            ops_done: 3,
            queue_wait_us: 88,
            execute_us: 1234,
        });
        let back = StatsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap, "JSON round-trip must be lossless");
    }

    #[test]
    fn snapshot_merge_accumulates() {
        let a_obs = ServeObs::default();
        a_obs.note_request(Op::Train);
        a_obs.record_exec(Op::Train, 10);
        let b_obs = ServeObs::default();
        b_obs.note_request(Op::Train);
        b_obs.note_request(Op::Evaluate);
        b_obs.record_exec(Op::Train, 1000);
        let mut a = a_obs.snapshot();
        let b = b_obs.snapshot();
        a.merge(&b);
        assert_eq!(a.requests.train, 2);
        assert_eq!(a.requests.evaluate, 1);
        let t = a.stage("exec/train_epoch").unwrap();
        assert_eq!(t.count, 2);
        assert_eq!(t.sum, 1010);
        assert_eq!(t.max, 1000);
    }
}
