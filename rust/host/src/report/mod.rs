//! Table/figure formatters: render run metrics in the same rows/series the
//! paper reports (Table I, Table II, Fig. 2, Fig. 3).

use crate::metrics::{MeanStd, RunMetrics};
use crate::pico::{MemoryFootprint, StepCost};

/// A Table I row: method name → per-column accuracy statistic.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: String,
    pub cells: Vec<Option<MeanStd>>,
}

/// Render Table I as Markdown, matching the paper's layout:
/// columns = (dataset, angle) pairs.
pub fn table1_markdown(columns: &[String], rows: &[Table1Row]) -> String {
    let mut out = String::new();
    out.push_str("| Method | ");
    out.push_str(&columns.join(" | "));
    out.push_str(" |\n|---|");
    for _ in columns {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str(&format!("| {} |", row.method));
        for cell in &row.cells {
            match cell {
                Some(ms) => out.push_str(&format!(" {} |", ms.fmt_pct())),
                None => out.push_str(" — |"),
            }
        }
        out.push('\n');
    }
    out
}

/// A Table II row.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub method: String,
    /// Measured wall-clock per image on this host (ms).
    pub host_ms: MeanStd,
    /// Modeled Cortex-M0+ time per image (ms).
    pub pico: StepCost,
    pub memory: MemoryFootprint,
}

pub fn table2_markdown(rows: &[Table2Row]) -> String {
    let mut out = String::from(
        "| Method | Host time [ms] | Pico-model time [ms] | \
         Est. memory footprint [B] |\n|---|---|---|---|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.2} | {} |\n",
            r.method,
            r.host_ms.fmt_ms(),
            r.pico.total_ms(),
            r.memory.total()
        ));
    }
    out
}

/// Fig. 3 series: accuracy-vs-epoch per method, CSV with one column per
/// method.
pub fn fig3_csv(methods: &[String], runs: &[&RunMetrics]) -> String {
    let mut out = String::from("epoch");
    for m in methods {
        out.push(',');
        out.push_str(m);
    }
    out.push('\n');
    let max_len = runs.iter().map(|r| r.accuracy.len()).max().unwrap_or(0);
    for e in 0..max_len {
        out.push_str(&format!("{e}"));
        for r in runs {
            match r.accuracy.get(e) {
                Some(a) => out.push_str(&format!(",{:.4}", a * 100.0)),
                None => out.push(','),
            }
        }
        out.push('\n');
    }
    out
}

/// Fig. 2 series: per-step overflow counts during the collapse window.
pub fn fig2_csv(step_overflows: &[(u64, u32)]) -> String {
    let mut out = String::from("step,overflowed_outputs\n");
    for (step, ovf) in step_overflows {
        out.push_str(&format!("{step},{ovf}\n"));
    }
    out
}

/// Render an accuracy history as a terminal sparkline (quick visual check
/// of the Fig. 3 shapes without plotting).
pub fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (lo, hi) = values
        .iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &v| (l.min(v), h.max(v)));
    let span = (hi - lo).max(1e-9);
    values
        .iter()
        .map(|&v| BARS[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

impl MeanStd {
    /// `62.02 (±0.06)`-style milliseconds cell.
    pub fn fmt_ms(&self) -> String {
        if self.n <= 1 {
            format!("{:.2}", self.mean)
        } else {
            format!("{:.2} (±{:.2})", self.mean, self.std)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders() {
        let rows = vec![Table1Row {
            method: "PRIOT".into(),
            cells: vec![
                Some(MeanStd { mean: 0.8894, std: 0.0102, n: 10 }),
                None,
            ],
        }];
        let md = table1_markdown(&["Digits 30°".into(), "Digits 45°".into()], &rows);
        assert!(md.contains("| PRIOT | 88.94 (±1.02) | — |"));
    }

    #[test]
    fn fig3_csv_is_ragged_safe() {
        let r1 = RunMetrics { accuracy: vec![0.5, 0.6], ..Default::default() };
        let r2 = RunMetrics { accuracy: vec![0.5], ..Default::default() };
        let csv = fig3_csv(&["a".into(), "b".into()], &[&r1, &r2]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "epoch,a,b");
        assert_eq!(lines[2], "1,60.0000,");
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
    }
}

pub mod bench;
pub mod experiments;
