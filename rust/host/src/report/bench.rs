//! Bencher-style perf snapshots: run the kernel / serve measurement
//! suites in-process, serialize the results as `BENCH_*.json`, and diff a
//! run against a checked-in baseline — the repo's recorded perf
//! trajectory (`priot bench`), so optimization PRs land with before/after
//! numbers instead of anecdotes.
//!
//! The kernel suite mirrors the shapes of `benches/kernel.rs` (tinycnn
//! conv/fc GEMMs, the vgg-ish mid layer, im2col); the serve suite times a
//! small in-process fleet round (register → train → evaluate over the
//! local transport).  Numbers are wall-clock and machine-dependent:
//! snapshots record provenance plus the measuring machine
//! ([`machine_context`] — OS, arch, cpu count, cpu model), so a diff
//! against a baseline from different hardware is never mistaken for a
//! regression.  A baseline whose `micros` are 0 is an unmeasured
//! placeholder seed that diffs report as "no baseline"; running
//! `priot bench --update .` on any machine with a toolchain replaces it
//! with measured numbers stamped with that machine's context.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::prng::XorShift64;
use crate::proto::MethodSpec;
use crate::session::FleetServer;
use crate::tensor::{im2col, Kernels, Mat};

/// Snapshot schema version (bump on field changes).
pub const SCHEMA: u32 = 1;

/// Provenance string for snapshots produced by a real measurement run.
pub const PROVENANCE_MEASURED: &str = "measured";
/// Provenance of a checked-in placeholder with no real numbers yet.
pub const PROVENANCE_SEED: &str = "unmeasured-seed";

/// One measured entry: label + µs per iteration (+ Gmac/s where the work
/// has a MAC count; 0.0 otherwise).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchEntry {
    pub label: String,
    pub micros: f64,
    pub gmacs: f64,
}

/// One suite's results (what a `BENCH_<suite>.json` file holds).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchResults {
    pub schema: u32,
    pub suite: String,
    pub provenance: String,
    /// The measuring machine ([`machine_context`]); empty for snapshots
    /// written before the field existed and for unmeasured seeds.
    pub machine: String,
    pub entries: Vec<BenchEntry>,
}

/// Best-effort description of the machine a measurement ran on — OS,
/// architecture, logical cpu count, and cpu model where readable.
/// Recorded in every measured snapshot so cross-machine diffs are
/// recognizable as such.
pub fn machine_context() -> String {
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(0);
    let model = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|m| m.trim().to_string())
        })
        .unwrap_or_else(|| "unknown-cpu".to_string());
    format!(
        "{}-{}, {cpus} cpus, {model}",
        std::env::consts::OS,
        std::env::consts::ARCH
    )
}

fn rand_mat(rng: &mut XorShift64, r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
}

/// Time `f` over `iters` iterations (plus warmup) and return (µs, Gmac/s).
fn time_it(work_macs: u64, iters: u32, mut f: impl FnMut()) -> (f64, f64) {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t0.elapsed().as_secs_f64();
    let per_iter = total / iters as f64;
    let micros = per_iter * 1e6;
    let gmacs = if work_macs > 0 && per_iter > 0.0 {
        work_macs as f64 / per_iter / 1e9
    } else {
        0.0
    };
    (micros, gmacs)
}

fn kernels_for(variant: &str) -> Kernels {
    if variant == "tiled" {
        Kernels::tiled()
    } else {
        Kernels::scalar()
    }
}

/// The kernel suite: the scalar and tiled GEMM variants over the tinycnn /
/// vgg-ish shapes tracked by `benches/kernel.rs`, plus im2col.  `filter`
/// keeps only entries whose label contains it (empty = run everything) —
/// the `priot bench --filter` hook; each variant carries its name in the
/// label, so `--filter tiled` or `--filter gemm_tn` select slices.
pub fn run_kernel(iters: u32, filter: &str) -> BenchResults {
    let mut rng = XorShift64::new(77);
    let mut entries = Vec::new();
    let wanted = |label: &str| filter.is_empty() || label.contains(filter);

    // (label stem, m, k, n) — gemm_nn shapes, both kernel variants.
    let nn_shapes: &[(&str, usize, usize, usize)] = &[
        ("conv1 8x9x784", 8, 9, 784),
        ("conv2 16x72x196", 16, 72, 196),
        ("vgg-mid 64x288x64", 64, 288, 64),
    ];
    for &(stem, m, k, n) in nn_shapes {
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let mut out = Mat::zeros(m, n);
        let macs = (m * k * n) as u64;
        for variant in ["scalar", "tiled"] {
            let label = format!("gemm_nn {variant} {stem}");
            if !wanted(&label) {
                continue;
            }
            let mut kr = kernels_for(variant);
            let (micros, gmacs) =
                time_it(macs, iters, || kr.gemm_nn(&a, &b, &mut out));
            entries.push(BenchEntry { label, micros, gmacs });
        }
    }

    // The n == 1 GEMV fast path is shared by both kernel kinds (tiled
    // dispatch falls back to the scalar row·vector loop for single-column
    // rhs), so it gets one entry, not a scalar/tiled pair.
    {
        let (m, k, n) = (64usize, 784usize, 1usize);
        let label = "gemm_nn gemv fc1 64x784x1".to_string();
        if wanted(&label) {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let mut out = Mat::zeros(m, n);
            let mut kr = Kernels::tiled();
            let macs = (m * k * n) as u64;
            let (micros, gmacs) =
                time_it(macs, iters, || kr.gemm_nn(&a, &b, &mut out));
            entries.push(BenchEntry { label, micros, gmacs });
        }
    }

    // Backward kernels at the conv2 shape.
    {
        let (m, k, n) = (16usize, 72usize, 196usize);
        let macs = (m * k * n) as u64;
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, m, n);
        let mut out = Mat::zeros(k, n);
        for variant in ["scalar", "tiled"] {
            let label = format!("gemm_tn {variant} conv2 16x72x196");
            if !wanted(&label) {
                continue;
            }
            let mut kr = kernels_for(variant);
            let (micros, gmacs) =
                time_it(macs, iters, || kr.gemm_tn(&a, &b, &mut out));
            entries.push(BenchEntry { label, micros, gmacs });
        }
        let a2 = rand_mat(&mut rng, m, n);
        let b2 = rand_mat(&mut rng, k, n);
        let mut out2 = Mat::zeros(m, k);
        for variant in ["scalar", "tiled"] {
            let label = format!("gemm_nt {variant} conv2 16x72x196");
            if !wanted(&label) {
                continue;
            }
            let mut kr = kernels_for(variant);
            let (micros, gmacs) =
                time_it(macs, iters, || kr.gemm_nt(&a2, &b2, &mut out2));
            entries.push(BenchEntry { label, micros, gmacs });
        }
    }

    // im2col at the conv2 input geometry (8 channels, 14x14).
    {
        let label = "im2col 8x14x14".to_string();
        if wanted(&label) {
            let (c, h, w) = (8usize, 14usize, 14usize);
            let x: Vec<i32> =
                (0..c * h * w).map(|_| rng.int_in(-127, 127)).collect();
            let mut cols = Mat::zeros(c * 9, h * w);
            let (micros, _) = time_it(0, iters, || im2col(&x, c, h, w, &mut cols));
            entries.push(BenchEntry { label, micros, gmacs: 0.0 });
        }
    }

    BenchResults {
        schema: SCHEMA,
        suite: "kernel".to_string(),
        provenance: PROVENANCE_MEASURED.to_string(),
        machine: machine_context(),
        entries,
    }
}

/// The server's counted MACs right now, read through the same
/// `GetStats` wire surface any client uses.  Zero when the `obs`
/// feature is compiled out — phase `gmacs` then report 0.0, exactly the
/// pre-counter behavior.
fn served_macs(client: &mut crate::proto::FleetClient) -> Result<u64> {
    match client.get_stats()? {
        crate::proto::Response::Stats { json } => {
            Ok(crate::obs::StatsSnapshot::from_json(&json)?.engine.macs())
        }
        other => bail!("expected a stats response, got {other:?}"),
    }
}

/// Gmac/s from a phase's counted MACs and its wall time.
fn phase_gmacs(macs: u64, micros: f64) -> f64 {
    if micros <= 0.0 {
        0.0
    } else {
        macs as f64 / (micros * 1e-6) / 1e9
    }
}

/// The serve suite: one small in-process fleet round — register 3 devices
/// (one per method family), train each for an epoch, evaluate — over the
/// local channel transport.  Per-phase `gmacs` come from the engine's
/// *counted* MACs (drained over `GetStats` after each phase), so the
/// throughput numbers are exact, not estimated from nominal shapes.
pub fn run_serve() -> Result<BenchResults> {
    use std::sync::Arc;
    let backbone = crate::ptest::gen::synthetic_backbone(1);
    let train = Arc::new(crate::ptest::gen::synthetic_dataset(11, 64));
    let test = Arc::new(crate::ptest::gen::synthetic_dataset(12, 32));
    let specs = [
        ("bench-niti", MethodSpec::niti_static()),
        ("bench-priot", MethodSpec::priot()),
        ("bench-priot-s", MethodSpec::priot_s(0.1, crate::config::Selection::Random)),
    ];
    let t0 = Instant::now();
    let server = FleetServer::builder(backbone).limit(64).record(false).build();
    let mut client = server.local_client();
    for (dev, spec) in &specs {
        client.register(dev, 7, spec.clone(), Arc::clone(&train), Arc::clone(&test))?;
    }
    let reg_us = t0.elapsed().as_secs_f64() * 1e6;
    let reg_macs = served_macs(&mut client)?;
    let t1 = Instant::now();
    for (dev, _) in &specs {
        client.train(dev, 1)?;
    }
    let train_us = t1.elapsed().as_secs_f64() * 1e6;
    let train_macs = served_macs(&mut client)?.saturating_sub(reg_macs);
    let t2 = Instant::now();
    for (dev, _) in &specs {
        client.evaluate(dev)?;
    }
    let eval_us = t2.elapsed().as_secs_f64() * 1e6;
    let eval_macs = served_macs(&mut client)?
        .saturating_sub(reg_macs)
        .saturating_sub(train_macs);
    drop(client);
    server.join()?;
    Ok(BenchResults {
        schema: SCHEMA,
        suite: "serve".to_string(),
        provenance: PROVENANCE_MEASURED.to_string(),
        machine: machine_context(),
        entries: vec![
            BenchEntry {
                label: "serve register 3 devices".to_string(),
                micros: reg_us,
                gmacs: phase_gmacs(reg_macs, reg_us),
            },
            BenchEntry {
                label: "serve train 3x1 epoch (64 samples)".to_string(),
                micros: train_us,
                gmacs: phase_gmacs(train_macs, train_us),
            },
            BenchEntry {
                label: "serve evaluate 3 devices (32 samples)".to_string(),
                micros: eval_us,
                gmacs: phase_gmacs(eval_macs, eval_us),
            },
        ],
    })
}

impl BenchResults {
    /// Serialize to the `BENCH_*.json` snapshot format.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"schema\": {},\n", self.schema));
        s.push_str(&format!("  \"suite\": {},\n", json_str(&self.suite)));
        s.push_str(&format!("  \"provenance\": {},\n", json_str(&self.provenance)));
        s.push_str(&format!("  \"machine\": {},\n", json_str(&self.machine)));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"label\": {}, \"micros\": {:.3}, \"gmacs\": {:.3}}}{}\n",
                json_str(&e.label),
                e.micros,
                e.gmacs,
                if i + 1 == self.entries.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse a snapshot previously written by [`Self::to_json`] (tolerant
    /// of field order; strict about types).
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().context("snapshot root is not an object")?;
        let schema = get(obj, "schema")?.as_f64().context("schema")? as u32;
        if schema != SCHEMA {
            bail!("snapshot schema {schema} != supported {SCHEMA}");
        }
        let suite = get(obj, "suite")?.as_str().context("suite")?.to_string();
        let provenance =
            get(obj, "provenance")?.as_str().context("provenance")?.to_string();
        // Optional: snapshots written before the field existed parse as
        // machine-less (same schema — readers treat empty as unknown).
        let machine = obj
            .iter()
            .find(|(k, _)| k == "machine")
            .and_then(|(_, v)| v.as_str())
            .unwrap_or("")
            .to_string();
        let mut entries = Vec::new();
        for e in get(obj, "entries")?.as_arr().context("entries")? {
            let eo = e.as_obj().context("entry is not an object")?;
            entries.push(BenchEntry {
                label: get(eo, "label")?.as_str().context("label")?.to_string(),
                micros: get(eo, "micros")?.as_f64().context("micros")?,
                gmacs: get(eo, "gmacs")?.as_f64().context("gmacs")?,
            });
        }
        Ok(BenchResults { schema, suite, provenance, machine, entries })
    }

    /// Human-readable results table.
    pub fn render(&self) -> String {
        let mut s = format!("## bench suite: {} ({})\n", self.suite, self.provenance);
        if !self.machine.is_empty() {
            s.push_str(&format!("   machine: {}\n", self.machine));
        }
        s.push('\n');
        for e in &self.entries {
            if e.gmacs > 0.0 {
                s.push_str(&format!(
                    "  {:<28} {:>12.2} us/iter  {:>8.3} Gmac/s\n",
                    e.label, e.micros, e.gmacs
                ));
            } else {
                s.push_str(&format!("  {:<28} {:>12.2} us/iter\n", e.label, e.micros));
            }
        }
        s
    }

    /// Diff this run against a baseline snapshot (matched by label).
    pub fn diff(&self, base: &BenchResults) -> String {
        let mut s = format!("## bench diff vs baseline ({})\n", base.provenance);
        if !base.machine.is_empty() && base.machine != self.machine {
            s.push_str(&format!(
                "   baseline is from a different machine ({}) — deltas are \
                 not regressions\n",
                base.machine
            ));
        }
        s.push('\n');
        for e in &self.entries {
            match base.entries.iter().find(|b| b.label == e.label) {
                None => s.push_str(&format!("  {:<28} (no baseline entry)\n", e.label)),
                Some(b) if b.micros <= 0.0 => s.push_str(&format!(
                    "  {:<28} {:>12.2} us  (no baseline — unmeasured seed)\n",
                    e.label, e.micros
                )),
                Some(b) => {
                    let pct = (e.micros - b.micros) / b.micros * 100.0;
                    s.push_str(&format!(
                        "  {:<28} {:>12.2} us  vs {:>12.2} us  ({:+.1}%)\n",
                        e.label, e.micros, b.micros, pct
                    ));
                }
            }
        }
        for b in &base.entries {
            if !self.entries.iter().any(|e| e.label == b.label) {
                s.push_str(&format!("  {:<28} (baseline entry not re-run)\n", b.label));
            }
        }
        s
    }
}

pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Minimal JSON value for the snapshot codec — supports exactly what the
/// snapshot formats use (objects, arrays, strings, numbers, bools, null).
/// Shared crate-internally with `obs::StatsSnapshot::from_json`.
#[derive(Clone, Debug)]
pub(crate) enum Json {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

pub(crate) fn get<'a>(obj: &'a [(String, Json)], key: &str) -> Result<&'a Json> {
    obj.iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v)
        .with_context(|| format!("snapshot is missing key {key:?}"))
}

impl Json {
    pub(crate) fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub(crate) fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub(crate) fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub(crate) fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub(crate) fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes after JSON value at offset {}", p.i);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of JSON"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.push((key, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            let v = self.value()?;
            out.push(v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .ok()
                                    .and_then(|h| u32::from_str_radix(h, 16).ok())
                                    .context("bad \\u escape")?;
                            out.push(
                                char::from_u32(hex).context("bad \\u code point")?,
                            );
                            self.i += 4;
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .context("snapshot is not valid UTF-8")?;
                    let c = rest.chars().next().expect("non-empty by peek");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .context("non-UTF-8 number")?;
        let n: f64 = s.parse().with_context(|| format!("bad number {s:?}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchResults {
        BenchResults {
            schema: SCHEMA,
            suite: "kernel".to_string(),
            provenance: PROVENANCE_MEASURED.to_string(),
            machine: "test-os-arch, 4 cpus, Test CPU".to_string(),
            entries: vec![
                BenchEntry {
                    label: "gemm_nn conv1 8x9x784".to_string(),
                    micros: 12.5,
                    gmacs: 4.5,
                },
                BenchEntry { label: "im2col 8x14x14".to_string(), micros: 3.25, gmacs: 0.0 },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let r = sample();
        let parsed = BenchResults::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn diff_handles_seed_and_missing_entries() {
        let cur = sample();
        let mut base = sample();
        base.provenance = PROVENANCE_SEED.to_string();
        base.entries[0].micros = 0.0; // unmeasured placeholder
        base.entries[1].label = "something else".to_string();
        let d = cur.diff(&base);
        assert!(d.contains("unmeasured seed"), "{d}");
        assert!(d.contains("no baseline entry"), "{d}");
        assert!(d.contains("not re-run"), "{d}");
    }

    #[test]
    fn diff_reports_percentages() {
        let cur = sample();
        let mut base = sample();
        base.entries[0].micros = 25.0; // cur 12.5 → -50%
        let d = cur.diff(&base);
        assert!(d.contains("-50.0%"), "{d}");
    }

    #[test]
    fn machine_field_is_optional_when_parsing() {
        // Snapshots written before the machine field existed (including
        // the checked-in unmeasured seeds) still parse; the machine
        // reads back empty.
        let mut r = sample();
        let without = r
            .to_json()
            .lines()
            .filter(|l| !l.trim_start().starts_with("\"machine\""))
            .collect::<Vec<_>>()
            .join("\n");
        let parsed = BenchResults::from_json(&without).unwrap();
        assert_eq!(parsed.machine, "");
        r.machine = String::new();
        assert_eq!(parsed, r);
    }

    #[test]
    fn cross_machine_diffs_are_flagged() {
        let cur = sample();
        let mut base = sample();
        base.machine = "other-os-arch, 128 cpus, Other CPU".to_string();
        let d = cur.diff(&base);
        assert!(d.contains("different machine"), "{d}");
        assert!(!cur.diff(&sample()).contains("different machine"));
    }

    #[test]
    fn measurement_runs_record_the_machine() {
        let r = run_kernel(1, "im2col");
        assert_eq!(r.machine, machine_context());
        assert!(!r.machine.is_empty());
    }

    #[test]
    fn parser_rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "{\"schema\": 1} trailing"] {
            assert!(BenchResults::from_json(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn kernel_suite_runs_with_tiny_iters() {
        let r = run_kernel(2, "");
        assert_eq!(r.suite, "kernel");
        assert_eq!(r.entries.len(), 12);
        assert!(r.entries.iter().all(|e| e.micros >= 0.0));
        // Every tiled entry has a scalar twin at the same shape; the GEMV
        // fast path (shared by both kinds) and im2col stand alone.
        for e in &r.entries {
            if let Some(stem) = e.label.strip_prefix("gemm_nn tiled ") {
                let twin = format!("gemm_nn scalar {stem}");
                assert!(r.entries.iter().any(|o| o.label == twin), "{twin}");
            }
        }
        assert_eq!(
            r.entries.iter()
                .filter(|e| e.label.contains("gemv"))
                .count(),
            1
        );
    }

    #[test]
    fn kernel_suite_filter_narrows_entries() {
        let r = run_kernel(1, "gemm_tn");
        assert_eq!(r.entries.len(), 2, "{:?}", r.entries);
        assert!(r.entries.iter().all(|e| e.label.contains("gemm_tn")));
        assert!(run_kernel(1, "no-such-kernel").entries.is_empty());
    }
}
