//! Experiment harness: the paper's tables and figures as library functions,
//! shared by the `priot` CLI and the `cargo bench` targets.
//!
//! Every function takes explicit size knobs so the benches can run a
//! CI-scale pass (`quick`) or the paper-scale protocol (`--full`).

use std::path::Path;

use anyhow::{bail, Result};

use crate::config::{Config, ExperimentConfig, Method, Selection};
use crate::coordinator::{sweep_seeds, RunOptions};
use crate::data;
use crate::metrics::{MeanStd, RunMetrics};
use crate::obs::Stopwatch;
use crate::pico;
use crate::report::{fig2_csv, fig3_csv, table2_markdown, Table2Row};
use crate::session::{Session, SessionBuilder};

/// Table I row carrying (best, final) statistics per column.
pub struct Table1RowBF {
    pub method: String,
    pub cells: Vec<Option<(MeanStd, MeanStd)>>,
}

/// Table I markdown with the paper's "best during training" statistic plus
/// our additional final-accuracy column (the static-NITI transient makes
/// "best" alone misleading in this reproduction — EXPERIMENTS.md
/// SSDeviations).
pub fn table1_markdown_bf(columns: &[String], rows: &[Table1RowBF]) -> String {
    let mut out = String::from("| Method |");
    for c in columns {
        out.push_str(&format!(" {c} best | {c} final |"));
    }
    out.push_str("\n|---|");
    for _ in columns {
        out.push_str("---|---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str(&format!("| {} |", row.method));
        for cell in &row.cells {
            match cell {
                Some((b, f)) => {
                    out.push_str(&format!(" {} | {} |", b.fmt_pct(), f.fmt_pct()))
                }
                None => out.push_str(" — | — |"),
            }
        }
        out.push('\n');
    }
    out
}
use crate::spec::NetSpec;

/// Global experiment scale.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub epochs: usize,
    pub limit: usize, // sample cap per split (0 = all)
    pub seeds: usize, // repetitions for randomized methods
    pub include_vgg: bool,
}

impl Scale {
    /// Paper protocol: 30 epochs × 1024 images × 10 seeds.
    pub fn full() -> Self {
        Self { epochs: 30, limit: 0, seeds: 10, include_vgg: true }
    }

    /// CI scale for a single-core box.
    pub fn quick() -> Self {
        Self { epochs: 8, limit: 384, seeds: 3, include_vgg: false }
    }
}

fn base_cfg(artifacts: &Path, model: &str, dataset: &str, angle: u32,
            method: Method) -> ExperimentConfig {
    let mut c = Config::default();
    c.set("artifacts", artifacts.to_str().unwrap_or("artifacts"));
    c.set("model", model);
    c.set("dataset", dataset);
    c.set("angle", &angle.to_string());
    c.set("method", method.name());
    ExperimentConfig::from_config(&c).expect("base config")
}

/// One (column) of Table I: dataset/model/angle; computes every method row.
pub struct Table1Column {
    pub label: String,
    pub model: String,
    pub dataset: String,
    pub angle: u32,
}

/// The method rows of Table I in paper order.
/// (method, frac_scored, selection, randomized?)
pub const TABLE1_ROWS: &[(&str, f64, &str)] = &[
    ("before", 0.0, "-"),
    ("dynamic-niti", 0.0, "-"),
    ("static-niti", 0.0, "-"),
    ("priot", 1.0, "-"),
    ("priot-s-90-random", 0.1, "random"),
    ("priot-s-90-weight", 0.1, "weight"),
    ("priot-s-80-random", 0.2, "random"),
    ("priot-s-80-weight", 0.2, "weight"),
];

/// Compute one Table I cell.
pub fn table1_cell(artifacts: &Path, col: &Table1Column, row: &str,
                   frac: f64, selection: &str, scale: Scale)
                   -> Result<(MeanStd, MeanStd)> {
    let method = match row {
        "before" | "dynamic-niti" => {
            if row == "before" {
                // evaluate the backbone without training
                let mut cfg = base_cfg(artifacts, &col.model, &col.dataset,
                                       col.angle, Method::StaticNiti);
                cfg.limit = scale.limit;
                let pair = data::load_pair(&cfg)?;
                let mut session = Session::from_experiment(&cfg)?;
                let acc = session.evaluate(&pair.test)?;
                let ms = MeanStd { mean: acc, std: 0.0, n: 1 };
                return Ok((ms, ms));
            }
            Method::DynamicNiti
        }
        "static-niti" => Method::StaticNiti,
        "priot" => Method::Priot,
        _ => Method::PriotS,
    };
    let mut cfg = base_cfg(artifacts, &col.model, &col.dataset, col.angle, method);
    cfg.epochs = scale.epochs;
    cfg.limit = scale.limit;
    if method == Method::PriotS {
        cfg.frac_scored = frac;
        cfg.theta = 0;
        cfg.selection = Selection::parse(selection)?;
    }
    let pair = data::load_pair(&cfg)?;
    let opts = RunOptions::from_config(&cfg);
    // NITI variants have no random state → a single run suffices (the
    // paper likewise reports NITI without ±std).
    let n_seeds = match method {
        Method::Priot | Method::PriotS => scale.seeds,
        _ => 1,
    };
    let seeds: Vec<u32> = (1..=n_seeds as u32).collect();
    let sweep = sweep_seeds(&cfg, &pair.train, &pair.test, &opts, &seeds)?;
    let finals: Vec<f64> = sweep.runs.iter().map(|r| r.final_accuracy()).collect();
    Ok((sweep.best, MeanStd::of(&finals)))
}

/// Regenerate Table I.  Returns (markdown, raw rows).
pub fn table1(artifacts: &Path, scale: Scale) -> Result<String> {
    let mut columns = vec![
        Table1Column {
            label: "Digits 30°".into(),
            model: "tinycnn".into(),
            dataset: "digits".into(),
            angle: 30,
        },
        Table1Column {
            label: "Digits 45°".into(),
            model: "tinycnn".into(),
            dataset: "digits".into(),
            angle: 45,
        },
    ];
    if scale.include_vgg {
        columns.push(Table1Column {
            label: "Patterns 30° (VGG11)".into(),
            model: "vgg11w0.25".into(),
            dataset: "patterns".into(),
            angle: 30,
        });
    }
    let mut rows = Vec::new();
    for &(row, frac, selection) in TABLE1_ROWS {
        let mut cells = Vec::new();
        for col in &columns {
            let cell = table1_cell(artifacts, col, row, frac, selection, scale);
            match cell {
                Ok(ms) => cells.push(Some(ms)),
                Err(e) => {
                    eprintln!("[table1] {row} × {}: {e}", col.label);
                    cells.push(None);
                }
            }
        }
        rows.push(Table1RowBF { method: row.to_string(), cells });
        eprintln!("[table1] row {row} done");
    }
    let labels: Vec<String> = columns.iter().map(|c| c.label.clone()).collect();
    Ok(table1_markdown_bf(&labels, &rows))
}

/// Regenerate Table II: host wall-clock per image + the Pico cost/memory
/// model, for the four on-device methods.
pub fn table2(artifacts: &Path, model: &str, iters: usize) -> Result<String> {
    let spec = NetSpec::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let scales =
        crate::quant::load_scales(&artifacts.join(format!("{model}.scales.txt")))?;
    let mut rows = Vec::new();
    let variants: Vec<(String, pico::MethodParams, ExperimentConfig)> = vec![
        (
            "Static-Scale NITI".into(),
            pico::MethodParams::new(Method::StaticNiti),
            base_cfg(artifacts, model, "digits", 30, Method::StaticNiti),
        ),
        (
            "PRIOT".into(),
            pico::MethodParams::new(Method::Priot),
            base_cfg(artifacts, model, "digits", 30, Method::Priot),
        ),
        (
            "PRIOT-S (p=90%)".into(),
            pico::MethodParams::priot_s(0.1, Selection::Random),
            {
                let mut c =
                    base_cfg(artifacts, model, "digits", 30, Method::PriotS);
                c.frac_scored = 0.1;
                c
            },
        ),
        (
            "PRIOT-S (p=80%)".into(),
            pico::MethodParams::priot_s(0.2, Selection::Random),
            {
                let mut c =
                    base_cfg(artifacts, model, "digits", 30, Method::PriotS);
                c.frac_scored = 0.2;
                c
            },
        ),
    ];
    for (label, params, mut cfg) in variants {
        // Micro-benchmark: a handful of samples suffices — keep the
        // generated-data fallback cheap when no artifacts exist.
        cfg.gen_train = cfg.gen_train.min(128);
        cfg.gen_test = cfg.gen_test.min(128);
        let pair = data::load_pair(&cfg)?;
        let mut session = Session::from_experiment(&cfg)?;
        let mut img = vec![0i32; pair.train.image_len()];
        let mut sw = Stopwatch::default();
        // warmup
        for i in 0..8.min(pair.train.n) {
            pair.train.image_i32(i, &mut img);
            session.train_step(&img, pair.train.label(i));
        }
        for i in 0..iters.min(pair.train.n) {
            pair.train.image_i32(i, &mut img);
            let label_i = pair.train.label(i);
            sw.start();
            session.train_step(&img, label_i);
            sw.lap();
        }
        rows.push(Table2Row {
            method: label,
            host_ms: sw.stats_ms(),
            pico: pico::step_cost(&spec, &scales, params),
            memory: pico::memory_footprint(&spec, params),
        });
    }
    Ok(table2_markdown(&rows))
}

/// Fig. 2: per-step overflow counts of static-scale NITI across the run —
/// shows the explosion during the collapse epoch.
pub fn fig2(artifacts: &Path, epochs: usize, limit: usize) -> Result<String> {
    let mut cfg = base_cfg(artifacts, "tinycnn", "digits", 30, Method::StaticNiti);
    cfg.epochs = epochs;
    cfg.limit = limit;
    let pair = data::load_pair(&cfg)?;
    let mut session = Session::from_experiment(&cfg)?;
    let n = if limit == 0 { pair.train.n } else { pair.train.n.min(limit) };
    let mut img = vec![0i32; pair.train.image_len()];
    let mut series = Vec::new();
    let mut step = 0u64;
    for _ in 0..epochs {
        for i in 0..n {
            pair.train.image_i32(i, &mut img);
            let out = session.train_step(&img, pair.train.label(i));
            series.push((step, out.overflow));
            step += 1;
        }
    }
    Ok(fig2_csv(&series))
}

/// Fig. 3: accuracy history per method (digits 30°).
pub fn fig3(artifacts: &Path, scale: Scale) -> Result<(String, Vec<RunMetrics>)> {
    let methods: Vec<(String, Method, f64, Selection)> = vec![
        ("static-niti".into(), Method::StaticNiti, 0.0, Selection::Random),
        ("dynamic-niti".into(), Method::DynamicNiti, 0.0, Selection::Random),
        ("priot".into(), Method::Priot, 1.0, Selection::Random),
        ("priot-s-90-weight".into(), Method::PriotS, 0.1, Selection::WeightBased),
        ("priot-s-80-weight".into(), Method::PriotS, 0.2, Selection::WeightBased),
    ];
    let mut names = Vec::new();
    let mut runs = Vec::new();
    for (name, method, frac, selection) in methods {
        let mut cfg = base_cfg(artifacts, "tinycnn", "digits", 30, method);
        cfg.epochs = scale.epochs;
        cfg.limit = scale.limit;
        cfg.frac_scored = frac;
        cfg.selection = selection;
        if method == Method::PriotS {
            cfg.theta = 0;
        }
        let pair = data::load_pair(&cfg)?;
        let mut session = Session::from_experiment(&cfg)?;
        let m = session.train(&pair.train, &pair.test)?;
        eprintln!("[fig3] {name}: best {:.4} {}", m.best_accuracy(),
                  crate::report::sparkline(&m.accuracy));
        names.push(name);
        runs.push(m);
    }
    let refs: Vec<&RunMetrics> = runs.iter().collect();
    Ok((fig3_csv(&names, &refs), runs))
}

/// Ablation: PRIOT threshold sweep + score-lr sweep + stochastic-rounding
/// scores (the design choices DESIGN.md calls out).
pub fn ablation(artifacts: &Path, scale: Scale) -> Result<String> {
    let mut out = String::from("variant,best_acc,final_acc,pruned_frac\n");
    for (label, theta, sr) in [
        ("theta=-96", -96, false),
        ("theta=-64 (paper)", -64, false),
        ("theta=-32", -32, false),
        ("theta=0", 0, false),
        ("theta=-64 +sr-scores", -64, true),
    ] {
        let mut cfg = base_cfg(artifacts, "tinycnn", "digits", 30, Method::Priot);
        cfg.epochs = scale.epochs;
        cfg.limit = scale.limit;
        cfg.theta = theta;
        let pair = data::load_pair(&cfg)?;
        let mut session = SessionBuilder::from_experiment(&cfg)?
            .method(crate::methods::Priot::new()
                        .with_theta(theta)
                        .stochastic_rounding(sr))
            .build()?;
        let m = session.train(&pair.train, &pair.test)?;
        let pruned = m
            .pruned_frac
            .last()
            .map(|fr| fr.iter().sum::<f64>() / fr.len() as f64)
            .unwrap_or(0.0);
        out.push_str(&format!(
            "{label},{:.4},{:.4},{:.4}\n",
            m.best_accuracy(),
            m.final_accuracy(),
            pruned
        ));
        eprintln!("[ablation] {label}: best {:.4}", m.best_accuracy());
    }
    // Score-init sigma ablation is a Python-side knob (init is bit-shared);
    // the equivalent here: seed variance across PRIOT seeds.
    Ok(out)
}

/// Quick self-test: engine vs PJRT bit parity on a few steps (also exposed
/// as an integration test).  Requires the `pjrt` cargo feature.
#[cfg(feature = "pjrt")]
pub fn selftest(artifacts: &Path) -> Result<String> {
    use crate::session::Backend;
    let mut report = String::new();
    for method in [Method::StaticNiti, Method::Priot, Method::PriotS] {
        let mut cfg = base_cfg(artifacts, "tinycnn", "digits", 30, method);
        cfg.frac_scored = 0.1;
        let pair = data::load_pair(&cfg)?;
        let mut eng = Session::from_experiment(&cfg)?;
        let mut pj = SessionBuilder::from_experiment(&cfg)?
            .backend(Backend::Pjrt)
            .build()?;
        if report.is_empty() {
            report.push_str(&format!("PJRT backend: {}\n", pj.name()));
        }
        let mut img = vec![0i32; pair.train.image_len()];
        for i in 0..6.min(pair.train.n) {
            pair.train.image_i32(i, &mut img);
            let label = pair.train.label(i);
            let a = eng.train_step(&img, label);
            let b = pj.train_step(&img, label);
            if a.logits != b.logits || a.overflow != b.overflow {
                bail!(
                    "{}: engine/PJRT diverged at step {i}:\n  engine {:?}\n  pjrt   {:?}",
                    method.name(), a.logits, b.logits
                );
            }
        }
        // compare trained state
        match (eng.scores(), pj.scores()) {
            (Some(a), Some(b)) if a != b => bail!("{}: scores diverged", method.name()),
            _ => {}
        }
        report.push_str(&format!("{}: engine == pjrt over 6 steps ✓\n",
                                 method.name()));
    }
    Ok(report)
}

/// Without the `pjrt` feature there is no second implementation to compare
/// against.
#[cfg(not(feature = "pjrt"))]
pub fn selftest(_artifacts: &Path) -> Result<String> {
    bail!("selftest needs the PJRT backend — rebuild with `--features pjrt`")
}
