//! PJRT runtime: loads the AOT HLO-text artifacts (`make artifacts`),
//! compiles them once on the CPU PJRT client, and exposes them as a
//! [`StepBackend`] — the jax/Pallas execution path of the three-layer
//! architecture.  Adapted from `/opt/xla-example/load_hlo`.
//!
//! Compiled only with the `pjrt` cargo feature (the default build targets
//! the pure-Rust engine; the in-tree `xla-stub` crate satisfies the
//! dependency when the real XLA bindings are absent).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.  All interface tensors are i32 (the crate has no i8
//! literal constructor); graphs convert to int8 semantics internally.
//!
//! The backend is method-agnostic: the [`MethodPlugin`] supplies a
//! [`PjrtPlan`] naming its artifact layout and absorbs the step outputs
//! through its `scores_mut` hook — `rust/cli/tests/parity.rs` asserts
//! bit-for-bit agreement with the engine executor.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::StepOut;
use crate::methods::{MethodPlugin, PjrtPlan, StepBackend};
use crate::session::Backbone;
use crate::spec::NetSpec;

/// A compiled HLO artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Run with i32 tensor inputs; returns the flattened i32 outputs
    /// (the AOT graphs are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<i32>>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.name))?;
        let parts = lit.to_tuple().context("untupling output")?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<i32>().map_err(|e| anyhow!("{e}")))
            .collect()
    }
}

/// The PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e}"))?;
        Ok(Self { client, artifacts_dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` from the artifacts directory.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {} missing — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable { exe, name: name.to_string() })
    }
}

/// Build an i32 literal of the given logical dims.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal size mismatch: {} vs dims {:?}", data.len(), dims);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("{e}"))
}

/// The AOT-artifact training backend (drop-in replacement for the engine
/// executor; `rust/cli/tests/parity.rs` asserts they agree bit-for-bit).
pub struct PjrtBackend {
    spec: NetSpec,
    plugin: Box<dyn MethodPlugin>,
    plan: PjrtPlan,
    weights: Vec<Vec<i32>>,
    step: u32,
    eval_exe: Executable,
    step_exe: Executable,
    label: String,
}

impl PjrtBackend {
    /// Build from a shared backbone and an *initialized* plugin (the
    /// session builder runs `plugin.init` first, so score/mask streams are
    /// bit-identical to the engine executor's).
    pub fn new(rt: &Runtime, backbone: &Backbone,
               plugin: Box<dyn MethodPlugin>) -> Result<Self> {
        let plan = plugin.pjrt_plan().ok_or_else(|| {
            anyhow!("method '{}' has no AOT artifact; use Backend::Engine",
                    plugin.name())
        })?;
        let spec = backbone.spec.clone();
        // PJRT owns its weights: NITI updates them per step, and the XLA
        // graphs take them as inputs either way.
        let weights: Vec<Vec<i32>> =
            backbone.weights.iter().map(|m| m.data.clone()).collect();
        let model = &backbone.model;
        let eval_exe = rt.load(&format!("{model}_fwd_eval"))?;
        let step_exe = match plan {
            PjrtPlan::NitiStep => rt.load(&format!("{model}_niti_step"))?,
            PjrtPlan::ScoreStep => rt.load(&format!("{model}_priot_step"))?,
        };
        let label = format!("pjrt/{}", plugin.name());
        Ok(Self { spec, plugin, plan, weights, step: 0, eval_exe, step_exe, label })
    }

    fn img_literal(&self, img: &[i32]) -> Result<xla::Literal> {
        let (c, h, w) = self.spec.input_chw;
        literal_i32(img, &[c, h, w])
    }

    fn weight_literals(&self) -> Result<Vec<xla::Literal>> {
        self.spec
            .layers
            .iter()
            .zip(self.weights.iter())
            .map(|(l, w)| {
                let (r, c) = l.weight_shape();
                literal_i32(w, &[r, c])
            })
            .collect()
    }

    fn score_mask_literals(&self) -> Result<Vec<xla::Literal>> {
        let (Some(scores), Some(masks)) =
            (self.plugin.scores(), self.plugin.masks())
        else {
            // Score-free methods: fwd_eval still takes score/mask inputs —
            // all-keep dummies.
            let mut lits = Vec::new();
            for l in &self.spec.layers {
                let (r, c) = l.weight_shape();
                lits.push(literal_i32(&vec![0i32; r * c], &[r, c])?);
            }
            for l in &self.spec.layers {
                let (r, c) = l.weight_shape();
                lits.push(literal_i32(&vec![1i32; r * c], &[r, c])?);
            }
            return Ok(lits);
        };
        let mut lits = Vec::new();
        for (l, s) in self.spec.layers.iter().zip(scores.iter()) {
            let (r, c) = l.weight_shape();
            lits.push(literal_i32(s, &[r, c])?);
        }
        for (l, m) in self.spec.layers.iter().zip(masks.iter()) {
            let (r, c) = l.weight_shape();
            lits.push(literal_i32(m, &[r, c])?);
        }
        Ok(lits)
    }

    fn theta_literal(&self) -> Result<xla::Literal> {
        // Score-free methods: no pruning — every dummy score (0) ≥ -128.
        literal_i32(&[self.plugin.theta().unwrap_or(-128)], &[1])
    }

    pub fn try_train_step(&mut self, img: &[i32], label: usize)
                          -> Result<StepOut> {
        let n = self.spec.layers.len();
        let mut onehot = vec![0i32; self.spec.num_classes()];
        onehot[label] = 1;
        let outs = match self.plan {
            PjrtPlan::ScoreStep => {
                let mut inputs = vec![
                    self.img_literal(img)?,
                    literal_i32(&onehot, &[onehot.len()])?,
                    self.theta_literal()?,
                ];
                inputs.extend(self.weight_literals()?);
                inputs.extend(self.score_mask_literals()?);
                let outs = self.step_exe.run(&inputs)?;
                // outputs: scores…, logits, overflow
                let scores = self
                    .plugin
                    .scores_mut()
                    .ok_or_else(|| anyhow!("{}: ScoreStep plan without scores",
                                           self.label))?;
                for (li, s) in scores.iter_mut().enumerate() {
                    s.copy_from_slice(&outs[li]);
                }
                outs
            }
            PjrtPlan::NitiStep => {
                let mut inputs = vec![
                    self.img_literal(img)?,
                    literal_i32(&onehot, &[onehot.len()])?,
                    literal_i32(&[self.step as i32], &[1])?,
                ];
                inputs.extend(self.weight_literals()?);
                let outs = self.step_exe.run(&inputs)?;
                for (li, w) in self.weights.iter_mut().enumerate() {
                    w.copy_from_slice(&outs[li]);
                }
                outs
            }
        };
        self.step += 1;
        let logits = outs[n].clone();
        let overflow = outs[n + 1][0] as u32;
        Ok(StepOut { logits, overflow })
    }

    pub fn try_predict(&mut self, img: &[i32]) -> Result<usize> {
        let mut inputs = vec![self.img_literal(img)?, self.theta_literal()?];
        inputs.extend(self.weight_literals()?);
        inputs.extend(self.score_mask_literals()?);
        let outs = self.eval_exe.run(&inputs)?;
        Ok(crate::engine::argmax(&outs[0]))
    }
}

impl StepBackend for PjrtBackend {
    fn train_step(&mut self, img: &[i32], label: usize) -> StepOut {
        self.try_train_step(img, label)
            .expect("PJRT train step failed")
    }

    fn predict(&mut self, img: &[i32]) -> usize {
        self.try_predict(img).expect("PJRT predict failed")
    }

    fn scores(&self) -> Option<&[Vec<i32>]> {
        self.plugin.scores()
    }

    fn masks(&self) -> Option<&[Vec<i32>]> {
        self.plugin.masks()
    }

    fn theta(&self) -> Option<i32> {
        self.plugin.theta()
    }

    fn name(&self) -> &str {
        &self.label
    }

    fn save_state(&self, path: &Path) -> Result<()> {
        let tensors = match self.plugin.checkpoint_state() {
            Some(t) => t,
            None => crate::methods::weight_checkpoint_tensors(
                &self.spec,
                self.weights.iter().map(|w| w.as_slice()),
            ),
        };
        crate::serial::save_weights(path, &tensors)
    }

    fn load_state(&mut self, path: &Path) -> Result<()> {
        let tensors = crate::serial::load_weights(path)?;
        if self.plugin.restore_state(&tensors)? {
            return Ok(());
        }
        crate::methods::restore_weight_tensors(&self.spec, &tensors,
                                               self.weights.iter_mut())
    }
}
