//! Experiment configuration system.
//!
//! Offline image ⇒ no serde/toml crates; this module implements a small
//! key–value config format (a TOML subset: `key = value` lines, `#`
//! comments, bare `[section]` headers flattened into `section.key`) plus
//! typed accessors and the [`ExperimentConfig`] the coordinator consumes.
//! CLI flags override file values (see `cli`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Parsed config: flat `section.key -> value` string map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

/// Strip a `#` comment, ignoring `#` characters inside double-quoted
/// strings (a naive `split('#')` would truncate `note = "a # b"`).
fn strip_comment(raw: &str) -> &str {
    let mut in_quotes = false;
    for (i, ch) in raw.char_indices() {
        match ch {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &raw[..i],
            _ => {}
        }
    }
    raw
}

/// Remove one matching pair of surrounding double quotes, if present.
fn unquote(v: &str) -> &str {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unclosed section", ln + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let val = unquote(v.trim()).to_string();
            values.insert(key, val);
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}")),
        }
    }

    pub fn get_i32(&self, key: &str, default: i32) -> Result<i32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config {key}: expected bool, got {v}"),
        }
    }
}

// The [`Method`] and [`Selection`] selector enums are plain data shared
// with snapshots and the wire protocol, so they live in the `no_std` core
// crate (`priot_core::methods`); re-exported here because the config file
// is where most callers meet them.  Their `parse` errors are core errors —
// anyhow picks them up at the `?` below.
pub use priot_core::methods::{Method, Selection};

/// Everything one on-device training run needs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub method: Method,
    pub dataset: String, // dataset stem, e.g. "digits" / "patterns"
    pub angle: u32,      // rotation of the on-device distribution
    pub epochs: usize,
    pub seed: u32,
    /// PRIOT pruning threshold θ (paper: -64 for PRIOT, 0 for PRIOT-S).
    pub theta: i32,
    /// PRIOT-S: fraction of edges *with* scores (1 - p).
    pub frac_scored: f64,
    pub selection: Selection,
    /// Execution backend: "engine" (pure Rust) or "pjrt" (AOT artifacts).
    pub backend: String,
    /// Cap on train/test samples (0 = all).
    pub limit: usize,
    /// Record per-layer pruned fractions + mask flips each epoch (a full
    /// scores scan per epoch on the hot path; on by default).
    pub track_pruning: bool,
    /// Samples per forward in dataset evaluation (0/1 = per-sample;
    /// batched evaluation is bit-identical, just faster).
    pub eval_batch: usize,
    /// Samples per training chunk (0/1 = the paper's strictly sequential
    /// loop).  Chunked training batches the forward passes while keeping
    /// every update a sequential batch-1 step — bit-identical.
    pub train_batch: usize,
    /// Worker threads for batched evaluation (0/1 = serial; inference
    /// only, bit-identical).
    pub eval_threads: usize,
    /// Dataset source: `auto` (artifact file if present, generated
    /// otherwise — the default), `artifact`, or `generated`.  See
    /// [`crate::data::DataSource`].
    pub source: String,
    /// Sample counts for generated datasets (default: the full
    /// `make artifacts` size, so generated data and artifact files are
    /// byte-identical per angle).
    pub gen_train: usize,
    pub gen_test: usize,
}

impl ExperimentConfig {
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let method = Method::parse(cfg.get_or("method", "priot"))?;
        let theta_default = match method {
            Method::Priot => -64,
            _ => 0,
        };
        Ok(Self {
            artifacts_dir: PathBuf::from(cfg.get_or("artifacts", "artifacts")),
            model: cfg.get_or("model", "tinycnn").to_string(),
            method,
            dataset: cfg.get_or("dataset", "digits").to_string(),
            angle: cfg.get_usize("angle", 30)? as u32,
            epochs: cfg.get_usize("epochs", 30)?,
            seed: cfg.get_usize("seed", 1)? as u32,
            theta: cfg.get_i32("theta", theta_default)?,
            frac_scored: cfg.get_f64("frac_scored", 0.1)?,
            selection: Selection::parse(cfg.get_or("selection", "weight"))?,
            backend: cfg.get_or("backend", "engine").to_string(),
            limit: cfg.get_usize("limit", 0)?,
            track_pruning: cfg.get_bool("track_pruning", true)?,
            eval_batch: cfg.get_usize("eval_batch", 1)?,
            train_batch: cfg.get_usize("train_batch", 1)?,
            eval_threads: cfg.get_usize("eval_threads", 1)?,
            source: {
                let s = cfg.get_or("source", "auto").to_string();
                match s.as_str() {
                    "auto" | "artifact" | "generated" => s,
                    other => bail!(
                        "config source={other} (want auto|artifact|generated)"
                    ),
                }
            },
            gen_train: cfg.get_usize("gen_train", crate::data::DEFAULT_GEN_N)?,
            gen_test: cfg.get_usize("gen_test", crate::data::DEFAULT_GEN_N)?,
        })
    }

    pub fn train_dataset_path(&self) -> PathBuf {
        self.artifacts_dir
            .join("data")
            .join(format!("{}_train_a{}.bin", self.dataset, self.angle))
    }

    pub fn test_dataset_path(&self) -> PathBuf {
        self.artifacts_dir
            .join("data")
            .join(format!("{}_test_a{}.bin", self.dataset, self.angle))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.artifacts_dir.join(format!("{}.weights.bin", self.model))
    }

    pub fn scales_path(&self) -> PathBuf {
        self.artifacts_dir.join(format!("{}.scales.txt", self.model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_toml_subset() {
        let text = r#"
            # experiment preset
            method = "priot"
            epochs = 30
            [run]
            seed = 7
        "#;
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.get("method"), Some("priot"));
        assert_eq!(cfg.get_usize("epochs", 0).unwrap(), 30);
        assert_eq!(cfg.get_usize("run.seed", 0).unwrap(), 7);
        assert_eq!(cfg.get_usize("missing", 5).unwrap(), 5);
    }

    #[test]
    fn parse_errors() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("no_equals_here").is_err());
        let cfg = Config::parse("x = notanumber").unwrap();
        assert!(cfg.get_usize("x", 0).is_err());
    }

    #[test]
    fn quoted_values_keep_hashes() {
        // regression: split('#') used to truncate quoted values
        let cfg = Config::parse(
            "note = \"rotated # 30 degrees\"\ntag = \"a#b\" # trailing comment",
        )
        .unwrap();
        assert_eq!(cfg.get("note"), Some("rotated # 30 degrees"));
        assert_eq!(cfg.get("tag"), Some("a#b"));
    }

    #[test]
    fn unquoting_removes_one_matching_pair_only() {
        let cfg = Config::parse("a = \"\"\nb = \"x\"\nc = \"\"y\"\"").unwrap();
        assert_eq!(cfg.get("a"), Some(""));
        assert_eq!(cfg.get("b"), Some("x"));
        assert_eq!(cfg.get("c"), Some("\"y\""), "inner quotes survive");
    }

    #[test]
    fn unclosed_section_reports_line() {
        let err = Config::parse("ok = 1\n[run\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(err.to_string().contains("unclosed section"), "{err}");
    }

    #[test]
    fn track_pruning_configurable() {
        let mut cfg = Config::default();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert!(e.track_pruning, "default on");
        cfg.set("track_pruning", "false");
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert!(!e.track_pruning);
    }

    #[test]
    fn experiment_defaults_and_paths() {
        let mut cfg = Config::default();
        cfg.set("method", "priot-s");
        cfg.set("angle", "45");
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.method, Method::PriotS);
        assert_eq!(e.theta, 0, "PRIOT-S default theta");
        assert!(e
            .train_dataset_path()
            .to_string_lossy()
            .ends_with("data/digits_train_a45.bin"));

        let mut cfg2 = Config::default();
        cfg2.set("method", "priot");
        let e2 = ExperimentConfig::from_config(&cfg2).unwrap();
        assert_eq!(e2.theta, -64, "PRIOT default theta");
    }

    #[test]
    fn source_keys_parse_and_validate() {
        let mut cfg = Config::default();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.source, "auto", "artifact-with-generated-fallback default");
        assert_eq!(e.gen_train, crate::data::DEFAULT_GEN_N);
        assert_eq!(e.gen_test, crate::data::DEFAULT_GEN_N);
        cfg.set("source", "generated");
        cfg.set("gen_train", "64");
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.source, "generated");
        assert_eq!(e.gen_train, 64);
        cfg.set("source", "magnetic-tape");
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn method_names_roundtrip() {
        for m in [Method::StaticNiti, Method::DynamicNiti, Method::Priot, Method::PriotS] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("sgd").is_err());
    }
}
