//! In-process procedural dataset generation — the native port of
//! `python/compile/dataset.py` (RotDigits / RotPatterns).
//!
//! The paper's experiment is *distribution drift by rotation*: pre-train
//! upright, adapt on-device to the same classes under an arbitrary
//! rotation.  This module synthesizes those datasets directly in Rust, so
//! `priot fleet` / `priot serve` drift traces (`drift dev0 60`), the test
//! suite, and the benches all run from a bare checkout — no `make
//! artifacts`, no Python toolchain.
//!
//! ## Bit-for-bit parity with the Python generator
//!
//! Generated samples are **byte-identical** to `compile.dataset` for any
//! `(task, n, seed, angle)` tuple.  Like `prng::XorShift32` (the score-init
//! RNG mirrored in `intnet.py`), both sides are written against portable
//! primitives:
//!
//! * [`PortableRng`] — SplitMix64 drawn as a counter (draw `k` mixes
//!   `seed + (k+1)*GAMMA`), so numpy vectorizes draw blocks while this
//!   port consumes the identical sequence one scalar at a time.
//! * [`portable`] — polynomial `sin`/`cos`/`exp`/`tanh` kernels built
//!   from IEEE-754 exactly-rounded ops only (`+ - * /`, `sqrt`, `floor`).
//!   libm transcendentals are never called: numpy's SIMD kernels and
//!   glibc can disagree in the last ulp, which a byte-level contract
//!   cannot tolerate.
//! * Gaussian-ish noise is Irwin–Hall (four uniforms, variance
//!   normalized); shuffles are Fisher–Yates over `raw % bound`; the digit
//!   stroke skeletons are frozen literals ([`strokes::DIGIT_STROKES`])
//!   shared verbatim with the Python module.
//!
//! The contract is pinned by golden fixtures generated once from the
//! Python side (`python -m compile.goldens` →
//! `rust/cli/tests/fixtures/datagen/`) and asserted byte-for-byte by
//! `rust/cli/tests/datagen.rs`.  Any change to the math here or in
//! `dataset.py` must regenerate those fixtures.
//!
//! ## Entry points
//!
//! * [`generate`] — `(task, n, seed, angle)` → a [`Dataset`] of u8 pixels
//!   (the device maps them to int8 activations via `p >> 1`, exactly like
//!   artifact data — see [`crate::serial::u8_to_i32_pixels`]).
//! * [`device_seed`] — the canonical seed for an on-device train/test set
//!   at a given angle, shared with `aot.py` so generated data and
//!   artifact files coincide at every angle.
//! * [`Task`] — the two dataset families and their geometry.
//! * [`fnv1a64`] / [`dataset_hash`] — the fixture-hash function used by
//!   the golden-parity tests and the serve round-trip checks.
//!
//! The resolution layer that decides *when* to generate instead of
//! loading artifacts lives in [`crate::data`] ([`crate::data::DataSource`]).

mod strokes;

pub use strokes::DIGIT_STROKES;

use anyhow::{bail, Result};

use crate::serial::Dataset;

// ---------------------------------------------------------------------------
// Portable math kernels (bit-identical to compile.dataset)
// ---------------------------------------------------------------------------

/// Polynomial transcendentals over exactly-rounded IEEE-754 ops.  Every
/// constant and the evaluation order mirror `python/compile/dataset.py`
/// verbatim — do not "simplify" an expression here without changing the
/// Python side and regenerating the golden fixtures.
pub mod portable {
    pub const TWO_PI: f64 = 6.283185307179586;
    pub const INV_TWO_PI: f64 = 0.15915494309189535;
    pub const RAD_PER_DEG: f64 = 0.017453292519943295;
    pub const LN2: f64 = 0.6931471805599453;
    pub const LOG2E: f64 = 1.4426950408889634;
    /// sqrt(3): normalizes the Irwin–Hall(4) sum to unit variance.
    pub const NOISE_NORM: f64 = 1.7320508075688772;
    /// 2^-53 — top-53-bit uniform scaling.
    pub const U53: f64 = 1.0 / 9007199254740992.0;

    const SIN_COEFFS: [f64; 9] = [
        -8.22063524662433e-18,   // 1/19!
        2.8114572543455206e-15,  // 1/17!
        -7.647163731819816e-13,  // 1/15!
        1.6059043836821613e-10,  // 1/13!
        -2.505210838544172e-08,  // 1/11!
        2.7557319223985893e-06,  // 1/9!
        -0.0001984126984126984,  // 1/7!
        0.008333333333333333,    // 1/5!
        -0.16666666666666666,    // 1/3!
    ];

    const COS_COEFFS: [f64; 10] = [
        4.110317623312165e-19,   // 1/20!
        -1.5619206968586225e-16, // 1/18!
        4.779477332387385e-14,   // 1/16!
        -1.1470745597729725e-11, // 1/14!
        2.08767569878681e-09,    // 1/12!
        -2.755731922398589e-07,  // 1/10!
        2.48015873015873e-05,    // 1/8!
        -0.001388888888888889,   // 1/6!
        0.041666666666666664,    // 1/4!
        -0.5,                    // 1/2!
    ];

    const EXP_COEFFS: [f64; 13] = [
        2.08767569878681e-09,   // 1/12!
        2.505210838544172e-08,  // 1/11!
        2.755731922398589e-07,  // 1/10!
        2.7557319223985893e-06, // 1/9!
        2.48015873015873e-05,   // 1/8!
        0.0001984126984126984,  // 1/7!
        0.001388888888888889,   // 1/6!
        0.008333333333333333,   // 1/5!
        0.041666666666666664,   // 1/4!
        0.16666666666666666,    // 1/3!
        0.5,                    // 1/2!
        1.0,                    // 1/1!
        1.0,                    // 1/0!
    ];

    /// Portable sine: range-reduce to `[-pi, pi]`, odd Taylor through y^19.
    pub fn p_sin(x: f64) -> f64 {
        let k = (x * INV_TWO_PI + 0.5).floor();
        let y = x - k * TWO_PI;
        let y2 = y * y;
        let mut p = SIN_COEFFS[0];
        for &c in &SIN_COEFFS[1..] {
            p = p * y2 + c;
        }
        y + y * y2 * p
    }

    /// Portable cosine: range-reduce to `[-pi, pi]`, even Taylor through
    /// y^20.
    pub fn p_cos(x: f64) -> f64 {
        let k = (x * INV_TWO_PI + 0.5).floor();
        let y = x - k * TWO_PI;
        let y2 = y * y;
        let mut p = COS_COEFFS[0];
        for &c in &COS_COEFFS[1..] {
            p = p * y2 + c;
        }
        1.0 + y2 * p
    }

    /// `2^k` for exponents in the normal f64 range — an exact value, so
    /// multiplying by it never rounds (only overflows/underflows).
    fn exp2i(k: i64) -> f64 {
        debug_assert!((-1022..=1023).contains(&k), "exp2i exponent {k}");
        f64::from_bits(((1023 + k) as u64) << 52)
    }

    /// Portable exp: `2^k * poly(r)` with `r = x - k*ln2`, Taylor through
    /// r^12.  The scaling is split into two exact power-of-two factors so
    /// the full `np.ldexp` range is matched — overflow saturates to ∞ and
    /// deep underflow to 0/subnormals exactly like the Python kernel,
    /// not just over the renderer's bounded inputs.
    pub fn p_exp(x: f64) -> f64 {
        let k = (x * LOG2E + 0.5).floor();
        let r = x - k * LN2;
        let mut p = EXP_COEFFS[0];
        for &c in &EXP_COEFFS[1..] {
            p = p * r + c;
        }
        // Beyond ±2044 the result is definitively 0/∞ for any mantissa;
        // inside, each half-exponent is a normal power of two, the first
        // multiply stays exact, and the second rounds at most once —
        // exactly what one correctly-rounded ldexp does.
        let k = (k as i64).clamp(-2044, 2044);
        let k1 = k / 2;
        p * exp2i(k1) * exp2i(k - k1)
    }

    /// Portable tanh via [`p_exp`]: `(e^{2x} - 1) / (e^{2x} + 1)`.
    pub fn p_tanh(x: f64) -> f64 {
        let t = p_exp(x + x);
        (t - 1.0) / (t + 1.0)
    }
}

use portable::{p_cos, p_exp, p_sin, p_tanh, NOISE_NORM, RAD_PER_DEG, TWO_PI, U53};

// ---------------------------------------------------------------------------
// Portable PRNG (SplitMix64 as a counter generator)
// ---------------------------------------------------------------------------

const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;

/// SplitMix64 drawn as a counter: draw `k` (0-based, across the whole
/// stream) mixes `seed + (k+1)*GAMMA`.  The Python generator vectorizes
/// blocks of draws; this port consumes the identical sequence one scalar
/// at a time.
#[derive(Clone, Debug)]
pub struct PortableRng {
    seed: u64,
    count: u64,
}

impl PortableRng {
    pub fn new(seed: u64) -> Self {
        Self { seed, count: 0 }
    }

    /// The next raw u64 draw.
    #[inline]
    pub fn raw(&mut self) -> u64 {
        self.count += 1;
        let mut z = self.seed.wrapping_add(self.count.wrapping_mul(GAMMA));
        z ^= z >> 30;
        z = z.wrapping_mul(MIX1);
        z ^= z >> 27;
        z = z.wrapping_mul(MIX2);
        z ^ (z >> 31)
    }

    /// One uniform in `[0, 1)` — top 53 bits scaled by 2^-53.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.raw() >> 11) as f64 * U53
    }

    /// One uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// One Irwin–Hall(4) noise value: ~N(0, scale^2), 4 draws.
    #[inline]
    pub fn noise(&mut self, scale: f64) -> f64 {
        let u0 = self.f64();
        let u1 = self.f64();
        let u2 = self.f64();
        let u3 = self.f64();
        (u0 + u1 + u2 + u3 - 2.0) * NOISE_NORM * scale
    }

    /// One draw in `[0, bound)` (modulo; the tiny bias is irrelevant and
    /// identical across languages, which is what matters).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        self.raw() % bound
    }

    /// Fisher–Yates permutation of `0..n` (n-1 draws).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut arr: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            arr.swap(i, j);
        }
        arr
    }
}

// ---------------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------------

fn clip(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

fn sign(x: f64) -> f64 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Rasterize one jittered, rotated digit into `out` (`size * size` u8).
fn render_digit(rng: &mut PortableRng, cls: usize, size: usize,
                angle_deg: f64, out: &mut [u8]) {
    debug_assert_eq!(out.len(), size * size);
    // Random affine jitter: scale, shear, translate + per-sample tilt (the
    // tilt is part of the base distribution — it is what gives the
    // backbone its partial rotation tolerance before transfer).
    let scale = rng.uniform(0.82, 1.05);
    let shear = rng.uniform(-0.12, 0.12);
    let tilt = rng.uniform(-14.0, 14.0);
    let shift_x = rng.uniform(-0.06, 0.06);
    let shift_y = rng.uniform(-0.06, 0.06);
    let thick = rng.uniform(0.045, 0.075);
    let a = (angle_deg + tilt) * RAD_PER_DEG;
    let co = p_cos(a);
    let si = p_sin(a);
    // rot(a) @ [[scale, shear], [0, scale]], written out.
    let a00 = co * scale;
    let a01 = co * shear - si * scale;
    let a10 = si * scale;
    let a11 = si * shear + co * scale;

    let fsize = size as f64;
    let mut img = vec![0.0f64; size * size];
    for stroke in DIGIT_STROKES[cls] {
        let npts = stroke.len();
        let jit: Vec<f64> = (0..npts * 2).map(|_| rng.noise(0.012)).collect();
        let mut tx = vec![0.0f64; npts];
        let mut ty = vec![0.0f64; npts];
        for (i, &(sx, sy)) in stroke.iter().enumerate() {
            let ux = sx - 0.5 + jit[2 * i];
            let uy = sy - 0.5 + jit[2 * i + 1];
            tx[i] = ux * a00 + uy * a01 + 0.5 + shift_x;
            ty[i] = ux * a10 + uy * a11 + 0.5 + shift_y;
        }
        // Distance field to the polyline: min over segments of the clamped
        // point-segment distance.
        for yy in 0..size {
            for xx in 0..size {
                let px = (xx as f64 + 0.5) / fsize;
                let py = (yy as f64 + 0.5) / fsize;
                let mut d2min = f64::INFINITY;
                for s in 0..npts - 1 {
                    let ax = tx[s];
                    let ay = ty[s];
                    let abx = tx[s + 1] - ax;
                    let aby = ty[s + 1] - ay;
                    let mut denom = abx * abx + aby * aby;
                    if denom < 1e-9 {
                        denom = 1e-9;
                    }
                    let t = clip(
                        ((px - ax) * abx + (py - ay) * aby) / denom, 0.0, 1.0,
                    );
                    let dx = px - (ax + t * abx);
                    let dy = py - (ay + t * aby);
                    let d2 = dx * dx + dy * dy;
                    if d2 < d2min {
                        d2min = d2;
                    }
                }
                let v = clip(1.35 - d2min.sqrt() / thick, 0.0, 1.0);
                let cell = &mut img[yy * size + xx];
                if v > *cell {
                    *cell = v;
                }
            }
        }
    }
    for cell in img.iter_mut() {
        *cell += rng.noise(0.045); // sensor noise
    }
    for (o, &v) in out.iter_mut().zip(img.iter()) {
        *o = (clip(v, 0.0, 1.0) * 255.0) as u8;
    }
}

/// One 3-channel procedural pattern into `out` (`3 * size * size` u8,
/// CHW order).
fn render_pattern(rng: &mut PortableRng, cls: usize, size: usize,
                  angle_deg: f64, out: &mut [u8]) {
    debug_assert_eq!(out.len(), 3 * size * size);
    let a = (angle_deg + rng.uniform(-5.0, 5.0)) * RAD_PER_DEG;
    let co = p_cos(a);
    let si = p_sin(a);
    let f = rng.uniform(2.5, 4.5); // frequency jitter
    let ph = rng.uniform(0.0, TWO_PI); // phase jitter
    let fsize = size as f64;
    let half = fsize / 2.0;
    // The per-sample extra draw of class 6 must happen at the same stream
    // position as in Python (after f/ph, before the tint jitter).
    let blob_k = if cls == 6 { rng.uniform(9.0, 14.0) } else { 0.0 };

    let mut base = vec![0.0f64; size * size];
    for yy in 0..size {
        for xx in 0..size {
            let u = (xx as f64 - half + 0.5) / fsize;
            let v = (yy as f64 - half + 0.5) / fsize;
            let ur = co * u - si * v;
            let vr = si * u + co * v;
            let r2 = ur * ur + vr * vr;
            base[yy * size + xx] = match cls {
                0 => {
                    // horizontal stripes
                    let w = TWO_PI * f;
                    p_sin(w * vr + ph)
                }
                1 => {
                    // vertical stripes
                    let w = TWO_PI * f;
                    p_sin(w * ur + ph)
                }
                2 => {
                    // checkerboard
                    let w = TWO_PI * f;
                    sign(p_sin(w * ur + ph)) * sign(p_sin(w * vr + ph))
                }
                3 => {
                    // concentric rings
                    let w = TWO_PI * (1.8 * f);
                    p_sin(w * r2.sqrt() + ph)
                }
                4 => {
                    // diagonal stripes
                    let w = TWO_PI * f;
                    p_sin(w * (ur + vr) + ph)
                }
                5 => {
                    // radial fan: sin(6*theta + ph) via angle addition
                    if r2 > 0.0 {
                        let r = r2.sqrt();
                        let c1 = ur / r;
                        let s1 = vr / r;
                        let mut c6 = c1;
                        let mut s6 = s1;
                        for _ in 0..5 {
                            let cn = c6 * c1 - s6 * s1;
                            let sn = s6 * c1 + c6 * s1;
                            c6 = cn;
                            s6 = sn;
                        }
                        s6 * p_cos(ph) + c6 * p_sin(ph)
                    } else {
                        0.0
                    }
                }
                6 => 2.0 * p_exp(-r2 * blob_k) - 1.0, // centered blob
                7 => p_tanh(3.0 * (ur + vr)),         // corner gradient
                8 => {
                    // square outline
                    let m = ur.abs().max(vr.abs());
                    clip(1.0 - 14.0 * (m - 0.28).abs(), -1.0, 1.0)
                }
                _ => {
                    // cross
                    let m = ur.abs().min(vr.abs());
                    clip(1.0 - 12.0 * m, -1.0, 1.0)
                }
            };
        }
    }
    // Class-tinted colorization with per-sample jitter.
    let tint_base = [
        (cls * 53 % 97) as f64 / 97.0,
        (cls * 31 % 89) as f64 / 89.0,
        (cls * 71 % 83) as f64 / 83.0,
    ];
    let mut tint = [0.0f64; 3];
    for ch in 0..3 {
        let mut tc = tint_base[ch] + rng.uniform(-0.15, 0.15);
        if tc < 0.05 {
            tc = 0.05;
        }
        if tc > 1.0 {
            tc = 1.0;
        }
        tint[ch] = tc;
    }
    for ch in 0..3 {
        for (o, &b) in out[ch * size * size..(ch + 1) * size * size]
            .iter_mut()
            .zip(base.iter())
        {
            let v = (b * 0.5 + 0.5) * tint[ch] + rng.noise(0.05);
            *o = (clip(v, 0.0, 1.0) * 255.0) as u8;
        }
    }
}

// ---------------------------------------------------------------------------
// Dataset assembly
// ---------------------------------------------------------------------------

/// The two procedural dataset families (the rotated-MNIST / rotated-CIFAR
/// stand-ins).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    /// 28x28x1 stroke digits ("digits" stems, the tinycnn input).
    Digits,
    /// 32x32x3 procedural textures ("patterns" stems, the VGG input).
    Patterns,
}

impl Task {
    /// Parse a dataset stem prefix (`digits` / `patterns`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "digits" => Task::Digits,
            "patterns" => Task::Patterns,
            other => bail!("unknown dataset {other} (want digits|patterns)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Digits => "digits",
            Task::Patterns => "patterns",
        }
    }

    /// Image geometry `(c, h, w)`.
    pub fn chw(&self) -> (usize, usize, usize) {
        match self {
            Task::Digits => (1, 28, 28),
            Task::Patterns => (3, 32, 32),
        }
    }
}

/// Train/test split selector for [`device_seed`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "train" => Split::Train,
            "test" => Split::Test,
            other => bail!("unknown split {other} (want train|test)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Test => "test",
        }
    }
}

/// Canonical seed for an on-device (train/test, angle) set — shared with
/// `compile.dataset.device_seed` so generated data and artifact files
/// coincide for every angle.
pub fn device_seed(task: Task, split: Split, angle: u32) -> u64 {
    let task_id: u64 = match task {
        Task::Digits => 0,
        Task::Patterns => 1,
    };
    let split_id: u64 = match split {
        Split::Train => 0,
        Split::Test => 1,
    };
    3000 + task_id * 6000 + split_id * 1000 + angle as u64
}

/// Generate `n` samples of `task` rotated by `angle_deg` — deterministic
/// in `seed` and byte-identical to the Python generator for the same
/// tuple.  Labels cycle the 10 classes, shuffled.
pub fn generate(task: Task, n: usize, seed: u64, angle_deg: f64) -> Dataset {
    let (c, h, w) = task.chw();
    let mut rng = PortableRng::new(seed);
    let perm = rng.permutation(n);
    let labels: Vec<u8> = perm.iter().map(|&p| (p % 10) as u8).collect();
    let len = c * h * w;
    let mut images = vec![0u8; n * len];
    for (i, &label) in labels.iter().enumerate() {
        let out = &mut images[i * len..(i + 1) * len];
        match task {
            Task::Digits => {
                render_digit(&mut rng, label as usize, h, angle_deg, out)
            }
            Task::Patterns => {
                render_pattern(&mut rng, label as usize, h, angle_deg, out)
            }
        }
    }
    Dataset { n, c, h, w, images, labels }
}

/// Generate the train/test pair for a device distribution at `angle`
/// using the canonical [`device_seed`] convention.
pub fn generate_pair(task: Task, n_train: usize, n_test: usize, angle: u32)
                     -> (Dataset, Dataset) {
    let train = generate(task, n_train,
                         device_seed(task, Split::Train, angle),
                         angle as f64);
    let test = generate(task, n_test,
                        device_seed(task, Split::Test, angle),
                        angle as f64);
    (train, test)
}

// ---------------------------------------------------------------------------
// Fixture hashing
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit — the fixture-hash function (`compile.goldens` writes the
/// same hashes from Python).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Hash of a dataset's payload (image bytes, then label bytes).
pub fn dataset_hash(ds: &Dataset) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in ds.images.iter().chain(ds.labels.iter()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_reference_vectors() {
        // SplitMix64 with seed 0: canonical first outputs (Steele et al.;
        // also asserted against compile.dataset in the pytest suite).
        let mut r = PortableRng::new(0);
        assert_eq!(r.raw(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.raw(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.raw(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn uniforms_in_range_and_deterministic() {
        let mut a = PortableRng::new(7);
        let mut b = PortableRng::new(7);
        for _ in 0..1000 {
            let x = a.f64();
            assert!((0.0..1.0).contains(&x));
            assert_eq!(x.to_bits(), b.f64().to_bits());
        }
        let mut c = PortableRng::new(8);
        assert_ne!(a.f64().to_bits(), c.f64().to_bits());
    }

    #[test]
    fn noise_is_centered() {
        let mut r = PortableRng::new(3);
        let n = 20_000;
        let vals: Vec<f64> = (0..n).map(|_| r.noise(0.045)).collect();
        let mean: f64 = vals.iter().sum::<f64>() / n as f64;
        let var: f64 =
            vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / n as f64;
        assert!(mean.abs() < 0.002, "mean {mean}");
        let sigma = var.sqrt();
        assert!((0.035..0.055).contains(&sigma), "sigma {sigma} not ~0.045");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = PortableRng::new(11);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn portable_kernels_are_accurate() {
        // Parity comes from identical bits, but the kernels must also be
        // *accurate* enough that the rendered geometry is right.
        let mut x = -40.0;
        while x < 40.0 {
            assert!((p_sin(x) - x.sin()).abs() < 1e-8, "sin({x})");
            assert!((p_cos(x) - x.cos()).abs() < 1e-8, "cos({x})");
            x += 0.0137;
        }
        let mut x = -9.0;
        while x < 9.0 {
            let rel = (p_exp(x) / x.exp() - 1.0).abs();
            assert!(rel < 1e-12, "exp({x}) rel {rel}");
            assert!((p_tanh(x / 3.0) - (x / 3.0).tanh()).abs() < 1e-12);
            x += 0.0171;
        }
        // Out-of-range arguments saturate like np.ldexp — the kernel is
        // public, so the contract must hold beyond the renderer's inputs.
        assert_eq!(p_exp(-800.0), 0.0);
        assert_eq!(p_exp(800.0), f64::INFINITY);
        assert_eq!(p_exp(-5000.0), 0.0);
        assert_eq!(p_exp(5000.0), f64::INFINITY);
    }

    #[test]
    fn generate_shapes_and_labels() {
        for (task, c, h, w) in
            [(Task::Digits, 1, 28, 28), (Task::Patterns, 3, 32, 32)]
        {
            let ds = generate(task, 20, 42, 30.0);
            assert_eq!((ds.n, ds.c, ds.h, ds.w), (20, c, h, w));
            assert_eq!(ds.images.len(), 20 * c * h * w);
            assert_eq!(ds.labels.len(), 20);
            // labels cycle 0..10: each class appears exactly twice
            let mut counts = [0usize; 10];
            for &l in &ds.labels {
                counts[l as usize] += 1;
            }
            assert_eq!(counts, [2; 10], "{task:?}");
            // pixels must not be blank or saturated
            let mean: f64 = ds.images.iter().map(|&p| p as f64).sum::<f64>()
                / ds.images.len() as f64;
            assert!((5.0..250.0).contains(&mean), "{task:?} mean {mean}");
        }
    }

    #[test]
    fn generation_is_deterministic_and_seed_sensitive() {
        let a = generate(Task::Digits, 8, 5, 45.0);
        let b = generate(Task::Digits, 8, 5, 45.0);
        assert_eq!(a, b);
        let c = generate(Task::Digits, 8, 6, 45.0);
        assert_ne!(a, c, "different seed, different bytes");
        let d = generate(Task::Digits, 8, 5, 46.0);
        assert_ne!(a, d, "different angle, different bytes");
    }

    #[test]
    fn device_seed_convention() {
        // Pinned: aot.py writes artifact files with these exact seeds, so
        // generated data and artifacts coincide per (task, split, angle).
        assert_eq!(device_seed(Task::Digits, Split::Train, 30), 3030);
        assert_eq!(device_seed(Task::Digits, Split::Test, 30), 4030);
        assert_eq!(device_seed(Task::Digits, Split::Train, 45), 3045);
        assert_eq!(device_seed(Task::Patterns, Split::Train, 30), 9030);
        assert_eq!(device_seed(Task::Patterns, Split::Test, 60), 10060);
    }

    #[test]
    fn fnv_reference_vector() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        let ds = generate(Task::Digits, 2, 1, 0.0);
        let mut payload = ds.images.clone();
        payload.extend_from_slice(&ds.labels);
        assert_eq!(dataset_hash(&ds), fnv1a64(&payload));
    }

    #[test]
    fn generate_pair_uses_canonical_seeds() {
        let (train, test) = generate_pair(Task::Digits, 4, 4, 60);
        assert_eq!(train,
                   generate(Task::Digits, 4,
                            device_seed(Task::Digits, Split::Train, 60),
                            60.0));
        assert_eq!(test,
                   generate(Task::Digits, 4,
                            device_seed(Task::Digits, Split::Test, 60),
                            60.0));
        assert_ne!(train, test);
    }
}
