//! Run metrics: accuracy/overflow/pruning traces and aggregation over
//! seeds.  `report` turns these into the paper's tables/figures.
//!
//! Timing helpers now live in [`crate::obs::clock`] (integer-microsecond
//! spans with one documented float seam); the float-lap [`Stopwatch`]
//! here is deprecated and kept only so external callers get a
//! deprecation warning instead of a break.

use std::time::Instant;

/// Everything one training run records (epoch granularity, epoch 0 = the
/// pre-training state — the paper's Fig. 3 curves start at the backbone
/// accuracy, which is also how static-NITI's "best" lands at ~baseline).
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Top-1 test accuracy at each epoch boundary (index 0 = before
    /// training).
    pub accuracy: Vec<f64>,
    /// Training-set top-1 per epoch (index aligned with accuracy[1..]).
    pub train_accuracy: Vec<f64>,
    /// Sum of final-layer overflow counts per epoch (Fig. 2 probe).
    pub overflow: Vec<u64>,
    /// Per-epoch fraction of pruned edges per layer (PRIOT only).
    pub pruned_frac: Vec<Vec<f64>>,
    /// # of edges whose pruned/unpruned state flipped between consecutive
    /// epochs (the §IV-B oscillation analysis).
    pub mask_flips: Vec<u64>,
    /// Wall-clock seconds per training epoch.
    pub epoch_secs: Vec<f64>,
    /// Training steps actually executed per epoch (may be less than the
    /// planned `epochs × capped(n)` for empty datasets or early-exit runs —
    /// throughput reporting must divide by this, not the plan).
    pub steps: Vec<u64>,
}

impl RunMetrics {
    /// Best top-1 test accuracy over the run (the Table I metric:
    /// "best top-1 accuracy during training" — the device checkpoints the
    /// best-training-accuracy model; we report the matching test score).
    pub fn best_accuracy(&self) -> f64 {
        self.accuracy.iter().copied().fold(0.0, f64::max)
    }

    pub fn final_accuracy(&self) -> f64 {
        *self.accuracy.last().unwrap_or(&0.0)
    }

    /// Executed training steps summed over all epochs.
    pub fn total_steps(&self) -> u64 {
        self.steps.iter().sum()
    }
}

/// Mean and (population) standard deviation over seed repetitions.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeanStd {
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl MeanStd {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Self { mean, std: var.sqrt(), n }
    }

    /// Format as the paper does: `88.94 (±1.02)` (percent points).
    pub fn fmt_pct(&self) -> String {
        if self.n <= 1 {
            format!("{:.2}", self.mean * 100.0)
        } else {
            format!("{:.2} (±{:.2})", self.mean * 100.0, self.std * 100.0)
        }
    }
}

/// Simple stopwatch with mean/std over laps (Table II timing).
#[deprecated(
    note = "use crate::obs::Stopwatch — same start/lap/stats_ms surface, \
            integer-microsecond laps underneath"
)]
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps: Vec<f64>,
    started: Option<Instant>,
}

#[allow(deprecated)]
impl Stopwatch {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn lap(&mut self) {
        if let Some(t) = self.started.take() {
            self.laps.push(t.elapsed().as_secs_f64());
        }
    }

    pub fn stats_ms(&self) -> MeanStd {
        let ms: Vec<f64> = self.laps.iter().map(|s| s * 1e3).collect();
        MeanStd::of(&ms)
    }

    pub fn count(&self) -> usize {
        self.laps.len()
    }
}

/// CSV emit helper: one header + rows of f64 columns.
pub fn to_csv(header: &[&str], rows: &[Vec<f64>]) -> String {
    let mut out = header.join(",");
    out.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let m = MeanStd::of(&[1.0, 2.0, 3.0]);
        assert!((m.mean - 2.0).abs() < 1e-12);
        assert!((m.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(m.n, 3);
    }

    #[test]
    fn fmt_pct_matches_paper_style() {
        let m = MeanStd { mean: 0.8894, std: 0.0102, n: 10 };
        assert_eq!(m.fmt_pct(), "88.94 (±1.02)");
        let one = MeanStd { mean: 0.8086, std: 0.0, n: 1 };
        assert_eq!(one.fmt_pct(), "80.86");
    }

    #[test]
    fn best_accuracy_includes_epoch0() {
        let m = RunMetrics {
            accuracy: vec![0.80, 0.35, 0.10],
            ..Default::default()
        };
        assert!((m.best_accuracy() - 0.80).abs() < 1e-12,
                "collapsed run's best is the pre-training point");
    }

    #[test]
    fn csv_shape() {
        let csv = to_csv(&["a", "b"], &[vec![1.0, 2.0], vec![3.0, 4.5]]);
        assert_eq!(csv, "a,b\n1,2\n3,4.5\n");
    }
}
