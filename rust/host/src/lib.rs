//! # priot-host — the std-side layer of the PRIOT stack
//!
//! Everything that needs an operating system lives here, layered over the
//! freestanding [`priot_core`] crate (tensors, quantized engine, method
//! plugins — `no_std` + `alloc`, the code a Pico port would carry):
//!
//! * **Data**: procedural dataset generation ([`datagen`]), dataset/config
//!   resolution ([`data`], [`config`]), binary file IO ([`serial`]).
//! * **Execution**: sessions and fleets over the core engine
//!   ([`session`]), the PJRT backend behind the `pjrt` feature
//!   ([`runtime`]), the experiment coordinator ([`coordinator`]).
//! * **Serving**: the wire protocol ([`proto`]), the long-lived fleet
//!   service ([`serve`] = [`session::serve`]), durable per-device state
//!   ([`store`]).
//! * **Analysis**: the static overflow-soundness auditor ([`audit`]), the
//!   Pico cost model ([`pico`]), metrics/report generation ([`metrics`],
//!   [`report`]), property-test scaffolding ([`ptest`]).
//!
//! ## Layering contract
//!
//! Dependencies point one way: plugins and numerics live in `priot-core`;
//! transports, stores, threads, files, and clocks live here.  The core
//! modules are re-exported below under their original names
//! ([`tensor`], [`quant`], [`engine`], [`methods`], [`spec`], [`prng`],
//! [`serial`]) so host code and downstream crates use one consistent
//! path set; the [`methods`], [`quant`] and [`serial`] re-exports are
//! thin shims that add the host-only pieces (the `StepBackend` executor
//! trait, file loading) on top of the core items.
//!
//! Core errors ([`priot_core::error::Error`]) implement
//! `core::error::Error`, so they compose with [`anyhow`] at this seam via
//! plain `?` — no adapter layer.

pub use priot_core::{engine, prng, spec, tensor};
pub use priot_core::INT8_MAX;

pub mod audit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod datagen;
pub mod methods;
pub mod metrics;
pub mod obs;
pub mod pico;
pub mod proto;
pub mod ptest;
pub mod quant;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serial;
pub mod session;
pub mod store;

pub use session::serve;
