//! Offline stub of the `xla` PJRT bindings (xla-rs / xla_extension 0.5.x).
//!
//! The real bindings need the native XLA toolchain, which the offline
//! build image does not ship.  This stub mirrors exactly the API surface
//! `priot::runtime` uses so `cargo build/clippy --features pjrt` type-check
//! everywhere; every runtime entry point returns [`Error::Unavailable`].
//! To execute the AOT artifacts for real, point the `xla` path dependency
//! in `rust/Cargo.toml` at the actual bindings.

use std::fmt;

/// The single error the stub produces.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT runtime unavailable (offline xla-stub build — link the \
             real xla bindings to execute AOT artifacts)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable)
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A host literal.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
