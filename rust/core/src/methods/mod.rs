//! Training-method layer: the paper's methods as *pluggable* objects.
//!
//! A [`MethodPlugin`] owns everything that is method-specific — mutable
//! state (scores/masks), the step and predict rules, checkpoint tensors,
//! and (optionally) a PJRT execution plan.  The host-side executors
//! (`priot_host::session`, `priot_host::runtime`) are method-agnostic:
//! adding a new training method (e.g. a TinyTrain-style sparse-layer
//! selector) means implementing this trait, not editing the engine or the
//! coordinator.
//!
//! Built-in plugins: [`Niti`] (static/dynamic scales), [`Priot`] (dense
//! scores), [`PriotS`] (sparse scores).  Their numerics are bit-identical
//! to the pre-plugin implementation — the engine⇄PJRT parity suite in
//! `rust/cli/tests/` still asserts bit-for-bit equality.
//!
//! This module also owns the *descriptions* of methods: the [`Method`] and
//! [`Selection`] selector enums and the serializable [`MethodSpec`].  They
//! are plain data plus `plugin()` materialization, so they live in the
//! `no_std` core; the wire codec for `MethodSpec` (and the host-only
//! `StepBackend` executor trait) live in `priot_host`.

use alloc::boxed::Box;
use alloc::vec;
use alloc::vec::Vec;

use crate::bail;
use crate::engine::{Engine, PruneState, StepOut};
use crate::error::Result;
use crate::prng::{init_scores, select_mask_random, XorShift32};
use crate::serial::TensorI8;
use crate::spec::NetSpec;

/// Training method selector (the four columns of Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    StaticNiti,
    DynamicNiti,
    Priot,
    PriotS,
}

impl Method {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "static-niti" => Method::StaticNiti,
            "dynamic-niti" => Method::DynamicNiti,
            "priot" => Method::Priot,
            "priot-s" => Method::PriotS,
            other => bail!(
                "unknown method {other} (want static-niti|dynamic-niti|priot|priot-s)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::StaticNiti => "static-niti",
            Method::DynamicNiti => "dynamic-niti",
            Method::Priot => "priot",
            Method::PriotS => "priot-s",
        }
    }
}

/// PRIOT-S scored-edge selection strategy (§III-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    Random,
    WeightBased,
}

impl Selection {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "random" => Selection::Random,
            "weight" | "weight-based" => Selection::WeightBased,
            other => bail!("unknown selection {other} (want random|weight)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Selection::Random => "random",
            Selection::WeightBased => "weight-based",
        }
    }
}

/// The serializable description of a training method — what a `Register`
/// carries instead of a live plugin object.  The server materializes it
/// via [`MethodSpec::plugin`].  (The wire encoding lives in the host
/// crate's `proto::codec`; this type is the payload.)
#[derive(Clone, Debug, PartialEq)]
pub struct MethodSpec {
    pub method: Method,
    /// PRIOT-S scored fraction (ignored by other methods).
    pub frac_scored: f64, // layering-allow: config-time fraction, never hot-path
    /// PRIOT-S edge-selection strategy (ignored by other methods).
    pub selection: Selection,
    /// Pruning threshold override (PRIOT / PRIOT-S).
    pub theta: Option<i32>,
}

impl MethodSpec {
    pub fn new(method: Method) -> Self {
        Self {
            method,
            frac_scored: 0.1,
            selection: Selection::WeightBased,
            theta: None,
        }
    }

    pub fn niti_static() -> Self {
        Self::new(Method::StaticNiti)
    }

    pub fn niti_dynamic() -> Self {
        Self::new(Method::DynamicNiti)
    }

    pub fn priot() -> Self {
        Self::new(Method::Priot)
    }

    // layering-allow: config-time fraction parameter
    pub fn priot_s(frac_scored: f64, selection: Selection) -> Self {
        Self { frac_scored, selection, ..Self::new(Method::PriotS) }
    }

    pub fn with_theta(mut self, theta: i32) -> Self {
        self.theta = Some(theta);
        self
    }

    /// The canonical form of this description: materialize the plugin
    /// and read its own description back.  Normalizes defaulted and
    /// ignored fields — an unset θ becomes the method's actual default,
    /// and PRIOT-S-only knobs collapse to their defaults for methods
    /// that ignore them — so equality on canonical specs is the right
    /// "same method?" test.  The server canonicalizes at ingress, and
    /// snapshots store canonical specs by construction, so resume and
    /// rehydrate identity checks compare like with like.
    pub fn canonical(&self) -> MethodSpec {
        self.plugin().method_spec().unwrap_or_else(|| self.clone())
    }

    /// Number of *scored* (trainable) edges this method materializes on
    /// `spec`: all of them for PRIOT, the selected subset for PRIOT-S,
    /// none for NITI (which trains weights, not scores).  With the
    /// concrete existence `masks` the count is exact; without them it is
    /// the nominal selection size — exact for
    /// [`Selection::WeightBased`] (`round(frac·n)` per layer, the same
    /// rounding [`select_mask_weight`] applies), the binomial mean for
    /// [`Selection::Random`] (whose per-edge Bernoulli draw makes the
    /// realized count seed-dependent).
    pub fn scored_params(&self, spec: &NetSpec,
                         masks: Option<&[Vec<i32>]>) -> usize {
        match self.method {
            Method::StaticNiti | Method::DynamicNiti => 0,
            Method::Priot => spec.num_params(),
            Method::PriotS => match masks {
                Some(ms) => ms
                    .iter()
                    .map(|m| m.iter().filter(|&&v| v != 0).count())
                    .sum(),
                None => spec
                    .layers
                    .iter()
                    .map(|l| {
                        crate::round_half_away(
                            // layering-allow: config-time count rounding
                            self.frac_scored * l.num_params() as f64,
                        ) as usize
                    })
                    .sum(),
            },
        }
    }

    /// Worst-case *device-side* persistent state of this method, in
    /// bytes — the accounting hook `priot_host::audit::mem` prices a
    /// registration with.  Backbone weights and the scale table are
    /// counted separately (they exist for every method); this is only
    /// what the method adds on top:
    ///
    /// * NITI (static or dynamic): **0** — weights are updated in place,
    ///   no score or mask arrays exist.
    /// * PRIOT: one int8 score per parameter (`num_params` bytes).  The
    ///   all-ones existence mask is implicit (every edge is scored) and
    ///   costs nothing to store.
    /// * PRIOT-S: 3 bytes per scored edge — an int8 score plus a u16
    ///   flat index identifying the edge (the sparse layout the RP2040
    ///   cost model in `priot_host::pico` assumes; every tinycnn layer
    ///   has < 2¹⁶ parameters).
    pub fn state_bytes(&self, spec: &NetSpec,
                       masks: Option<&[Vec<i32>]>) -> usize {
        match self.method {
            Method::StaticNiti | Method::DynamicNiti => 0,
            Method::Priot => spec.num_params(),
            Method::PriotS => {
                3usize.saturating_mul(self.scored_params(spec, masks))
            }
        }
    }

    /// Materialize the described method as a live plugin.
    pub fn plugin(&self) -> Box<dyn MethodPlugin> {
        match self.method {
            Method::StaticNiti => Box::new(Niti::static_scale()),
            Method::DynamicNiti => Box::new(Niti::dynamic()),
            Method::Priot => {
                let mut p = Priot::new();
                if let Some(t) = self.theta {
                    p = p.with_theta(t);
                }
                Box::new(p)
            }
            Method::PriotS => {
                let mut p = PriotS::new(self.frac_scored, self.selection);
                if let Some(t) = self.theta {
                    p = p.with_theta(t);
                }
                Box::new(p)
            }
        }
    }
}

/// How the PJRT executor drives a method's AOT step artifact.
///
/// The set of *artifact layouts* is closed (they are lowered at build time
/// by `python/compile/aot.py`); the set of *methods* is not — an
/// engine-only method simply returns `None` from
/// [`MethodPlugin::pjrt_plan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PjrtPlan {
    /// `<model>_niti_step`: inputs `(img, onehot, step, weights…)`,
    /// outputs `(weights…, logits, overflow)`.
    NitiStep,
    /// `<model>_priot_step`: inputs `(img, onehot, θ, weights…, scores…,
    /// masks…)`, outputs `(scores…, logits, overflow)`.
    ScoreStep,
}

/// A training method: init/step/predict/checkpoint hooks over the engine.
///
/// Implementations must be `Send` so a host-side `Fleet` can run sessions
/// across worker threads.
pub trait MethodPlugin: Send {
    /// Method label for logs and artifact names.
    fn name(&self) -> &'static str;

    /// Initialize mutable state against the backbone.  `seed` drives the
    /// shared xorshift stream (score init, random mask selection).
    fn init(&mut self, spec: &NetSpec, weights: &[crate::tensor::Mat],
            seed: u32) -> Result<()>;

    /// One training step on the pure-Rust engine.
    fn train_step(&mut self, engine: &mut Engine, img: &[i32], label: usize,
                  step: u32) -> StepOut;

    /// Inference on the pure-Rust engine.
    fn predict(&mut self, engine: &mut Engine, img: &[i32]) -> usize;

    /// Batched inference on the pure-Rust engine (one sample per row of
    /// `imgs`).  Default: the per-sample loop; the built-in plugins
    /// override with [`Engine::predict_batch`], which is bit-identical.
    fn predict_batch(&mut self, engine: &mut Engine,
                     imgs: &crate::tensor::Mat) -> Vec<usize> {
        let mut out = Vec::with_capacity(imgs.rows);
        for bi in 0..imgs.rows {
            out.push(self.predict(engine, imgs.row(bi)));
        }
        out
    }

    /// Chunked training: batch the *forward* passes over one sample per
    /// row of `imgs` while keeping every update a sequential batch-1 step
    /// (the paper's device protocol).  Returns `Some(consumed)` — how
    /// many samples (≥ 1) were trained, appending one [`StepOut`] per
    /// consumed sample to `outs` — or `None` when the method has no
    /// chunked path and the caller should loop [`Self::train_step`]
    /// instead.  Implementations must be bit-identical to the sequential
    /// loop; a method that cannot guarantee that (e.g. NITI, whose weight
    /// updates change the very next forward) must leave this as `None`.
    fn train_chunk(&mut self, engine: &mut Engine, imgs: &crate::tensor::Mat,
                   labels: &[usize], step0: u32, outs: &mut Vec<StepOut>)
                   -> Option<usize> {
        let _ = (engine, imgs, labels, step0, outs);
        None
    }

    /// Current scores, if the method has them.
    fn scores(&self) -> Option<&[Vec<i32>]> {
        None
    }

    /// Mutable scores (the PJRT executor writes step outputs back here).
    fn scores_mut(&mut self) -> Option<&mut [Vec<i32>]> {
        None
    }

    /// Existence masks, if any.
    fn masks(&self) -> Option<&[Vec<i32>]> {
        None
    }

    /// Mutable existence masks (exact-state rehydration writes restored
    /// masks back here — see the host crate's `Session::rehydrate`).
    fn masks_mut(&mut self) -> Option<&mut [Vec<i32>]> {
        None
    }

    /// Pruning threshold θ, if the method prunes.
    fn theta(&self) -> Option<i32> {
        None
    }

    /// The serializable [`MethodSpec`] describing this plugin, when its
    /// configuration is expressible as one — what a durable snapshot
    /// stores so the plugin can be rebuilt bit-identically on
    /// rehydration.  `None` means the configuration has no wire
    /// description (e.g. ablation-only knobs); sessions over such a
    /// plugin refuse to snapshot rather than silently dropping state.
    fn method_spec(&self) -> Option<MethodSpec> {
        None
    }

    /// Plugin-owned checkpoint tensors (e.g. scores+masks), or `None` when
    /// the trained state lives in the executor's weights (NITI) — the
    /// executor then checkpoints those instead.
    fn checkpoint_state(&self) -> Option<Vec<TensorI8>> {
        None
    }

    /// Restore plugin-owned state from checkpoint tensors.  `Ok(false)`
    /// means this plugin has no state of its own and the executor should
    /// restore its weights from the tensors instead.
    fn restore_state(&mut self, tensors: &[TensorI8]) -> Result<bool> {
        let _ = tensors;
        Ok(false)
    }

    /// PJRT execution plan; `None` = engine-only method.
    fn pjrt_plan(&self) -> Option<PjrtPlan> {
        None
    }
}

/// Weight-state checkpoint tensors (the fallback when a plugin has no
/// state of its own, e.g. NITI): the executor's trained weights, narrowed
/// with saturation.  Shared by the engine and PJRT executors so the
/// on-disk format cannot drift between them.
pub fn weight_checkpoint_tensors<'a, I>(spec: &NetSpec, weights: I)
                                        -> Vec<TensorI8>
where
    I: Iterator<Item = &'a [i32]>,
{
    spec.layers
        .iter()
        .zip(weights)
        .map(|(l, w)| {
            let (r, c) = l.weight_shape();
            TensorI8::from_i32_saturating(vec![r, c], w)
        })
        .collect()
}

/// Restore a weight-state checkpoint into the executor's weights (the
/// counterpart of [`weight_checkpoint_tensors`]); validates tensor count
/// and per-layer sizes.
pub fn restore_weight_tensors<'a, I>(spec: &NetSpec, tensors: &[TensorI8],
                                     weights: I) -> Result<()>
where
    I: Iterator<Item = &'a mut Vec<i32>>,
{
    let n = spec.layers.len();
    if tensors.len() != n {
        bail!("checkpoint has {} tensors, want {n}", tensors.len());
    }
    for (li, (w, t)) in weights.zip(tensors.iter()).enumerate() {
        let t32 = t.to_i32();
        if t32.len() != w.len() {
            bail!("checkpoint layer {li} size mismatch");
        }
        w.copy_from_slice(&t32);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// NITI
// ---------------------------------------------------------------------------

/// NITI baseline: direct integer weight updates (stochastically rounded),
/// with either the deployed static scale table or per-step dynamic shifts.
pub struct Niti {
    dynamic: bool,
}

impl Niti {
    /// Static-scale NITI (the paper's collapsing baseline).
    pub fn static_scale() -> Self {
        Self { dynamic: false }
    }

    /// Dynamic-scale NITI (the reference; no AOT artifact — its shifts are
    /// data-dependent).
    pub fn dynamic() -> Self {
        Self { dynamic: true }
    }
}

impl MethodPlugin for Niti {
    fn name(&self) -> &'static str {
        if self.dynamic {
            "dynamic-niti"
        } else {
            "static-niti"
        }
    }

    fn init(&mut self, _spec: &NetSpec, _weights: &[crate::tensor::Mat],
            _seed: u32) -> Result<()> {
        Ok(()) // NITI's mutable state is the executor's weights
    }

    fn train_step(&mut self, engine: &mut Engine, img: &[i32], label: usize,
                  step: u32) -> StepOut {
        engine.step_niti(img, label, self.dynamic, step)
    }

    fn predict(&mut self, engine: &mut Engine, img: &[i32]) -> usize {
        engine.predict(img, None)
    }

    fn predict_batch(&mut self, engine: &mut Engine,
                     imgs: &crate::tensor::Mat) -> Vec<usize> {
        engine.predict_batch(imgs, None)
    }

    fn pjrt_plan(&self) -> Option<PjrtPlan> {
        // dynamic-niti has no AOT artifact (data-dependent scales)
        (!self.dynamic).then_some(PjrtPlan::NitiStep)
    }

    fn method_spec(&self) -> Option<MethodSpec> {
        Some(if self.dynamic {
            MethodSpec::niti_dynamic()
        } else {
            MethodSpec::niti_static()
        })
    }
}

// ---------------------------------------------------------------------------
// Shared score state (PRIOT / PRIOT-S)
// ---------------------------------------------------------------------------

/// Scores + existence masks + θ, plus the per-layer shapes needed to
/// checkpoint them.  Shared by the dense and sparse score methods.
#[derive(Default)]
struct ScoreState {
    scores: Vec<Vec<i32>>,
    masks: Vec<Vec<i32>>,
    shapes: Vec<(usize, usize)>,
}

impl ScoreState {
    fn checkpoint(&self) -> Vec<TensorI8> {
        self.scores
            .iter()
            .chain(self.masks.iter())
            .zip(self.shapes.iter().chain(self.shapes.iter()))
            .map(|(v, &(r, c))| TensorI8::from_i32_saturating(vec![r, c], v))
            .collect()
    }

    /// Restore scores+masks saved by [`Self::checkpoint`].
    fn restore(&mut self, tensors: &[TensorI8]) -> Result<()> {
        let n = self.scores.len();
        if tensors.len() != 2 * n {
            bail!("checkpoint has {} tensors, want {} (scores+masks)",
                  tensors.len(), 2 * n);
        }
        for (li, s) in self.scores.iter_mut().enumerate() {
            let t = tensors[li].to_i32();
            if t.len() != s.len() {
                bail!("checkpoint layer {li} size mismatch");
            }
            s.copy_from_slice(&t);
        }
        for (li, m) in self.masks.iter_mut().enumerate() {
            let t = tensors[n + li].to_i32();
            if t.len() != m.len() {
                bail!("checkpoint mask {li} size mismatch");
            }
            m.copy_from_slice(&t);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// PRIOT
// ---------------------------------------------------------------------------

/// PRIOT: weights frozen, a dense int8 score per edge, edges whose score
/// falls below θ are pruned from the forward pass (paper §III-A).
pub struct Priot {
    theta: i32,
    sr: bool,
    st: ScoreState,
}

impl Priot {
    /// PRIOT with the paper's default θ = −64.
    pub fn new() -> Self {
        Self { theta: -64, sr: false, st: ScoreState::default() }
    }

    pub fn with_theta(mut self, theta: i32) -> Self {
        self.theta = theta;
        self
    }

    /// NITI-style stochastic rounding on the score step (ablation knob;
    /// deterministic rounding is the paper's default).
    pub fn stochastic_rounding(mut self, sr: bool) -> Self {
        self.sr = sr;
        self
    }
}

impl Default for Priot {
    fn default() -> Self {
        Self::new()
    }
}

impl MethodPlugin for Priot {
    fn name(&self) -> &'static str {
        "priot"
    }

    fn init(&mut self, spec: &NetSpec, _weights: &[crate::tensor::Mat],
            seed: u32) -> Result<()> {
        let mut rng = XorShift32::new(seed);
        self.st.scores = spec
            .layers
            .iter()
            .map(|l| widen(init_scores(&mut rng, l.num_params())))
            .collect();
        self.st.masks =
            spec.layers.iter().map(|l| vec![1i32; l.num_params()]).collect();
        self.st.shapes = spec.layers.iter().map(|l| l.weight_shape()).collect();
        Ok(())
    }

    fn train_step(&mut self, engine: &mut Engine, img: &[i32], label: usize,
                  step: u32) -> StepOut {
        engine.step_priot(img, label, &mut self.st.scores, &self.st.masks,
                          self.theta, step, self.sr, false)
    }

    fn train_chunk(&mut self, engine: &mut Engine, imgs: &crate::tensor::Mat,
                   labels: &[usize], step0: u32, outs: &mut Vec<StepOut>)
                   -> Option<usize> {
        Some(engine.step_priot_chunk(imgs, labels, &mut self.st.scores,
                                     &self.st.masks, self.theta, step0,
                                     self.sr, false, outs))
    }

    fn predict(&mut self, engine: &mut Engine, img: &[i32]) -> usize {
        let prune = PruneState {
            scores: &self.st.scores,
            masks: &self.st.masks,
            theta: self.theta,
        };
        engine.predict(img, Some(&prune))
    }

    fn predict_batch(&mut self, engine: &mut Engine,
                     imgs: &crate::tensor::Mat) -> Vec<usize> {
        let prune = PruneState {
            scores: &self.st.scores,
            masks: &self.st.masks,
            theta: self.theta,
        };
        engine.predict_batch(imgs, Some(&prune))
    }

    fn scores(&self) -> Option<&[Vec<i32>]> {
        Some(&self.st.scores)
    }

    fn scores_mut(&mut self) -> Option<&mut [Vec<i32>]> {
        Some(&mut self.st.scores)
    }

    fn masks(&self) -> Option<&[Vec<i32>]> {
        Some(&self.st.masks)
    }

    fn masks_mut(&mut self) -> Option<&mut [Vec<i32>]> {
        Some(&mut self.st.masks)
    }

    fn theta(&self) -> Option<i32> {
        Some(self.theta)
    }

    fn checkpoint_state(&self) -> Option<Vec<TensorI8>> {
        Some(self.st.checkpoint())
    }

    fn restore_state(&mut self, tensors: &[TensorI8]) -> Result<bool> {
        self.st.restore(tensors)?;
        Ok(true)
    }

    fn pjrt_plan(&self) -> Option<PjrtPlan> {
        Some(PjrtPlan::ScoreStep)
    }

    fn method_spec(&self) -> Option<MethodSpec> {
        // The stochastic-rounding ablation knob has no wire description;
        // a session over it cannot be snapshotted.
        (!self.sr).then(|| MethodSpec::priot().with_theta(self.theta))
    }
}

// ---------------------------------------------------------------------------
// PRIOT-S
// ---------------------------------------------------------------------------

/// PRIOT-S: only a fraction of edges carry scores (paper §III-B), chosen
/// randomly or by weight magnitude; the backward pass computes gradients
/// for scored edges only (the Table II speed win).
pub struct PriotS {
    theta: i32,
    frac_scored: f64, // layering-allow: config-time fraction, read at init only
    selection: Selection,
    st: ScoreState,
}

impl PriotS {
    /// `frac_scored` is the fraction of edges *with* scores (1 − p); θ
    /// defaults to the paper's PRIOT-S value of 0.
    // layering-allow: config-time fraction parameter
    pub fn new(frac_scored: f64, selection: Selection) -> Self {
        Self { theta: 0, frac_scored, selection, st: ScoreState::default() }
    }

    pub fn with_theta(mut self, theta: i32) -> Self {
        self.theta = theta;
        self
    }
}

impl MethodPlugin for PriotS {
    fn name(&self) -> &'static str {
        "priot-s"
    }

    fn init(&mut self, spec: &NetSpec, weights: &[crate::tensor::Mat],
            seed: u32) -> Result<()> {
        if !(0.0..=1.0).contains(&self.frac_scored) {
            bail!("frac_scored must be in [0,1], got {}", self.frac_scored);
        }
        // Stream order (scores for all layers, then masks) is part of the
        // bit-exactness contract with the Python oracle — do not reorder.
        let mut rng = XorShift32::new(seed);
        self.st.scores = spec
            .layers
            .iter()
            .map(|l| widen(init_scores(&mut rng, l.num_params())))
            .collect();
        self.st.masks = match self.selection {
            Selection::Random => spec
                .layers
                .iter()
                .map(|l| {
                    select_mask_random(&mut rng, l.num_params(),
                                       self.frac_scored)
                        .into_iter()
                        .map(i32::from)
                        .collect()
                })
                .collect(),
            Selection::WeightBased => {
                select_mask_weight(weights, self.frac_scored)
            }
        };
        self.st.shapes = spec.layers.iter().map(|l| l.weight_shape()).collect();
        Ok(())
    }

    fn train_step(&mut self, engine: &mut Engine, img: &[i32], label: usize,
                  step: u32) -> StepOut {
        engine.step_priot(img, label, &mut self.st.scores, &self.st.masks,
                          self.theta, step, false, true)
    }

    fn train_chunk(&mut self, engine: &mut Engine, imgs: &crate::tensor::Mat,
                   labels: &[usize], step0: u32, outs: &mut Vec<StepOut>)
                   -> Option<usize> {
        Some(engine.step_priot_chunk(imgs, labels, &mut self.st.scores,
                                     &self.st.masks, self.theta, step0,
                                     false, true, outs))
    }

    fn predict(&mut self, engine: &mut Engine, img: &[i32]) -> usize {
        let prune = PruneState {
            scores: &self.st.scores,
            masks: &self.st.masks,
            theta: self.theta,
        };
        engine.predict(img, Some(&prune))
    }

    fn predict_batch(&mut self, engine: &mut Engine,
                     imgs: &crate::tensor::Mat) -> Vec<usize> {
        let prune = PruneState {
            scores: &self.st.scores,
            masks: &self.st.masks,
            theta: self.theta,
        };
        engine.predict_batch(imgs, Some(&prune))
    }

    fn scores(&self) -> Option<&[Vec<i32>]> {
        Some(&self.st.scores)
    }

    fn scores_mut(&mut self) -> Option<&mut [Vec<i32>]> {
        Some(&mut self.st.scores)
    }

    fn masks(&self) -> Option<&[Vec<i32>]> {
        Some(&self.st.masks)
    }

    fn masks_mut(&mut self) -> Option<&mut [Vec<i32>]> {
        Some(&mut self.st.masks)
    }

    fn theta(&self) -> Option<i32> {
        Some(self.theta)
    }

    fn checkpoint_state(&self) -> Option<Vec<TensorI8>> {
        Some(self.st.checkpoint())
    }

    fn restore_state(&mut self, tensors: &[TensorI8]) -> Result<bool> {
        self.st.restore(tensors)?;
        Ok(true)
    }

    fn pjrt_plan(&self) -> Option<PjrtPlan> {
        Some(PjrtPlan::ScoreStep)
    }

    fn method_spec(&self) -> Option<MethodSpec> {
        Some(
            MethodSpec::priot_s(self.frac_scored, self.selection)
                .with_theta(self.theta),
        )
    }
}

fn widen(v: Vec<i8>) -> Vec<i32> {
    v.into_iter().map(|x| x as i32).collect()
}

/// PRIOT-S weight-based selection: score the largest-|W| edges per layer.
/// Deterministic, stable ordering by (-|w|, flat index) — bit-compatible
/// with `intnet.select_mask_weight`.
// layering-allow: init-time selection (exact rounding, bit-compatible)
pub fn select_mask_weight(weights: &[crate::tensor::Mat], frac_scored: f64)
                          -> Vec<Vec<i32>> {
    weights
        .iter()
        .map(|w| {
            let n = w.data.len();
            // layering-allow: init-time count rounding (exact, < 2^52)
            let k = crate::round_half_away(frac_scored * n as f64) as usize;
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&i| (-(w.data[i].abs() as i64), i));
            let mut m = vec![0i32; n];
            for &i in order.iter().take(k) {
                m[i] = 1;
            }
            m
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::XorShift64;
    use crate::quant::Scales;
    use crate::tensor::Mat;

    fn test_engine(seed: u64) -> (NetSpec, Engine) {
        let spec = NetSpec::tinycnn();
        let mut rng = XorShift64::new(seed);
        let weights: Vec<Mat> = spec
            .layers
            .iter()
            .map(|l| {
                let (r, c) = l.weight_shape();
                Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
            })
            .collect();
        let e = Engine::new(spec.clone(), weights,
                            Scales::default_for(spec.layers.len())).unwrap();
        (spec, e)
    }

    #[test]
    fn weight_based_selection_picks_largest() {
        let w = Mat::from_vec(2, 3, vec![5, -100, 3, 50, -2, 1]);
        let m = select_mask_weight(&[w], 0.5);
        // 3 of 6 edges: |100|, |50|, |5| → indices 1, 3, 0
        assert_eq!(m[0], vec![1, 1, 0, 1, 0, 0]);
    }

    #[test]
    fn weight_based_selection_tie_break_by_index() {
        let w = Mat::from_vec(1, 4, vec![7, -7, 7, 7]);
        let m = select_mask_weight(&[w], 0.5);
        assert_eq!(m[0], vec![1, 1, 0, 0], "ties resolve to earliest index");
    }

    #[test]
    fn priot_s_rejects_bad_frac() {
        let (spec, e) = test_engine(31);
        let mut p = PriotS::new(1.5, Selection::Random);
        assert!(p.init(&spec, &e.weights, 1).is_err());
    }

    #[test]
    fn method_and_selection_parse_roundtrip() {
        for m in [Method::StaticNiti, Method::DynamicNiti, Method::Priot,
                  Method::PriotS] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
        for s in [Selection::Random, Selection::WeightBased] {
            assert_eq!(Selection::parse(s.name()).unwrap(), s);
        }
        assert_eq!(Selection::parse("weight").unwrap(), Selection::WeightBased);
        assert!(Selection::parse("nope").is_err());
    }

    #[test]
    fn method_spec_canonical_fills_theta_defaults() {
        assert_eq!(MethodSpec::priot().canonical().theta, Some(-64));
        assert_eq!(
            MethodSpec::priot_s(0.2, Selection::Random).canonical().theta,
            Some(0)
        );
        // Methods that ignore the PRIOT-S knobs collapse them to defaults.
        let mut odd = MethodSpec::niti_static();
        odd.frac_scored = 0.7;
        odd.selection = Selection::Random;
        assert_eq!(odd.canonical(), MethodSpec::niti_static());
    }

    #[test]
    fn seeds_give_different_scores_same_seed_same_scores() {
        let (spec, e) = test_engine(32);
        let scores_for = |seed: u32| -> Vec<i32> {
            let mut p = Priot::new();
            p.init(&spec, &e.weights, seed).unwrap();
            p.scores().unwrap()[0].clone()
        };
        assert_eq!(scores_for(7), scores_for(7));
        assert_ne!(scores_for(7), scores_for(8));
    }

    #[test]
    fn plugin_step_advances_scores() {
        let (spec, mut e) = test_engine(33);
        let mut p = Priot::new();
        p.init(&spec, &e.weights, 1).unwrap();
        let img = vec![1i32; spec.input_len()];
        p.train_step(&mut e, &img, 3, 0);
        p.train_step(&mut e, &img, 4, 1);
        assert!(p.scores().is_some());
        assert_eq!(p.theta(), Some(-64));
    }

    #[test]
    fn checkpoint_saturates_out_of_range_scores() {
        // Regression for the silent i32→i8 wrap: a score of 300 must
        // checkpoint as 127, not 44.
        let (spec, e) = test_engine(34);
        let mut p = Priot::new();
        p.init(&spec, &e.weights, 1).unwrap();
        p.scores_mut().unwrap()[0][0] = 300;
        p.scores_mut().unwrap()[0][1] = -300;
        let tensors = p.checkpoint_state().unwrap();
        assert_eq!(tensors[0].data[0], 127, "positive overflow saturates");
        assert_eq!(tensors[0].data[1], -128, "negative overflow saturates");
    }

    #[test]
    fn checkpoint_restore_roundtrip_at_plugin_level() {
        let (spec, e) = test_engine(35);
        let mut a = PriotS::new(0.2, Selection::WeightBased);
        a.init(&spec, &e.weights, 5).unwrap();
        let tensors = a.checkpoint_state().unwrap();
        let mut b = PriotS::new(0.2, Selection::WeightBased);
        b.init(&spec, &e.weights, 99).unwrap(); // different stream
        assert!(b.restore_state(&tensors).unwrap());
        assert_eq!(a.scores(), b.scores());
        assert_eq!(a.masks(), b.masks(), "masks restore bit-identically");
    }

    #[test]
    fn niti_has_no_plugin_state() {
        let mut n = Niti::static_scale();
        assert!(n.checkpoint_state().is_none());
        assert!(!n.restore_state(&[]).unwrap());
        assert_eq!(Niti::dynamic().pjrt_plan(), None);
        assert_eq!(n.pjrt_plan(), Some(PjrtPlan::NitiStep));
    }
}
