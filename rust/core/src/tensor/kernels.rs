//! `tensor::kernels` — the dispatchable GEMM kernel set behind the engine.
//!
//! The seed shipped three free functions (`gemm_nn`/`gemm_tn`/`gemm_nt`,
//! still available as deprecated wrappers in [`super::gemm`]).  This module
//! replaces them with a [`Kernels`] object selected **once** per engine:
//!
//! * [`Kernels::scalar`] — the seed's reference loops, unchanged.  The
//!   mandatory fallback: `no_std`, allocation-free, and the bit-exactness
//!   oracle every other variant is tested against.
//! * [`Kernels::tiled`] — cache-tiled, register-blocked microkernels: A is
//!   packed into `MR`-row panels, B into `NR`-column panels (both
//!   contiguous, zero-padded at the tails), and an unrolled `MR`×`NR`
//!   i8×i8→i32 microkernel runs over full-depth panels.  The packing
//!   buffers live in a [`GemmScratch`] owned by the `Kernels` value, so an
//!   engine that calls [`Kernels::reserve`] up front performs **zero**
//!   kernel-side allocations in steady state (the `LayerBufs`/`BatchBufs`
//!   discipline, extended to the kernels; `engine::plan::BufferPlan` prices
//!   these buffers via [`packed_a_len`]/[`packed_b_len`]).
//!
//! Both variants keep the seed's GEMV fast paths for `n == 1` (every FC
//! layer at batch 1), where packing would only add traffic.
//!
//! ## Bit-identity
//!
//! The tiled kernels are **bit-identical** to the scalar ones — asserted by
//! the differential tests below and by `rust/cli/tests/properties.rs` —
//! for two stacked reasons:
//!
//! 1. They accumulate each output element over the depth index in the same
//!    ascending order as the scalar loops (tiling reorders *which outputs*
//!    are touched when, never the per-output summation order), and padded
//!    lanes contribute exact zeros.  i32 addition (wrapping or not) along
//!    the same sequence of operands is deterministic, so equality holds
//!    unconditionally.
//! 2. Independently, `priot::audit` statically proves every engine-shaped
//!    accumulator stays inside i32, so even a *reordered* summation would
//!    agree there.  We keep (1) anyway: the kernels are correct for any
//!    caller, not just audited engine shapes.
//!
//! ## Arithmetic lint wall
//!
//! Like `tensor::gemm`, implicit arithmetic is denied
//! (`clippy::arithmetic_side_effects`); the packers and the microkernel
//! carry scoped `#[allow]`s because their index arithmetic is pinned by
//! the shape asserts at each entry point and their i32 MAC accumulation is
//! the audited contract (see the module docs of [`super::gemm`]).

#![deny(clippy::arithmetic_side_effects)]

use alloc::vec::Vec;

use super::gemm::{scalar_nn, scalar_nt, scalar_tn};
use super::Mat;

/// Microkernel register-block height: rows of A per packed panel.
pub const MR: usize = 4;
/// Microkernel register-block width: columns of B per packed panel.
pub const NR: usize = 8;

/// Which kernel implementation a [`Kernels`] value dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// The seed's reference loops (`super::gemm`): allocation-free, the
    /// bit-exactness oracle.
    Scalar,
    /// Packed-panel tiled microkernels reusing a [`GemmScratch`].
    Tiled,
}

/// Deterministic per-kernel perf counters (the `obs` feature): GEMM call
/// counts per dispatch entry point, GEMV fast-path hits, total i8×i8→i32
/// MACs implied by the shapes, and the scratch high-water mark in bytes.
///
/// All plain `u64` — no atomics, no clocks, no floats — so counting is
/// exactly as deterministic as the kernels themselves and the `no_std`
/// build is unaffected.  Saturating arithmetic throughout: a counter can
/// pin at `u64::MAX`, never wrap or panic.
#[cfg(feature = "obs")]
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelCounters {
    /// `gemm_nn` dispatches.
    pub nn_calls: u64,
    /// `gemm_tn` dispatches.
    pub tn_calls: u64,
    /// `gemm_nt` dispatches.
    pub nt_calls: u64,
    /// Calls that took the shared `n == 1` GEMV fast path.
    pub gemv_hits: u64,
    /// Total multiply-accumulates implied by the dispatched shapes
    /// (`m·k·n` per call — the quantity bench `gmacs` are derived from).
    pub macs: u64,
    /// High-water mark of live packing-scratch bytes.
    pub scratch_high_water_bytes: u64,
}

#[cfg(feature = "obs")]
impl KernelCounters {
    /// Fold another counter block into this one (fleet-level merges).
    pub fn merge(&mut self, other: &KernelCounters) {
        self.nn_calls = self.nn_calls.saturating_add(other.nn_calls);
        self.tn_calls = self.tn_calls.saturating_add(other.tn_calls);
        self.nt_calls = self.nt_calls.saturating_add(other.nt_calls);
        self.gemv_hits = self.gemv_hits.saturating_add(other.gemv_hits);
        self.macs = self.macs.saturating_add(other.macs);
        self.scratch_high_water_bytes = self
            .scratch_high_water_bytes
            .max(other.scratch_high_water_bytes);
    }

    /// Total GEMM dispatches across all three entry points.
    pub fn calls(&self) -> u64 {
        self.nn_calls
            .saturating_add(self.tn_calls)
            .saturating_add(self.nt_calls)
    }

    fn bump(&mut self, macs: u64, gemv: bool) {
        self.macs = self.macs.saturating_add(macs);
        if gemv {
            self.gemv_hits = self.gemv_hits.saturating_add(1);
        }
    }

    fn note_nn(&mut self, macs: u64, gemv: bool) {
        self.nn_calls = self.nn_calls.saturating_add(1);
        self.bump(macs, gemv);
    }

    fn note_tn(&mut self, macs: u64, gemv: bool) {
        self.tn_calls = self.tn_calls.saturating_add(1);
        self.bump(macs, gemv);
    }

    fn note_nt(&mut self, macs: u64, gemv: bool) {
        self.nt_calls = self.nt_calls.saturating_add(1);
        self.bump(macs, gemv);
    }
}

/// MACs implied by an `m`×`k` · `k`×`n` product.
#[cfg(feature = "obs")]
fn mac_count(m: usize, k: usize, n: usize) -> u64 {
    (m as u64).saturating_mul(k as u64).saturating_mul(n as u64)
}

/// Packing buffers for the tiled kernels: one panel buffer per operand,
/// grow-only, reused across every GEMM an engine issues.
#[derive(Clone, Debug, Default)]
pub struct GemmScratch {
    apack: Vec<i32>,
    bpack: Vec<i32>,
}

impl GemmScratch {
    /// Grow (never shrink) both buffers to at least the given element
    /// counts — call once with the worst-case [`packed_a_len`]/
    /// [`packed_b_len`] over the shapes to come, and steady-state packing
    /// never reallocates.
    pub fn ensure(&mut self, a_elems: usize, b_elems: usize) {
        if self.apack.len() < a_elems {
            self.apack.resize(a_elems, 0);
        }
        if self.bpack.len() < b_elems {
            self.bpack.resize(b_elems, 0);
        }
    }

    /// Total live elements (both buffers) — what `Engine::mem_probe`
    /// reports and `BufferPlan::host_scratch_bytes` must reproduce.
    // Lint wall: capacity bookkeeping, not data arithmetic.
    #[allow(clippy::arithmetic_side_effects)]
    pub fn elems(&self) -> usize {
        self.apack.len() + self.bpack.len()
    }
}

/// Packed length of the A-side panel buffer for an `m`×`depth` operand:
/// `m` rounded up to whole `MR`-row panels, each panel `depth` deep.
// Lint wall: buffer sizing over usize dims (also used by engine::plan).
#[allow(clippy::arithmetic_side_effects)]
pub fn packed_a_len(m: usize, depth: usize) -> usize {
    m.div_ceil(MR) * MR * depth
}

/// Packed length of the B-side panel buffer for a `depth`×`n` operand:
/// `n` rounded up to whole `NR`-column panels, each panel `depth` deep.
// Lint wall: buffer sizing over usize dims (also used by engine::plan).
#[allow(clippy::arithmetic_side_effects)]
pub fn packed_b_len(n: usize, depth: usize) -> usize {
    n.div_ceil(NR) * NR * depth
}

/// The kernel dispatch object: selected once (per engine / per bench
/// variant), carries its own [`GemmScratch`].
#[derive(Clone, Debug)]
pub struct Kernels {
    kind: KernelKind,
    scratch: GemmScratch,
    #[cfg(feature = "obs")]
    counters: KernelCounters,
}

impl Kernels {
    /// The seed's scalar reference kernels (no scratch ever allocated).
    pub fn scalar() -> Self {
        Self {
            kind: KernelKind::Scalar,
            scratch: GemmScratch::default(),
            #[cfg(feature = "obs")]
            counters: KernelCounters::default(),
        }
    }

    /// The tiled microkernels (scratch grows on first use per shape, or up
    /// front via [`Self::reserve`]).
    pub fn tiled() -> Self {
        Self {
            kind: KernelKind::Tiled,
            scratch: GemmScratch::default(),
            #[cfg(feature = "obs")]
            counters: KernelCounters::default(),
        }
    }

    /// Read-and-reset the perf counters accumulated since the last take.
    #[cfg(feature = "obs")]
    pub fn take_counters(&mut self) -> KernelCounters {
        core::mem::take(&mut self.counters)
    }

    /// Fold the current scratch footprint into the high-water mark.
    #[cfg(feature = "obs")]
    fn note_scratch(&mut self) {
        let bytes = (self.scratch.elems() as u64)
            .saturating_mul(core::mem::size_of::<i32>() as u64);
        if bytes > self.counters.scratch_high_water_bytes {
            self.counters.scratch_high_water_bytes = bytes;
        }
    }

    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    /// Variant name for bench labels / logs: `"scalar"` or `"tiled"`.
    pub fn variant(&self) -> &'static str {
        match self.kind {
            KernelKind::Scalar => "scalar",
            KernelKind::Tiled => "tiled",
        }
    }

    /// Live scratch elements (see [`GemmScratch::elems`]).
    pub fn scratch_elems(&self) -> usize {
        self.scratch.elems()
    }

    /// Pre-size the scratch for the worst packed operand lengths to come
    /// (no-op for [`KernelKind::Scalar`], which never packs).
    pub fn reserve(&mut self, a_elems: usize, b_elems: usize) {
        if self.kind == KernelKind::Tiled {
            self.scratch.ensure(a_elems, b_elems);
        }
    }

    /// `out = a · b` — (m,k)·(k,n) → (m,n).
    pub fn gemm_nn(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(a.cols, b.rows, "gemm_nn inner dim");
        assert_eq!(out.rows, a.rows);
        assert_eq!(out.cols, b.cols);
        #[cfg(feature = "obs")]
        self.counters
            .note_nn(mac_count(a.rows, a.cols, b.cols), b.cols == 1);
        if self.kind == KernelKind::Scalar || b.cols == 1 {
            // n == 1 is the GEMV fast path in the scalar kernel — packing
            // a single column would only add traffic.
            scalar_nn(a, b, out);
            return;
        }
        let depth = a.cols;
        pack_a_rows(a, a.rows, depth, &mut self.scratch.apack);
        pack_b_rows(b, b.cols, depth, &mut self.scratch.bpack);
        microkernel_drive(&self.scratch.apack, &self.scratch.bpack, a.rows,
                          b.cols, depth, out);
        #[cfg(feature = "obs")]
        self.note_scratch();
    }

    /// `out = aᵀ · b` — (m,k)ᵀ·(m,n) → (k,n).
    pub fn gemm_tn(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(a.rows, b.rows, "gemm_tn inner dim");
        assert_eq!(out.rows, a.cols);
        assert_eq!(out.cols, b.cols);
        #[cfg(feature = "obs")]
        self.counters
            .note_tn(mac_count(a.cols, a.rows, b.cols), b.cols == 1);
        if self.kind == KernelKind::Scalar || b.cols == 1 {
            scalar_tn(a, b, out);
            return;
        }
        let depth = a.rows;
        pack_a_cols(a, a.cols, depth, &mut self.scratch.apack);
        pack_b_rows(b, b.cols, depth, &mut self.scratch.bpack);
        microkernel_drive(&self.scratch.apack, &self.scratch.bpack, a.cols,
                          b.cols, depth, out);
        #[cfg(feature = "obs")]
        self.note_scratch();
    }

    /// `out = a · bᵀ` — (m,k)·(n,k)ᵀ → (m,n).
    pub fn gemm_nt(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(a.cols, b.cols, "gemm_nt inner dim");
        assert_eq!(out.rows, a.rows);
        assert_eq!(out.cols, b.rows);
        #[cfg(feature = "obs")]
        self.counters
            .note_nt(mac_count(a.rows, a.cols, b.rows), false);
        if self.kind == KernelKind::Scalar {
            scalar_nt(a, b, out);
            return;
        }
        let depth = a.cols;
        pack_a_rows(a, a.rows, depth, &mut self.scratch.apack);
        pack_b_cols(b, b.rows, depth, &mut self.scratch.bpack);
        microkernel_drive(&self.scratch.apack, &self.scratch.bpack, a.rows,
                          b.rows, depth, out);
        #[cfg(feature = "obs")]
        self.note_scratch();
    }
}

/// Pack the logical left operand (rows of `a` are rows of the product)
/// into `MR`-row panels, column-major within each panel:
/// `apack[panel*MR*depth + p*MR + r] = A[i0+r, p]` (0 past the tail).
// Lint wall: panel-index arithmetic pinned by the entry-point asserts;
// padding writes exact zeros so tail lanes never contribute.
#[allow(clippy::arithmetic_side_effects)]
fn pack_a_rows(a: &Mat, m: usize, depth: usize, apack: &mut Vec<i32>) {
    let need = packed_a_len(m, depth);
    if apack.len() < need {
        apack.resize(need, 0);
    }
    let mut i0 = 0usize;
    let mut base = 0usize;
    while i0 < m {
        for r in 0..MR {
            let gi = i0 + r;
            if gi < m {
                let arow = a.row(gi);
                for p in 0..depth {
                    apack[base + p * MR + r] = arow[p];
                }
            } else {
                for p in 0..depth {
                    apack[base + p * MR + r] = 0;
                }
            }
        }
        i0 += MR;
        base += MR * depth;
    }
}

/// Pack the logical left operand when it is the *transpose* of `a`
/// (`gemm_tn`: product rows are columns of `a`):
/// `apack[panel*MR*depth + p*MR + r] = A[p, i0+r]`.
// Lint wall: see `pack_a_rows`.
#[allow(clippy::arithmetic_side_effects)]
fn pack_a_cols(a: &Mat, m: usize, depth: usize, apack: &mut Vec<i32>) {
    let need = packed_a_len(m, depth);
    if apack.len() < need {
        apack.resize(need, 0);
    }
    let mut i0 = 0usize;
    let mut base = 0usize;
    while i0 < m {
        for r in 0..MR {
            let gi = i0 + r;
            for p in 0..depth {
                apack[base + p * MR + r] =
                    if gi < m { a.data[p * a.cols + gi] } else { 0 };
            }
        }
        i0 += MR;
        base += MR * depth;
    }
}

/// Pack the logical right operand (columns of `b` are columns of the
/// product) into `NR`-column panels, row-major within each panel:
/// `bpack[panel*NR*depth + p*NR + c] = B[p, j0+c]` (0 past the tail).
// Lint wall: see `pack_a_rows`.
#[allow(clippy::arithmetic_side_effects)]
fn pack_b_rows(b: &Mat, n: usize, depth: usize, bpack: &mut Vec<i32>) {
    let need = packed_b_len(n, depth);
    if bpack.len() < need {
        bpack.resize(need, 0);
    }
    let mut j0 = 0usize;
    let mut base = 0usize;
    while j0 < n {
        for p in 0..depth {
            let brow = b.row(p);
            let dst = base + p * NR;
            for c in 0..NR {
                let gj = j0 + c;
                bpack[dst + c] = if gj < n { brow[gj] } else { 0 };
            }
        }
        j0 += NR;
        base += NR * depth;
    }
}

/// Pack the logical right operand when it is the *transpose* of `b`
/// (`gemm_nt`: product columns are rows of `b`):
/// `bpack[panel*NR*depth + p*NR + c] = B[j0+c, p]`.
// Lint wall: see `pack_a_rows`.
#[allow(clippy::arithmetic_side_effects)]
fn pack_b_cols(b: &Mat, n: usize, depth: usize, bpack: &mut Vec<i32>) {
    let need = packed_b_len(n, depth);
    if bpack.len() < need {
        bpack.resize(need, 0);
    }
    let mut j0 = 0usize;
    let mut base = 0usize;
    while j0 < n {
        for c in 0..NR {
            let gj = j0 + c;
            if gj < n {
                let brow = b.row(gj);
                for p in 0..depth {
                    bpack[base + p * NR + c] = brow[p];
                }
            } else {
                for p in 0..depth {
                    bpack[base + p * NR + c] = 0;
                }
            }
        }
        j0 += NR;
        base += NR * depth;
    }
}

/// Run the `MR`×`NR` microkernel over every packed panel pair and store
/// the valid sub-tile of each accumulator block.  Per output element the
/// depth index ascends exactly as in the scalar kernels (bit-identity —
/// see the module docs); the scalar kernels' `av == 0` skip is kept, both
/// because pruned/ReLU zeros are common in this workload and because
/// skipping a `+ 0` term is arithmetic-neutral.
// Lint wall: audited i32 MAC accumulation + panel-index arithmetic whose
// bounds are pinned by the packed lengths (`packed_a_len`/`packed_b_len`).
#[allow(clippy::arithmetic_side_effects)]
fn microkernel_drive(apack: &[i32], bpack: &[i32], m: usize, n: usize,
                     depth: usize, out: &mut Mat) {
    debug_assert_eq!(out.rows, m);
    debug_assert_eq!(out.cols, n);
    let mtiles = m.div_ceil(MR);
    let ntiles = n.div_ceil(NR);
    for ti in 0..mtiles {
        let ap = &apack[ti * MR * depth..(ti + 1) * MR * depth];
        let i0 = ti * MR;
        let rtake = MR.min(m - i0);
        for tj in 0..ntiles {
            let bp = &bpack[tj * NR * depth..(tj + 1) * NR * depth];
            let mut acc = [[0i32; NR]; MR];
            for p in 0..depth {
                let ar = &ap[p * MR..(p + 1) * MR];
                let br = &bp[p * NR..(p + 1) * NR];
                for r in 0..MR {
                    let av = ar[r];
                    if av == 0 {
                        continue;
                    }
                    let accr = &mut acc[r];
                    for c in 0..NR {
                        accr[c] += av * br[c];
                    }
                }
            }
            let j0 = tj * NR;
            let ctake = NR.min(n - j0);
            for r in 0..rtake {
                let o0 = (i0 + r) * n + j0;
                out.data[o0..o0 + ctake].copy_from_slice(&acc[r][..ctake]);
            }
        }
    }
}

// Lint wall: test oracles and shape bookkeeping compute freely.
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::XorShift64;

    fn rand_mat(rng: &mut XorShift64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
    }

    /// Naive i64 oracle for `a · b`.
    fn naive_nn(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0i64;
                for p in 0..a.cols {
                    acc += a.at(i, p) as i64 * b.at(p, j) as i64;
                }
                *out.at_mut(i, j) = acc as i32;
            }
        }
        out
    }

    fn transpose(a: &Mat) -> Mat {
        let mut t = Mat::zeros(a.cols, a.rows);
        for i in 0..a.rows {
            for j in 0..a.cols {
                *t.at_mut(j, i) = a.at(i, j);
            }
        }
        t
    }

    /// Adversarial shape set: 1 (pad-free GEMV edge), primes, and exact /
    /// ±1 multiples of both tile sizes (MR=4, NR=8).
    const DIMS: &[usize] = &[1, 3, 4, 5, 7, 8, 9, 16, 17];

    #[test]
    fn tiled_matches_oracle_and_scalar_on_adversarial_shapes() {
        // Differential fuzz: every (m, k, n) in DIMS³, all three variants,
        // tiled vs the naive i64 oracle *and* bit-vs the seed scalar
        // kernels (fresh scratch each op — growth path covered too).
        let mut rng = XorShift64::new(91);
        for &m in DIMS {
            for &k in DIMS {
                for &n in DIMS {
                    let mut tiled = Kernels::tiled();
                    let mut scalar = Kernels::scalar();

                    let a = rand_mat(&mut rng, m, k);
                    let b = rand_mat(&mut rng, k, n);
                    let want = naive_nn(&a, &b);
                    let mut got_t = Mat::zeros(m, n);
                    let mut got_s = Mat::zeros(m, n);
                    tiled.gemm_nn(&a, &b, &mut got_t);
                    scalar.gemm_nn(&a, &b, &mut got_s);
                    assert_eq!(got_t, want, "nn m={m} k={k} n={n}");
                    assert_eq!(got_t, got_s, "nn vs scalar m={m} k={k} n={n}");

                    // tn: out = aᵀ·b with a (m,k) interpreted over inner m.
                    let bt = rand_mat(&mut rng, m, n);
                    let want = naive_nn(&transpose(&a), &bt);
                    let mut got_t = Mat::zeros(k, n);
                    let mut got_s = Mat::zeros(k, n);
                    tiled.gemm_tn(&a, &bt, &mut got_t);
                    scalar.gemm_tn(&a, &bt, &mut got_s);
                    assert_eq!(got_t, want, "tn m={m} k={k} n={n}");
                    assert_eq!(got_t, got_s, "tn vs scalar m={m} k={k} n={n}");

                    // nt: out = a·bᵀ with b (n,k).
                    let bn = rand_mat(&mut rng, n, k);
                    let want = naive_nn(&a, &transpose(&bn));
                    let mut got_t = Mat::zeros(m, n);
                    let mut got_s = Mat::zeros(m, n);
                    tiled.gemm_nt(&a, &bn, &mut got_t);
                    scalar.gemm_nt(&a, &bn, &mut got_s);
                    assert_eq!(got_t, want, "nt m={m} k={k} n={n}");
                    assert_eq!(got_t, got_s, "nt vs scalar m={m} k={k} n={n}");
                }
            }
        }
    }

    #[test]
    fn tiled_bit_identical_to_scalar_on_random_shapes() {
        // The satellite property: random int8 matrices, random shapes,
        // one long-lived tiled Kernels (scratch reused across shapes).
        let mut rng = XorShift64::new(92);
        let mut tiled = Kernels::tiled();
        let mut scalar = Kernels::scalar();
        for _ in 0..60 {
            let m = rng.int_in(1, 40) as usize;
            let k = rng.int_in(1, 40) as usize;
            let n = rng.int_in(1, 40) as usize;
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let mut got_t = Mat::zeros(m, n);
            let mut got_s = Mat::zeros(m, n);
            tiled.gemm_nn(&a, &b, &mut got_t);
            scalar.gemm_nn(&a, &b, &mut got_s);
            assert_eq!(got_t, got_s, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn scratch_reuse_across_shrinking_and_growing_shapes() {
        // Stale tail data from a larger earlier op must never leak into a
        // smaller later one (packers rewrite every needed element).
        let mut rng = XorShift64::new(93);
        let mut tiled = Kernels::tiled();
        for &(m, k, n) in &[(33usize, 17usize, 9usize), (3, 4, 5), (16, 8, 24),
                            (2, 2, 2), (33, 17, 9)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let mut got = Mat::zeros(m, n);
            tiled.gemm_nn(&a, &b, &mut got);
            assert_eq!(got, naive_nn(&a, &b), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn reserve_makes_steady_state_allocation_free() {
        let mut tiled = Kernels::tiled();
        let (m, k, n) = (16usize, 72usize, 196usize);
        tiled.reserve(packed_a_len(m, k), packed_b_len(n, k));
        let reserved = tiled.scratch_elems();
        assert_eq!(reserved, packed_a_len(m, k) + packed_b_len(n, k));
        let mut rng = XorShift64::new(94);
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let mut out = Mat::zeros(m, n);
        for _ in 0..3 {
            tiled.gemm_nn(&a, &b, &mut out);
            assert_eq!(tiled.scratch_elems(), reserved,
                       "steady-state GEMM must not grow the scratch");
        }
        // The scalar variant never allocates scratch at all.
        let mut scalar = Kernels::scalar();
        scalar.reserve(1024, 1024);
        scalar.gemm_nn(&a, &b, &mut out);
        assert_eq!(scalar.scratch_elems(), 0);
    }

    #[test]
    fn gemv_fast_path_is_shared() {
        // n == 1 dispatches to the scalar GEMV in both variants.
        let mut rng = XorShift64::new(95);
        let a = rand_mat(&mut rng, 64, 784);
        let b = rand_mat(&mut rng, 784, 1);
        let mut got_t = Mat::zeros(64, 1);
        let mut got_s = Mat::zeros(64, 1);
        let mut tiled = Kernels::tiled();
        tiled.gemm_nn(&a, &b, &mut got_t);
        Kernels::scalar().gemm_nn(&a, &b, &mut got_s);
        assert_eq!(got_t, got_s);
        assert_eq!(tiled.scratch_elems(), 0, "GEMV must not touch scratch");
    }

    #[cfg(feature = "obs")]
    #[test]
    fn counters_track_calls_macs_and_scratch() {
        let mut rng = XorShift64::new(96);
        let mut tiled = Kernels::tiled();
        let (m, k, n) = (5usize, 7usize, 9usize);
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let mut out = Mat::zeros(m, n);
        tiled.gemm_nn(&a, &b, &mut out);
        let gemv = rand_mat(&mut rng, k, 1);
        let mut outv = Mat::zeros(m, 1);
        tiled.gemm_nn(&a, &gemv, &mut outv);
        let c = tiled.take_counters();
        assert_eq!(c.nn_calls, 2);
        assert_eq!(c.calls(), 2);
        assert_eq!(c.gemv_hits, 1, "the n == 1 call is a GEMV hit");
        assert_eq!(c.macs, (m * k * n + m * k) as u64);
        assert_eq!(
            c.scratch_high_water_bytes,
            ((packed_a_len(m, k) + packed_b_len(n, k)) * 4) as u64,
            "high-water = packed panels of the tiled call (GEMV packs none)"
        );
        // take_counters resets.
        assert_eq!(tiled.take_counters(), KernelCounters::default());

        // merge accumulates counts and maxes the high-water mark.
        let mut acc = KernelCounters::default();
        acc.merge(&c);
        acc.merge(&c);
        assert_eq!(acc.nn_calls, 4);
        assert_eq!(acc.macs, c.macs * 2);
        assert_eq!(acc.scratch_high_water_bytes, c.scratch_high_water_bytes);
    }

    #[test]
    fn packed_lengths_round_up_to_whole_panels() {
        assert_eq!(packed_a_len(1, 10), MR * 10);
        assert_eq!(packed_a_len(4, 10), MR * 10);
        assert_eq!(packed_a_len(5, 10), 2 * MR * 10);
        assert_eq!(packed_b_len(1, 10), NR * 10);
        assert_eq!(packed_b_len(8, 10), NR * 10);
        assert_eq!(packed_b_len(9, 10), 2 * NR * 10);
    }
}
