//! The scalar reference implementations of the three integer GEMM
//! variants the training loop needs.
//!
//! * `nn`:  C = A · B        (forward / conv via im2col)
//! * `tn`:  C = Aᵀ · B       (delta-x backward: Wᵀ · δy)
//! * `nt`:  C = A · Bᵀ       (weight gradient: δy · xᵀ)
//!
//! **Entry point:** callers go through [`super::kernels::Kernels`] — the
//! dispatch object selected once per engine (scalar vs tiled) that owns
//! the tiled variant's packing scratch.  The loops in this module are the
//! `KernelKind::Scalar` implementation *and* the bit-exactness oracle the
//! tiled microkernels are tested against; the old free functions
//! ([`gemm_nn`]/[`gemm_tn`]/[`gemm_nt`]) remain as thin deprecated
//! wrappers so pre-`Kernels` call sites keep compiling.
//!
//! All variants accumulate in i32 over int8-range operands (the
//! DESIGN.md §5 contract keeps every accumulator in range).  These are
//! the hot path of the whole device engine; `priot bench --suite kernel`
//! tracks both variants per shape and `BENCH_kernel.json` records the
//! trajectory.
//!
//! `scalar_nn` is written as an ikj loop (row of B streamed per A
//! element) which vectorizes well and is cache-friendly for the small row
//! counts the models here use; `scalar_tn`/`scalar_nt` choose loop orders
//! that keep the inner loop contiguous in both operands.  All three keep
//! an `n == 1` GEMV fast path that the tiled dispatch reuses.  The tiling
//! design itself (MR×NR register blocks over packed full-depth panels,
//! identical per-output summation order) is documented in
//! [`super::kernels`].
//!
//! ## Arithmetic lint wall
//!
//! Implicit arithmetic is denied here (`clippy::arithmetic_side_effects`);
//! the three kernels carry scoped `#[allow]`s because their i32 MAC
//! accumulation *is* the audited contract — `priot::audit` statically
//! proves (per layer, per method) that every partial sum stays inside i32,
//! so plain `+=` is correct and a `wrapping_*`/`checked_*` would either
//! hide a soundness bug or tax the hottest loop in the repo.

#![deny(clippy::arithmetic_side_effects)]

use super::Mat;

/// `out = a · b` — (m,k)·(k,n) -> (m,n).  Scalar reference kernel.
// Lint wall: audited i32 MAC accumulation + slice index arithmetic whose
// bounds are pinned by the shape asserts above each loop nest.
#[allow(clippy::arithmetic_side_effects)]
pub(crate) fn scalar_nn(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows, "gemm_nn inner dim");
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.cols);
    let (k, n) = (a.cols, b.cols);
    if n == 1 {
        // Matrix-vector (every FC layer at batch 1): contiguous dot
        // products — the ikj form below would pay slice overhead per MAC.
        // §Perf: fc1 GEMV 350 µs → ~25 µs (0.14 → ~2 Gmac/s).
        for i in 0..a.rows {
            let arow = &a.data[i * k..(i + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(b.data.iter()) {
                acc += av * bv;
            }
            out.data[i] = acc;
        }
        return;
    }
    out.data.iter_mut().for_each(|v| *v = 0);
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue; // pruned edges / ReLU zeros are common — skip
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out = aᵀ · b` — (m,k)ᵀ·(m,n) -> (k,n).  Scalar reference kernel.
// Lint wall: audited MAC contract (see `scalar_nn`).
#[allow(clippy::arithmetic_side_effects)]
pub(crate) fn scalar_tn(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.rows, b.rows, "gemm_tn inner dim");
    assert_eq!(out.rows, a.cols);
    assert_eq!(out.cols, b.cols);
    let (k, n) = (a.cols, b.cols);
    out.data.iter_mut().for_each(|v| *v = 0);
    if n == 1 {
        // aᵀ·v: accumulate b[i]-scaled rows of a — contiguous in both.
        for i in 0..a.rows {
            let bv = b.data[i];
            if bv == 0 {
                continue;
            }
            let arow = &a.data[i * k..(i + 1) * k];
            for (o, &av) in out.data.iter_mut().zip(arow.iter()) {
                *o += av * bv;
            }
        }
        return;
    }
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let orow = &mut out.data[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out = a · bᵀ` — (m,k)·(n,k)ᵀ -> (m,n).  Scalar reference kernel.
// Lint wall: audited MAC contract (see `scalar_nn`).
#[allow(clippy::arithmetic_side_effects)]
pub(crate) fn scalar_nt(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.cols, "gemm_nt inner dim");
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.rows);
    let k = a.cols;
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..b.rows {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out.data[i * b.rows + j] = acc;
        }
    }
}

/// `out = a · b` — (m,k)·(k,n) -> (m,n).
#[deprecated(note = "construct a `tensor::kernels::Kernels` (scalar or \
                     tiled) and call its `gemm_nn` — the dispatch object \
                     owns the tiled variant's packing scratch")]
pub fn gemm_nn(a: &Mat, b: &Mat, out: &mut Mat) {
    scalar_nn(a, b, out);
}

/// `out = aᵀ · b` — (m,k)ᵀ·(m,n) -> (k,n).
#[deprecated(note = "construct a `tensor::kernels::Kernels` (scalar or \
                     tiled) and call its `gemm_tn` — the dispatch object \
                     owns the tiled variant's packing scratch")]
pub fn gemm_tn(a: &Mat, b: &Mat, out: &mut Mat) {
    scalar_tn(a, b, out);
}

/// `out = a · bᵀ` — (m,k)·(n,k)ᵀ -> (m,n).
#[deprecated(note = "construct a `tensor::kernels::Kernels` (scalar or \
                     tiled) and call its `gemm_nt` — the dispatch object \
                     owns the tiled variant's packing scratch")]
pub fn gemm_nt(a: &Mat, b: &Mat, out: &mut Mat) {
    scalar_nt(a, b, out);
}

// Lint wall: the naive i64 oracles compute freely.
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::XorShift64;

    fn rand_mat(rng: &mut XorShift64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
    }

    fn naive_nn(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0i64;
                for p in 0..a.cols {
                    acc += a.at(i, p) as i64 * b.at(p, j) as i64;
                }
                *out.at_mut(i, j) = acc as i32;
            }
        }
        out
    }

    #[test]
    fn gemm_nn_matches_naive() {
        let mut rng = XorShift64::new(21);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 4), (8, 72, 196), (10, 64, 1)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let mut out = Mat::zeros(m, n);
            scalar_nn(&a, &b, &mut out);
            assert_eq!(out, naive_nn(&a, &b), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_tn_is_transpose_of_nn() {
        let mut rng = XorShift64::new(22);
        for &(m, k, n) in &[(4usize, 3usize, 5usize), (10, 64, 1), (16, 72, 7)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, m, n);
            // naive: transpose a then nn
            let mut at = Mat::zeros(k, m);
            for i in 0..m {
                for p in 0..k {
                    *at.at_mut(p, i) = a.at(i, p);
                }
            }
            let want = naive_nn(&at, &b);
            let mut out = Mat::zeros(k, n);
            scalar_tn(&a, &b, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn gemm_nt_is_nn_with_transposed_b() {
        let mut rng = XorShift64::new(23);
        for &(m, k, n) in &[(5usize, 4usize, 3usize), (10, 1, 64), (16, 196, 72)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let mut bt = Mat::zeros(k, n);
            for i in 0..n {
                for p in 0..k {
                    *bt.at_mut(p, i) = b.at(i, p);
                }
            }
            let want = naive_nn(&a, &bt);
            let mut out = Mat::zeros(m, n);
            scalar_nt(&a, &b, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn gemm_bilinear_property() {
        // (a1 + a2)·b == a1·b + a2·b elementwise — catches indexing bugs
        // that preserve shapes but scramble contributions.
        let mut rng = XorShift64::new(24);
        let (m, k, n) = (4usize, 6usize, 5usize);
        for _ in 0..20 {
            let a1 = rand_mat(&mut rng, m, k);
            let a2 = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let sum = Mat::from_vec(
                m,
                k,
                a1.data.iter().zip(a2.data.iter()).map(|(&x, &y)| x + y).collect(),
            );
            let (mut o1, mut o2, mut os) =
                (Mat::zeros(m, n), Mat::zeros(m, n), Mat::zeros(m, n));
            scalar_nn(&a1, &b, &mut o1);
            scalar_nn(&a2, &b, &mut o2);
            scalar_nn(&sum, &b, &mut os);
            for i in 0..m * n {
                assert_eq!(os.data[i], o1.data[i] + o2.data[i]);
            }
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_free_functions_still_dispatch_to_scalar() {
        // The compat wrappers must stay behaviorally identical to the
        // scalar kernels for external callers that haven't migrated.
        let mut rng = XorShift64::new(25);
        let a = rand_mat(&mut rng, 6, 9);
        let b = rand_mat(&mut rng, 9, 7);
        let mut via_wrapper = Mat::zeros(6, 7);
        let mut via_scalar = Mat::zeros(6, 7);
        gemm_nn(&a, &b, &mut via_wrapper);
        scalar_nn(&a, &b, &mut via_scalar);
        assert_eq!(via_wrapper, via_scalar);

        let bt = rand_mat(&mut rng, 6, 7);
        let mut w_tn = Mat::zeros(9, 7);
        let mut s_tn = Mat::zeros(9, 7);
        gemm_tn(&a, &bt, &mut w_tn);
        scalar_tn(&a, &bt, &mut s_tn);
        assert_eq!(w_tn, s_tn);

        let bn = rand_mat(&mut rng, 7, 9);
        let mut w_nt = Mat::zeros(6, 7);
        let mut s_nt = Mat::zeros(6, 7);
        gemm_nt(&a, &bn, &mut w_nt);
        scalar_nt(&a, &bn, &mut s_nt);
        assert_eq!(w_nt, s_nt);
    }
}
