//! The three integer GEMM variants the training loop needs.
//!
//! * `gemm_nn`:  C = A · B        (forward / conv via im2col)
//! * `gemm_tn`:  C = Aᵀ · B       (delta-x backward: Wᵀ · δy)
//! * `gemm_nt`:  C = A · Bᵀ       (weight gradient: δy · xᵀ)
//!
//! All accumulate in i32 over int8-range operands (the DESIGN.md §5
//! contract keeps every accumulator in range).  These are the hot path of
//! the whole device engine; the kernel bench (`cargo bench --bench kernel`)
//! tracks them and EXPERIMENTS.md §Perf logs the optimization history.
//!
//! `gemm_nn` is written as an ikj loop (row of B streamed per A element)
//! which vectorizes well and is cache-friendly for the small row counts the
//! models here use; `gemm_tn`/`gemm_nt` choose loop orders that keep the
//! inner loop contiguous in both operands.
//!
//! ## Arithmetic lint wall
//!
//! Implicit arithmetic is denied here (`clippy::arithmetic_side_effects`);
//! the three kernels carry scoped `#[allow]`s because their i32 MAC
//! accumulation *is* the audited contract — `priot::audit` statically
//! proves (per layer, per method) that every partial sum stays inside i32,
//! so plain `+=` is correct and a `wrapping_*`/`checked_*` would either
//! hide a soundness bug or tax the hottest loop in the repo.

#![deny(clippy::arithmetic_side_effects)]

use super::Mat;

/// `out = a · b` — (m,k)·(k,n) -> (m,n).
// Lint wall: audited i32 MAC accumulation + slice index arithmetic whose
// bounds are pinned by the shape asserts above each loop nest.
#[allow(clippy::arithmetic_side_effects)]
pub fn gemm_nn(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows, "gemm_nn inner dim");
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.cols);
    let (k, n) = (a.cols, b.cols);
    if n == 1 {
        // Matrix-vector (every FC layer at batch 1): contiguous dot
        // products — the ikj form below would pay slice overhead per MAC.
        // §Perf: fc1 GEMV 350 µs → ~25 µs (0.14 → ~2 Gmac/s).
        for i in 0..a.rows {
            let arow = &a.data[i * k..(i + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(b.data.iter()) {
                acc += av * bv;
            }
            out.data[i] = acc;
        }
        return;
    }
    out.data.iter_mut().for_each(|v| *v = 0);
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue; // pruned edges / ReLU zeros are common — skip
            }
            let brow = &b.data[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out = aᵀ · b` — (m,k)ᵀ·(m,n) -> (k,n).
// Lint wall: audited MAC contract (see `gemm_nn`).
#[allow(clippy::arithmetic_side_effects)]
pub fn gemm_tn(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.rows, b.rows, "gemm_tn inner dim");
    assert_eq!(out.rows, a.cols);
    assert_eq!(out.cols, b.cols);
    let (k, n) = (a.cols, b.cols);
    out.data.iter_mut().for_each(|v| *v = 0);
    if n == 1 {
        // aᵀ·v: accumulate b[i]-scaled rows of a — contiguous in both.
        for i in 0..a.rows {
            let bv = b.data[i];
            if bv == 0 {
                continue;
            }
            let arow = &a.data[i * k..(i + 1) * k];
            for (o, &av) in out.data.iter_mut().zip(arow.iter()) {
                *o += av * bv;
            }
        }
        return;
    }
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let orow = &mut out.data[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out = a · bᵀ` — (m,k)·(n,k)ᵀ -> (m,n).
// Lint wall: audited MAC contract (see `gemm_nn`).
#[allow(clippy::arithmetic_side_effects)]
pub fn gemm_nt(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.cols, "gemm_nt inner dim");
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.rows);
    let k = a.cols;
    for i in 0..a.rows {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..b.rows {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out.data[i * b.rows + j] = acc;
        }
    }
}

// Lint wall: the naive i64 oracles compute freely.
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::XorShift64;

    fn rand_mat(rng: &mut XorShift64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
    }

    fn naive_nn(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0i64;
                for p in 0..a.cols {
                    acc += a.at(i, p) as i64 * b.at(p, j) as i64;
                }
                *out.at_mut(i, j) = acc as i32;
            }
        }
        out
    }

    #[test]
    fn gemm_nn_matches_naive() {
        let mut rng = XorShift64::new(21);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 4), (8, 72, 196), (10, 64, 1)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let mut out = Mat::zeros(m, n);
            gemm_nn(&a, &b, &mut out);
            assert_eq!(out, naive_nn(&a, &b), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_tn_is_transpose_of_nn() {
        let mut rng = XorShift64::new(22);
        for &(m, k, n) in &[(4usize, 3usize, 5usize), (10, 64, 1), (16, 72, 7)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, m, n);
            // naive: transpose a then nn
            let mut at = Mat::zeros(k, m);
            for i in 0..m {
                for p in 0..k {
                    *at.at_mut(p, i) = a.at(i, p);
                }
            }
            let want = naive_nn(&at, &b);
            let mut out = Mat::zeros(k, n);
            gemm_tn(&a, &b, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn gemm_nt_is_nn_with_transposed_b() {
        let mut rng = XorShift64::new(23);
        for &(m, k, n) in &[(5usize, 4usize, 3usize), (10, 1, 64), (16, 196, 72)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let mut bt = Mat::zeros(k, n);
            for i in 0..n {
                for p in 0..k {
                    *bt.at_mut(p, i) = b.at(i, p);
                }
            }
            let want = naive_nn(&a, &bt);
            let mut out = Mat::zeros(m, n);
            gemm_nt(&a, &b, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn gemm_bilinear_property() {
        // (a1 + a2)·b == a1·b + a2·b elementwise — catches indexing bugs
        // that preserve shapes but scramble contributions.
        let mut rng = XorShift64::new(24);
        let (m, k, n) = (4usize, 6usize, 5usize);
        for _ in 0..20 {
            let a1 = rand_mat(&mut rng, m, k);
            let a2 = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let sum = Mat::from_vec(
                m,
                k,
                a1.data.iter().zip(a2.data.iter()).map(|(&x, &y)| x + y).collect(),
            );
            let (mut o1, mut o2, mut os) =
                (Mat::zeros(m, n), Mat::zeros(m, n), Mat::zeros(m, n));
            gemm_nn(&a1, &b, &mut o1);
            gemm_nn(&a2, &b, &mut o2);
            gemm_nn(&sum, &b, &mut os);
            for i in 0..m * n {
                assert_eq!(os.data[i], o1.data[i] + o2.data[i]);
            }
        }
    }
}
