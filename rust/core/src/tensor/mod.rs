//! Integer tensor substrate: row-major matrices, the GEMM kernel set the
//! training loop needs ([`kernels::Kernels`] — scalar reference loops plus
//! tiled, scratch-reusing microkernels), and the 3×3/pad-1 conv geometry
//! helpers (im2col, col2im, 2×2 max-pool) — bit-identical to
//! `python/compile/intnet.py`.
//!
//! Values are int8-range integers carried in `i32` (accumulators are genuine
//! int32); the contract guarantees no accumulator overflows int32 for the
//! model sizes in this repo (see DESIGN.md §5).

pub mod gemm;
pub mod kernels;

// The free-function kernels predate the `Kernels` dispatch API; they stay
// re-exported (deprecated) so external `use priot::tensor::gemm_nn` paths
// keep compiling while their call sites migrate.
#[allow(deprecated)]
pub use gemm::{gemm_nn, gemm_nt, gemm_tn};
pub use kernels::{GemmScratch, KernelKind, Kernels};
#[cfg(feature = "obs")]
pub use kernels::KernelCounters;

use alloc::vec;
use alloc::vec::Vec;

/// Row-major integer matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec size mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut i32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reset all elements to zero (reusing the allocation — hot path).
    pub fn clear(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0);
    }

    /// Row `r` as a slice — the one audited place for the
    /// `data[r*cols..(r+1)*cols]` bounds arithmetic (batched datasets,
    /// packing, per-sample gathers all go through here).
    #[inline]
    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row `r` as a slice (see [`Self::row`]).
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [i32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Iterate all rows in order as slices.
    pub fn rows_iter(&self) -> impl Iterator<Item = &[i32]> {
        self.data.chunks_exact(self.cols.max(1))
    }
}

/// im2col for 3×3 / pad 1 / stride 1: `(C,H,W)` (flat, len C*H*W) into the
/// `(C*9, H*W)` patch matrix with row index `c*9 + ky*3 + kx`.
///
/// `out` must be `C*9 x H*W`; rows are written fully (no zeroing needed).
pub fn im2col(x: &[i32], c: usize, h: usize, w: usize, out: &mut Mat) {
    debug_assert_eq!(x.len(), c * h * w);
    debug_assert_eq!(out.rows, c * 9);
    debug_assert_eq!(out.cols, h * w);
    let hw = h * w;
    for ci in 0..c {
        let xc = &x[ci * hw..(ci + 1) * hw];
        for ky in 0..3 {
            for kx in 0..3 {
                let row = ci * 9 + ky * 3 + kx;
                let dst = &mut out.data[row * hw..(row + 1) * hw];
                // Source pixel for output (y, x) is (y + ky - 1, x + kx - 1).
                for y in 0..h {
                    let sy = y as isize + ky as isize - 1;
                    let drow = &mut dst[y * w..(y + 1) * w];
                    if sy < 0 || sy >= h as isize {
                        drow.iter_mut().for_each(|v| *v = 0);
                        continue;
                    }
                    let srow = &xc[(sy as usize) * w..(sy as usize + 1) * w];
                    match kx {
                        0 => {
                            drow[0] = 0;
                            drow[1..].copy_from_slice(&srow[..w - 1]);
                        }
                        1 => drow.copy_from_slice(srow),
                        _ => {
                            drow[..w - 1].copy_from_slice(&srow[1..]);
                            drow[w - 1] = 0;
                        }
                    }
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add the `(C*9, H*W)` patch matrix back to
/// `(C,H,W)` (accumulating in i32; the contract keeps sums in range).
pub fn col2im(cols: &Mat, c: usize, h: usize, w: usize, out: &mut [i32]) {
    debug_assert_eq!(cols.rows, c * 9);
    debug_assert_eq!(cols.cols, h * w);
    debug_assert_eq!(out.len(), c * h * w);
    out.iter_mut().for_each(|v| *v = 0);
    let hw = h * w;
    for ci in 0..c {
        let oc = &mut out[ci * hw..(ci + 1) * hw];
        for ky in 0..3 {
            for kx in 0..3 {
                let row = ci * 9 + ky * 3 + kx;
                let src = &cols.data[row * hw..(row + 1) * hw];
                for y in 0..h {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    let dst = &mut oc[(sy as usize) * w..(sy as usize + 1) * w];
                    let srow = &src[y * w..(y + 1) * w];
                    match kx {
                        0 => {
                            for x in 1..w {
                                dst[x - 1] += srow[x];
                            }
                        }
                        1 => {
                            for x in 0..w {
                                dst[x] += srow[x];
                            }
                        }
                        _ => {
                            for x in 0..w - 1 {
                                dst[x + 1] += srow[x];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// 2×2 max-pool on `(C,H,W)` -> `(C,H/2,W/2)` plus the argmax index in
/// `0..4`, row-major `(dy,dx)`, first-max tie-break (matches
/// `numpy.argmax` / `jnp.argmax`).
pub fn maxpool2(x: &[i32], c: usize, h: usize, w: usize,
                out: &mut [i32], idx: &mut [u8]) {
    let (h2, w2) = (h / 2, w / 2);
    debug_assert_eq!(x.len(), c * h * w);
    debug_assert_eq!(out.len(), c * h2 * w2);
    debug_assert_eq!(idx.len(), c * h2 * w2);
    for ci in 0..c {
        let xc = &x[ci * h * w..(ci + 1) * h * w];
        for y in 0..h2 {
            for xo in 0..w2 {
                let o = ci * h2 * w2 + y * w2 + xo;
                let mut best = i32::MIN;
                let mut bi = 0u8;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let v = xc[(2 * y + dy) * w + 2 * xo + dx];
                        if v > best {
                            best = v;
                            bi = (dy * 2 + dx) as u8;
                        }
                    }
                }
                out[o] = best;
                idx[o] = bi;
            }
        }
    }
}

/// Scatter `dy` `(C,H/2,W/2)` back to `(C,H,W)` at the recorded argmaxes.
pub fn maxpool2_backward(dy: &[i32], idx: &[u8], c: usize, h: usize,
                         w: usize, out: &mut [i32]) {
    let (h2, w2) = (h / 2, w / 2);
    debug_assert_eq!(dy.len(), c * h2 * w2);
    debug_assert_eq!(out.len(), c * h * w);
    out.iter_mut().for_each(|v| *v = 0);
    for ci in 0..c {
        for y in 0..h2 {
            for xo in 0..w2 {
                let o = ci * h2 * w2 + y * w2 + xo;
                let (dy_, dx_) = ((idx[o] / 2) as usize, (idx[o] % 2) as usize);
                out[ci * h * w + (2 * y + dy_) * w + 2 * xo + dx_] = dy[o];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::XorShift64;

    fn rand_vec(rng: &mut XorShift64, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.int_in(-127, 127)).collect()
    }

    /// Brute-force im2col directly from the definition.
    fn im2col_ref(x: &[i32], c: usize, h: usize, w: usize) -> Mat {
        let mut out = Mat::zeros(c * 9, h * w);
        for ci in 0..c {
            for ky in 0..3i32 {
                for kx in 0..3i32 {
                    for y in 0..h as i32 {
                        for xo in 0..w as i32 {
                            let (sy, sx) = (y + ky - 1, x_off(xo, kx));
                            let v = if sy < 0 || sy >= h as i32 || sx < 0
                                || sx >= w as i32
                            {
                                0
                            } else {
                                x[ci * h * w + sy as usize * w + sx as usize]
                            };
                            *out.at_mut(
                                ci * 9 + (ky * 3 + kx) as usize,
                                (y * w as i32 + xo) as usize,
                            ) = v;
                        }
                    }
                }
            }
        }
        out
    }

    fn x_off(x: i32, kx: i32) -> i32 {
        x + kx - 1
    }

    #[test]
    fn im2col_matches_bruteforce() {
        let mut rng = XorShift64::new(5);
        for &(c, h, w) in &[(1usize, 4usize, 4usize), (3, 6, 8), (2, 5, 7), (4, 2, 2)] {
            let x = rand_vec(&mut rng, c * h * w);
            let mut out = Mat::zeros(c * 9, h * w);
            im2col(&x, c, h, w, &mut out);
            assert_eq!(out, im2col_ref(&x, c, h, w), "c={c} h={h} w={w}");
        }
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y — the defining
        // property of the adjoint pair used by conv backward.
        let mut rng = XorShift64::new(6);
        let (c, h, w) = (3usize, 6usize, 5usize);
        for _ in 0..10 {
            let x = rand_vec(&mut rng, c * h * w);
            let ymat = Mat::from_vec(c * 9, h * w, rand_vec(&mut rng, c * 9 * h * w));
            let mut xi = Mat::zeros(c * 9, h * w);
            im2col(&x, c, h, w, &mut xi);
            let mut back = vec![0i32; c * h * w];
            col2im(&ymat, c, h, w, &mut back);
            let lhs: i64 = xi
                .data
                .iter()
                .zip(ymat.data.iter())
                .map(|(&a, &b)| a as i64 * b as i64)
                .sum();
            let rhs: i64 = x
                .iter()
                .zip(back.iter())
                .map(|(&a, &b)| a as i64 * b as i64)
                .sum();
            assert_eq!(lhs, rhs);
        }
    }

    #[test]
    fn maxpool_first_max_tiebreak() {
        // All-equal window: index 0 (top-left) must win.
        let x = vec![7i32; 4];
        let mut out = vec![0i32; 1];
        let mut idx = vec![9u8; 1];
        maxpool2(&x, 1, 2, 2, &mut out, &mut idx);
        assert_eq!(out[0], 7);
        assert_eq!(idx[0], 0);
    }

    #[test]
    fn maxpool_roundtrip_scatter() {
        let mut rng = XorShift64::new(7);
        let (c, h, w) = (2usize, 4usize, 6usize);
        let x = rand_vec(&mut rng, c * h * w);
        let mut pooled = vec![0i32; c * h * w / 4];
        let mut idx = vec![0u8; c * h * w / 4];
        maxpool2(&x, c, h, w, &mut pooled, &mut idx);
        // every pooled value exists in its window
        let mut back = vec![0i32; c * h * w];
        maxpool2_backward(&pooled, &idx, c, h, w, &mut back);
        // scattered positions hold the max; everything else zero
        let nonzero = back.iter().filter(|&&v| v != 0).count();
        assert!(nonzero <= pooled.len());
        for ci in 0..c {
            for y in 0..h / 2 {
                for xo in 0..w / 2 {
                    let o = ci * (h / 2) * (w / 2) + y * (w / 2) + xo;
                    let (dy_, dx_) = ((idx[o] / 2) as usize, (idx[o] % 2) as usize);
                    let pos = ci * h * w + (2 * y + dy_) * w + 2 * xo + dx_;
                    assert_eq!(x[pos], pooled[o], "argmax points at the max");
                    assert_eq!(back[pos], pooled[o]);
                }
            }
        }
    }
}
