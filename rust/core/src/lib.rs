//! # priot-core — the freestanding PRIOT training core
//!
//! Everything a device build needs to *run* PRIOT adaptation, and nothing
//! it doesn't: the pure integer engine, the method plugins, quantization
//! helpers, network specs, the deterministic PRNGs, and the serial
//! snapshot-state types.  `#![no_std]` + `alloc` — no filesystem, no
//! sockets, no threads, no floating-point runtime requirements on the hot
//! paths (the few `f64` touches are config-time: score-fraction rounding
//! and channel-width scaling).
//!
//! The layering contract (enforced by the `cargo check -p priot-core
//! --no-default-features` CI gate and the `layering` test in `cli/tests`):
//!
//! * **New training methods target this crate** — implement
//!   [`methods::MethodPlugin`] against [`engine::Engine`]; no host code
//!   needed until you want a CLI flag for it.
//! * **Transports, stores, datasets, and reporting live above**, in
//!   `priot-host` (and the `priot` CLI above that).  Host-only seams are
//!   re-exported shims: e.g. `priot::methods` = this crate's [`methods`]
//!   plus the host-side `StepBackend`/`plugin_for`.
//! * Errors are the in-crate [`error::Error`] (a message string
//!   implementing [`core::error::Error`]), so host code composes them
//!   with `anyhow` via plain `?`.
//!
//! The next consumer of this seam is a `thumbv6m-none-eabi` (Raspberry Pi
//! Pico) build of exactly this crate — see ROADMAP.

#![cfg_attr(not(test), no_std)]

extern crate alloc;

pub mod engine;
pub mod error;
pub mod methods;
pub mod prng;
pub mod quant;
pub mod serial;
pub mod spec;
pub mod tensor;

/// Symmetric int8 magnitude bound: values live in `[-127, 127]`
/// (`-128` is never produced by any requantization).
pub const INT8_MAX: i32 = 127;

/// `f64::round` (round half away from zero) for no_std builds, where the
/// std float methods are unavailable.  Exact for `|x| < 2^52` — every
/// caller rounds small non-negative counts (channel widths, score
/// fractions × edge counts).
// layering-allow: the one config-time float helper (exact for |x| < 2^52)
pub(crate) fn round_half_away(x: f64) -> f64 {
    let t = x as i64 as f64; // truncate toward zero (layering-allow: ditto)
    let r = x - t;
    if r >= 0.5 {
        t + 1.0
    } else if r <= -0.5 {
        t - 1.0
    } else {
        t
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn round_half_away_matches_std_round() {
        for &x in &[0.0, 0.4, 0.5, 0.6, 1.5, 2.5, 102.3999, 409.6,
                    -0.4, -0.5, -0.6, -1.5, -2.5] {
            assert_eq!(super::round_half_away(x), x.round(), "x={x}");
        }
    }
}
