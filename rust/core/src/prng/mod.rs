//! Deterministic PRNGs and the integer score initializer.
//!
//! `XorShift32` is the cross-language RNG: `python/compile/intnet.py`
//! implements the identical generator, and the score-init / random-selection
//! routines here are bit-compatible with their Python counterparts, so any
//! (seed, shape) pair produces the same scores in the oracle, the JAX path
//! and the engine.

use alloc::vec::Vec;

use crate::quant::clamp8;

/// xorshift32 (Marsaglia). Period 2^32-1; state must be non-zero.
#[derive(Clone, Debug)]
pub struct XorShift32 {
    state: u32,
}

impl XorShift32 {
    pub fn new(seed: u32) -> Self {
        Self { state: if seed == 0 { 0xDEAD_BEEF } else { seed } }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.state = x;
        x
    }

    /// Uniform in `[0, n)` by multiply-shift (n <= 2^31). Slight modulo bias
    /// is irrelevant here and identical across languages is what matters —
    /// only used by Rust-side shuffles, not by cross-language init.
    #[inline]
    pub fn next_below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Fisher–Yates shuffle of indices (epoch-order shuffling).
    pub fn shuffle(&mut self, idx: &mut [usize]) {
        for i in (1..idx.len()).rev() {
            let j = self.next_below((i + 1) as u32) as usize;
            idx.swap(i, j);
        }
    }
}

/// 64-bit xorshift for the property-test generators (richer streams).
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x
    }

    /// Uniform i32 in `[lo, hi]` inclusive.
    pub fn int_in(&mut self, lo: i32, hi: i32) -> i32 {
        let span = (hi as i64 - lo as i64 + 1) as u64;
        lo.wrapping_add((self.next_u64() % span) as i32)
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// Approx-N(0,32) int8 score init — the paper's §III-A initialization in
/// pure integer arithmetic (bit-compatible with `intnet.init_scores`):
/// three top-byte uniforms (σ≈128) summed, centered, then
/// round-half-up-shifted by 2 (σ≈32).
pub fn init_scores(rng: &mut XorShift32, n: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t = (rng.next_u32() >> 24) as i32 + (rng.next_u32() >> 24) as i32
            + (rng.next_u32() >> 24) as i32
            - 382;
        out.push(clamp8((t + 2) >> 2) as i8);
    }
    out
}

/// PRIOT-S random selection mask: `1` for ~`frac_scored` of edges
/// (bit-compatible with `intnet.select_mask_random`).
// layering-allow: init-time threshold derivation (bit-compatible contract)
pub fn select_mask_random(rng: &mut XorShift32, n: usize, frac_scored: f64) -> Vec<u8> {
    let thresh = (frac_scored * 4294967296.0) as u64;
    (0..n)
        .map(|_| u8::from((rng.next_u32() as u64) < thresh))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift32_reference_vectors() {
        // First outputs for seed 1, computed from the algorithm definition
        // (x ^= x<<13; x ^= x>>17; x ^= x<<5) — also asserted in Python.
        let mut r = XorShift32::new(1);
        assert_eq!(r.next_u32(), 270369);
        assert_eq!(r.next_u32(), 67634689);
        let mut r2 = XorShift32::new(1);
        let a: Vec<u32> = (0..8).map(|_| r2.next_u32()).collect();
        let mut r3 = XorShift32::new(1);
        let b: Vec<u32> = (0..8).map(|_| r3.next_u32()).collect();
        assert_eq!(a, b, "determinism");
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift32::new(0);
        assert_ne!(r.next_u32(), 0);
    }

    #[test]
    fn score_init_distribution() {
        let mut rng = XorShift32::new(42);
        let s = init_scores(&mut rng, 20_000);
        let mean: f64 = s.iter().map(|&v| v as f64).sum::<f64>() / s.len() as f64;
        let var: f64 =
            s.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / s.len() as f64;
        assert!(mean.abs() < 1.5, "mean {mean} too far from 0");
        let sigma = var.sqrt();
        assert!((26.0..38.0).contains(&sigma), "sigma {sigma} not ~32");
        assert!(s.iter().all(|&v| (-127..=127).contains(&(v as i32))));
    }

    #[test]
    fn random_mask_fraction() {
        let mut rng = XorShift32::new(7);
        let m = select_mask_random(&mut rng, 50_000, 0.1);
        let frac = m.iter().map(|&v| v as usize).sum::<usize>() as f64 / m.len() as f64;
        assert!((0.08..0.12).contains(&frac), "frac {frac} not ~0.1");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = XorShift32::new(3);
        let mut idx: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut idx);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(idx, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn xorshift64_int_in_bounds() {
        let mut r = XorShift64::new(9);
        for _ in 0..1000 {
            let v = r.int_in(-127, 127);
            assert!((-127..=127).contains(&v));
        }
    }
}
