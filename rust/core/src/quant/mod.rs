//! Scale-factor machinery: the static-shift requantization contract
//! (mirrors `python/compile/quantlib.py` bit-for-bit), NITI-style dynamic
//! shift selection, the integer cross-entropy backward, and the calibration
//! histogram used to pick static shifts.
//!
//! ## Arithmetic lint wall
//!
//! Like `engine` and `tensor::gemm`, this module denies implicit
//! arithmetic (`clippy::arithmetic_side_effects`).  Every deliberate
//! operation carries a scoped `#[allow]` with its range argument; the two
//! `wrapping_add`s here are the *only* intentionally-wrapping ops in the
//! repo's hot path (documented at their sites), and `priot::audit`
//! statically proves the accumulator + rounding-bias sums they see cannot
//! actually wrap for a sound model/scale table.

#![deny(clippy::arithmetic_side_effects)]

// Lint wall: the scale-table text codec does parsing/formatting arithmetic
// only (line counters, error positions) — no hot-path math.  Validity of
// the *values* it parses is `priot::audit`'s job (shift-range issues).
#[allow(clippy::arithmetic_side_effects)]
pub mod scales;

pub use scales::{LayerScales, Scales};

use alloc::vec;
use alloc::vec::Vec;

use crate::INT8_MAX;

/// Fixed-point one for the base-2 softmax (14 fractional bits).
pub const SOFTMAX_ONE_BITS: i32 = 14;
pub const SOFTMAX_ONE: i32 = 1 << SOFTMAX_ONE_BITS;
/// Logit-gap pre-shift: logits differing by `1 << SOFTMAX_GAP_SHIFT` get a
/// probability ratio of 2.
pub const SOFTMAX_GAP_SHIFT: i32 = 3;

/// Arithmetic right shift with round-half-up: `(x + (1 << (s-1))) >> s`.
///
/// `s == 0` is the identity.  Rust's `>>` on `i32` is arithmetic, matching
/// numpy/jnp — the cross-language contract all three stacks share.
// Lint wall: `s - 1` is guarded by the `s == 0` branch; the `wrapping_add`
// is the audited bias add (`audit::Verdict` proves acc + 1<<(s-1) fits i32
// for every sound layer — wrapping is the overflow the auditor rules out).
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
pub fn rshift_round(x: i32, s: u32) -> i32 {
    if s == 0 {
        x
    } else {
        (x.wrapping_add(1 << (s - 1))) >> s
    }
}

/// Clamp into the symmetric int8 range `[-127, 127]`.
// Lint wall: `-INT8_MAX` is a constant negation of 127.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
pub fn clamp8(x: i32) -> i32 {
    x.clamp(-INT8_MAX, INT8_MAX)
}

/// int32 accumulator -> int8-range value: shift-round then clamp.
#[inline(always)]
pub fn requant(x: i32, s: u32) -> i32 {
    clamp8(rshift_round(x, s))
}

/// Slice version of [`requant`] writing into `out`.
pub fn requant_slice(acc: &[i32], s: u32, out: &mut [i32]) {
    debug_assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o = requant(a, s);
    }
}

/// NITI dynamic scale: smallest `s` with `max_abs >> s <= 127`.
///
/// Equivalent to `max(0, bitlen(max_abs) - 7)`; kept as the loop form to
/// mirror the oracle definition exactly.
// Lint wall: `s += 1` is bounded by the loop condition (s < 32 since
// max_abs >> 31 is 0 or -1 for any i32).
#[allow(clippy::arithmetic_side_effects)]
#[inline]
pub fn dynamic_shift_for(max_abs: i32) -> u32 {
    debug_assert!(max_abs >= 0);
    let mut s = 0u32;
    while (max_abs >> s) > INT8_MAX {
        s += 1;
    }
    s
}

/// Max |x| over a slice (0 for empty) — the dynamic-scale probe.
// Lint wall: `abs()` panics only on i32::MIN, unreachable for audited
// accumulators (|acc| ≤ K·127² < 2^31 is exactly the proven bound).
#[allow(clippy::arithmetic_side_effects)]
pub fn max_abs(xs: &[i32]) -> i32 {
    xs.iter().fold(0, |m, &x| m.max(x.abs()))
}

/// Integer cross-entropy backward via base-2 fixed-point softmax
/// (bit-identical to `quantlib.int_softmax_grad`):
///
/// ```text
/// e_i   = SOFTMAX_ONE >> min(14, (max - logit_i) >> SOFTMAX_GAP_SHIFT)
/// p̂_i  = e_i * 127 / Σe          (trunc div; operands nonnegative)
/// δ_i   = p̂_i - 127·onehot_i     ∈ [-127, 127]
/// ```
// Lint wall: int8-range logits widen through i64 (`m - l` ≤ 254, the
// truncating division has total ≥ e_i ≥ 1), every range shown above.
#[allow(clippy::arithmetic_side_effects)]
pub fn int_softmax_grad(logits: &[i32], label: usize, out: &mut [i32]) {
    debug_assert_eq!(logits.len(), out.len());
    let m = logits.iter().copied().max().unwrap_or(0);
    let mut total: i64 = 0;
    for (o, &l) in out.iter_mut().zip(logits.iter()) {
        let gap = ((m - l) >> SOFTMAX_GAP_SHIFT).min(SOFTMAX_ONE_BITS);
        let e = SOFTMAX_ONE >> gap;
        *o = e;
        total += e as i64;
    }
    for (i, o) in out.iter_mut().enumerate() {
        let p_hat = ((*o as i64 * INT8_MAX as i64) / total) as i32;
        *o = p_hat - if i == label { INT8_MAX } else { 0 };
    }
}

/// Counter-based u32 hash (splitmix-style) for stochastic rounding —
/// bit-identical to `quantlib.sr_hash_u32` (numpy/jnp mirror).
#[inline(always)]
pub fn sr_hash_u32(step: u32, idx: u32) -> u32 {
    let mut x = idx.wrapping_mul(0x85EB_CA6B) ^ step.wrapping_mul(0x9E37_79B9);
    x ^= x >> 16;
    x = x.wrapping_mul(0x045D_9F3B);
    x ^= x >> 16;
    x = x.wrapping_mul(0x2C1B_3C6D);
    x ^= x >> 16;
    x
}

/// int32 → int8-range with NITI-style *stochastic* rounding:
/// `(x + r) >> s` with `r = hash(step, idx) mod 2^s`, so `E[out] = x/2^s`
/// and sub-threshold update signal survives in expectation (deterministic
/// round-half-up rounds nearly all batch-1 updates to zero — see
/// EXPERIMENTS.md pilot log).  Bit-identical to
/// `quantlib.stochastic_requant`.
// Lint wall: `(1u32 << s) - 1` with s ≥ 1 cannot underflow; the
// `wrapping_add` is the second audited bias add (r < 2^s ≤ the
// round-half-up bias bound the auditor already accounts for).
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
pub fn stochastic_requant(x: i32, s: u32, step: u32, idx: u32) -> i32 {
    if s == 0 {
        return clamp8(x);
    }
    let r = (sr_hash_u32(step, idx) & ((1u32 << s) - 1)) as i32;
    clamp8(x.wrapping_add(r) >> s)
}

/// Histogram-of-shifts calibrator: feed observed dynamic shifts, read back
/// the mode (the paper's "most frequent value", §IV-A).  Ties break toward
/// the smaller shift, matching the Python `max(sorted(items), key=count)`
/// reversed-stability convention (first-seen smallest wins on equal count).
#[derive(Clone, Debug, Default)]
pub struct ShiftHistogram {
    counts: Vec<u32>, // index = shift (shifts are tiny: < 32)
}

// Lint wall: u32 vote counters (`+= 1` saturates the test budget long
// before 2^32) and a `len() - 1` over a never-empty vec.
#[allow(clippy::arithmetic_side_effects)]
impl ShiftHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0; 32] }
    }

    pub fn record(&mut self, s: u32) {
        let idx = (s as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn mode(&self) -> u32 {
        let mut best = 0usize;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > self.counts[best] {
                best = i;
            }
        }
        best as u32
    }

    pub fn total(&self) -> u32 {
        self.counts.iter().sum()
    }
}

// Lint wall: tests compute reference values freely.
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rshift_round_reference_cases() {
        // Mirrors python/tests/test_kernels.py::test_rshift_round_cases.
        for &(x, s, want) in &[
            (5i32, 1u32, 3i32),
            (-5, 1, -2),
            (4, 2, 1),
            (-4, 2, -1),
            (7, 3, 1),
            (-7, 3, -1),
            (8, 3, 1),
            (127, 0, 127),
            (-128, 4, -8),
        ] {
            assert_eq!(rshift_round(x, s), want, "x={x} s={s}");
        }
    }

    #[test]
    fn rshift_round_is_round_half_up() {
        for x in -10_000i32..10_000 {
            for s in 1u32..8 {
                let want = ((x as f64) / f64::from(1 << s) + 0.5).floor() as i32;
                assert_eq!(rshift_round(x, s), want, "x={x} s={s}");
            }
        }
    }

    #[test]
    fn requant_stays_in_range() {
        for &x in &[i32::MIN + 1024, -12345, -1, 0, 1, 98765, i32::MAX - 1024] {
            for s in 0..20 {
                let v = requant(x, s);
                assert!((-127..=127).contains(&v));
            }
        }
    }

    #[test]
    fn dynamic_shift_matches_bitlen_rule() {
        for m in 0i32..100_000 {
            let s = dynamic_shift_for(m);
            assert!(m >> s <= 127);
            if s > 0 {
                assert!(m >> (s - 1) > 127, "shift not minimal for {m}");
            }
        }
    }

    #[test]
    fn softmax_grad_properties() {
        let mut rng = crate::prng::XorShift64::new(11);
        let mut out = [0i32; 10];
        for _ in 0..500 {
            let logits: Vec<i32> = (0..10).map(|_| rng.int_in(-127, 127)).collect();
            let label = rng.below(10);
            int_softmax_grad(&logits, label, &mut out);
            for (i, &g) in out.iter().enumerate() {
                assert!((-127..=127).contains(&g));
                if i == label {
                    assert!(g <= 0, "true-class grad must be <= 0");
                } else {
                    assert!(g >= 0);
                }
            }
        }
    }

    #[test]
    fn softmax_grad_peaked_logits() {
        // A confidently-correct prediction produces a near-zero gradient:
        // e = [16384 at true, 1 elsewhere]; p̂_true = 127·16384/16393 = 126
        // → δ_true = -1; all other classes round to 0.
        let mut logits = [-127i32; 10];
        logits[3] = 127;
        let mut out = [0i32; 10];
        int_softmax_grad(&logits, 3, &mut out);
        assert_eq!(out[3], -1);
        assert!(out.iter().enumerate().all(|(i, &g)| i == 3 || g == 0));
    }

    #[test]
    fn sr_hash_reference_vectors() {
        // Values pinned against the Python implementation (see
        // python/tests/test_quantlib.py::test_sr_hash_cross_language).
        assert_eq!(sr_hash_u32(0, 0), sr_hash_u32(0, 0));
        assert_ne!(sr_hash_u32(0, 0), sr_hash_u32(0, 1));
        assert_ne!(sr_hash_u32(0, 0), sr_hash_u32(1, 0));
    }

    #[test]
    fn stochastic_requant_unbiased() {
        // Mean over many (step) draws approaches x / 2^s.
        for &x in &[37i32, -37, 1000, -1000, 5] {
            let s = 5u32;
            let mut sum = 0i64;
            let n = 4096u32;
            for step in 0..n {
                sum += stochastic_requant(x, s, step, 123) as i64;
            }
            let mean = sum as f64 / n as f64;
            let want = x as f64 / 32.0;
            assert!((mean - want).abs() < 0.1, "x={x}: mean {mean} want {want}");
        }
    }

    #[test]
    fn stochastic_requant_range_and_zero_shift() {
        for step in 0..100 {
            let v = stochastic_requant(1 << 28, 10, step, step);
            assert!((-127..=127).contains(&v));
        }
        assert_eq!(stochastic_requant(300, 0, 7, 7), 127, "s=0 is clamp only");
    }

    #[test]
    fn histogram_mode() {
        let mut h = ShiftHistogram::new();
        for s in [3u32, 5, 5, 7, 5, 3] {
            h.record(s);
        }
        assert_eq!(h.mode(), 5);
        assert_eq!(h.total(), 6);
    }
}
