//! The static scale-shift table — parsed from `artifacts/<model>.scales.txt`
//! (written by `python/compile/pretrain.py` after calibration).
//!
//! This module is pure parsing/formatting: reading the file off disk lives
//! in the host layer (`priot_host::quant::load_scales`), keeping the core
//! crate free of filesystem IO.

use alloc::format;
use alloc::string::String;
use alloc::vec;
use alloc::vec::Vec;

use crate::bail;
use crate::error::Result;

/// Static shifts for one parameterized layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerScales {
    /// conv/fc output accumulator → int8.
    pub fwd: u32,
    /// delta-x accumulator → int8.
    pub bwd: u32,
    /// delta-W accumulator → int8 gradient `g8`.
    pub grad: u32,
    /// `W ⊙ g8` accumulator → int8 score step.
    pub score: u32,
}

impl Default for LayerScales {
    fn default() -> Self {
        Self { fwd: 7, bwd: 7, grad: 7, score: 7 }
    }
}

/// Per-layer shifts plus the two global learning-rate shifts (see
/// `intnet.Scales` for the rationale: without them every integer update
/// saturates the int8 step).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Scales {
    pub layers: Vec<LayerScales>,
    pub lr_shift: u32,
    pub score_lr_shift: u32,
}

/// Parse one whitespace-separated field, reporting the line and field name.
fn parse_field<T: core::str::FromStr>(s: &str, what: &str, line: usize) -> Result<T> {
    s.parse().map_err(|_| crate::err!("scales line {line}: bad {what} value {s:?}"))
}

impl Scales {
    pub fn default_for(n_layers: usize) -> Self {
        Self {
            layers: vec![LayerScales::default(); n_layers],
            lr_shift: 5,
            score_lr_shift: 5,
        }
    }

    /// Parse the text format: optional `lr_shift N` / `score_lr_shift N`
    /// lines, then `layer fwd bwd grad score` rows; `#` comments.
    pub fn from_text(text: &str) -> Result<Self> {
        let mut layers = Vec::new();
        let (mut lr_shift, mut score_lr_shift) = (5u32, 5u32);
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts[0] {
                "lr_shift" => {
                    let v = parts.get(1).ok_or_else(|| {
                        crate::err!("scales line {}: lr_shift needs a value", ln + 1)
                    })?;
                    lr_shift = parse_field(v, "lr_shift", ln + 1)?;
                }
                "score_lr_shift" => {
                    let v = parts.get(1).ok_or_else(|| {
                        crate::err!("scales line {}: score_lr_shift needs a value", ln + 1)
                    })?;
                    score_lr_shift = parse_field(v, "score_lr_shift", ln + 1)?;
                }
                _ => {
                    if parts.len() != 5 {
                        bail!("scales line {}: expected 5 fields, got {}",
                              ln + 1, parts.len());
                    }
                    let idx: usize = parse_field(parts[0], "layer index", ln + 1)?;
                    if idx != layers.len() {
                        bail!("scales line {}: layer index {} out of order",
                              ln + 1, idx);
                    }
                    layers.push(LayerScales {
                        fwd: parse_field(parts[1], "fwd", ln + 1)?,
                        bwd: parse_field(parts[2], "bwd", ln + 1)?,
                        grad: parse_field(parts[3], "grad", ln + 1)?,
                        score: parse_field(parts[4], "score", ln + 1)?,
                    });
                }
            }
        }
        if layers.is_empty() {
            bail!("scales file contained no layer rows");
        }
        Ok(Self { layers, lr_shift, score_lr_shift })
    }

    pub fn to_text(&self) -> String {
        let mut out = format!(
            "lr_shift {}\nscore_lr_shift {}\n# layer fwd bwd grad score\n",
            self.lr_shift, self.score_lr_shift
        );
        for (i, l) in self.layers.iter().enumerate() {
            out.push_str(&format!("{} {} {} {} {}\n", i, l.fwd, l.bwd, l.grad, l.score));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = Scales {
            layers: vec![
                LayerScales { fwd: 9, bwd: 7, grad: 11, score: 6 },
                LayerScales { fwd: 8, bwd: 8, grad: 10, score: 6 },
            ],
            lr_shift: 4,
            score_lr_shift: 6,
        };
        let t = s.to_text();
        assert_eq!(Scales::from_text(&t).unwrap(), s);
    }

    #[test]
    fn parses_python_output_shape() {
        let text = "lr_shift 5\nscore_lr_shift 6\n# layer fwd bwd grad score\n\
                    0 9 7 7 7\n1 8 8 4 6\n2 11 8 10 6\n3 8 2 9 6\n";
        let s = Scales::from_text(text).unwrap();
        assert_eq!(s.layers.len(), 4);
        assert_eq!(s.layers[2].grad, 10);
        assert_eq!(s.score_lr_shift, 6);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Scales::from_text("").is_err());
        assert!(Scales::from_text("0 1 2").is_err());
        assert!(Scales::from_text("1 1 2 3 4").is_err(), "out-of-order index");
    }
}
