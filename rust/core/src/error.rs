//! The core crate's error type: a plain message string.
//!
//! `priot-core` is `no_std`, so it cannot use `anyhow`; it also doesn't
//! need structured errors — every fallible core path reports a
//! human-readable invariant violation (shape mismatch, bad scale table,
//! implausible checkpoint).  [`Error`] implements [`core::error::Error`]
//! (stable since Rust 1.81, and the same trait object `std::error::Error`
//! names), so host code composes core results with `anyhow` via plain
//! `?` / `.context(..)` — no adapter layer at the crate seam.

use alloc::string::String;
use core::fmt;

/// A message-only error (the core-crate counterpart of `anyhow!`).
#[derive(Debug)]
pub struct Error(String);

/// Result alias used throughout `priot-core`.
pub type Result<T, E = Error> = core::result::Result<T, E>;

impl Error {
    /// Build from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self(alloc::string::ToString::to_string(&msg))
    }

    /// Build from a `format_args!` invocation — what the [`bail!`] and
    /// [`err!`] macros expand to.
    ///
    /// [`bail!`]: crate::bail
    /// [`err!`]: crate::err
    pub fn from_args(args: fmt::Arguments<'_>) -> Self {
        Self(alloc::fmt::format(args))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl core::error::Error for Error {}

/// Construct an [`Error`] from a format string (the core-crate
/// counterpart of `anyhow::anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::from_args(core::format_args!($($arg)*))
    };
}

/// Return early with an [`Error`] (the core-crate counterpart of
/// `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_macros() {
        let e = Error::msg("plain");
        assert_eq!(e.to_string(), "plain");
        let e = crate::err!("layer {} bad", 3);
        assert_eq!(e.to_string(), "layer 3 bad");
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                crate::bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn composes_with_the_std_error_trait() {
        // The host crates rely on this: anyhow's blanket From<E: Error>
        // picks core errors up at the crate seam.
        let e: alloc::boxed::Box<dyn core::error::Error> =
            alloc::boxed::Box::new(Error::msg("seam"));
        assert_eq!(e.to_string(), "seam");
    }
}
