//! The "picoengine": a pure-Rust, batch-1, integer-only training engine —
//! the device-side implementation of the paper (the authors' C++ on the
//! Raspberry Pi Pico), bit-identical to the numpy oracle
//! (`python/compile/intnet.py`) and to the AOT JAX graphs.
//!
//! All activations/weights/scores are int8-range values in `i32` working
//! buffers; every MAC accumulates in int32; requantization is the shared
//! round-half-up shift (`quant::rshift_round`), except NITI's update step
//! which uses counter-based stochastic rounding (`quant::stochastic_requant`).
//!
//! The hot path is allocation-free: all tape and gradient buffers live in
//! the [`Workspace`], sized once from the [`NetSpec`].
//!
//! ## Arithmetic lint wall
//!
//! This module is inside the `priot::audit` soundness perimeter: implicit
//! arithmetic is denied (`clippy::arithmetic_side_effects`), and every
//! block that intentionally does raw `+`/`*` carries a scoped, documented
//! `#[allow]`.  The point is that *new* arithmetic cannot sneak into the
//! integer hot path without either a review note or a static bound from
//! `priot::audit` — the i32 MAC accumulation here is exactly the contract
//! the auditor proves (`K·127·127` per row plus the rounding bias fits
//! i32, see `audit::Verdict`).

#![deny(clippy::arithmetic_side_effects)]

pub mod plan;

use alloc::sync::Arc;
use alloc::vec;
use alloc::vec::Vec;

use crate::bail;
use crate::error::Result;
use crate::quant::{
    clamp8, dynamic_shift_for, int_softmax_grad, max_abs, requant, rshift_round,
    stochastic_requant, Scales,
};
use crate::serial::TensorI8;
use crate::spec::{LayerSpec, NetSpec};
use crate::tensor::{
    col2im, im2col, maxpool2, maxpool2_backward, Kernels, Mat,
};
#[cfg(feature = "obs")]
use crate::tensor::{KernelCounters, KernelKind};
use crate::INT8_MAX;

/// Result of one forward or training step.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub logits: Vec<i32>,
    /// # of final-layer outputs exceeding the int8 range before clamping
    /// (the Fig. 2 probe).
    pub overflow: u32,
}

/// Per-layer tape + scratch buffers (preallocated; reused every step).
struct LayerBufs {
    /// Forward GEMM input: im2col patches (conv) or the input vector (fc),
    /// stored as (K, N) with N = H·W for conv, 1 for fc.
    cols: Mat,
    /// Raw int32 forward accumulator (F, N).
    acc: Mat,
    /// Post-relu, pre-pool activation (len F·N).
    relu_out: Vec<i32>,
    /// 2×2 argmax indices (conv+pool layers only).
    pool_idx: Vec<u8>,
    /// Layer output after pool (input of the next layer).
    out: Vec<i32>,
    /// Effective (masked) weight for the forward pass.
    weff: Mat,
    /// Weight-gradient accumulator δy·xᵀ (F, K).
    grad: Mat,
    /// δx int32 accumulator (len of layer input).
    dx32: Vec<i32>,
    /// δcols scratch for conv backward (K, N).
    dcols: Mat,
}

/// Workspace: per-layer buffers + the backward delta ping-pong buffers.
pub struct Workspace {
    layers: Vec<LayerBufs>,
    dy_a: Vec<i32>,
    dy_b: Vec<i32>,
    dlogits: Vec<i32>,
}

// Lint wall: buffer-sizing products over spec dims; an overflow here would
// fail the allocation loudly, never corrupt training arithmetic.
#[allow(clippy::arithmetic_side_effects)]
impl Workspace {
    pub fn new(spec: &NetSpec) -> Self {
        let mut layers = Vec::with_capacity(spec.layers.len());
        let mut max_len = spec.input_len();
        for l in &spec.layers {
            let (f, k) = l.weight_shape();
            let (n, pre_pool_len, pooled) = match *l {
                LayerSpec::Conv { in_h, in_w, out_c, pool, .. } => {
                    (in_h * in_w, out_c * in_h * in_w, pool)
                }
                LayerSpec::Fc { out_f, .. } => (1, out_f, false),
            };
            layers.push(LayerBufs {
                cols: Mat::zeros(k, n),
                acc: Mat::zeros(f, n),
                relu_out: vec![0; pre_pool_len],
                pool_idx: vec![0; if pooled { pre_pool_len / 4 } else { 0 }],
                out: vec![0; l.out_len()],
                weff: Mat::zeros(f, k),
                grad: Mat::zeros(f, k),
                dx32: vec![0; l.in_len()],
                dcols: Mat::zeros(k, n),
            });
            max_len = max_len.max(pre_pool_len).max(l.in_len());
        }
        Workspace {
            layers,
            dy_a: vec![0; max_len],
            dy_b: vec![0; max_len],
            dlogits: vec![0; spec.num_classes()],
        }
    }
}

/// Pruning state passed to forward: scores + PRIOT-S existence masks + θ.
pub struct PruneState<'a> {
    pub scores: &'a [Vec<i32>],
    pub masks: &'a [Vec<i32>],
    pub theta: i32,
}

/// Buffers for the batched forward path, allocated on first use and
/// rebuilt when the batch size changes.  Batch-B forward is the batch-1
/// forward with B samples laid side by side along the GEMM column axis:
/// per-column arithmetic is untouched, so results are bit-identical to B
/// calls of [`Engine::forward`] while the weight matrix streams through
/// the cache once per layer instead of once per sample (and the FC layers
/// hit the `gemm_nn` n>1 kernel instead of the GEMV path).
///
/// Besides inference, these buffers double as the *batched tape* for
/// chunked training ([`Engine::step_priot_chunk`]): `cols`, `relu`, and
/// the per-layer `pool_idx` hold every sample's forward record, and
/// [`Engine::load_tape`] gathers one sample's slice back into the
/// per-sample [`Workspace`] so the batch-1 backward runs unchanged.
struct BatchBufs {
    b: usize,
    /// Per-layer scratch for one sample's im2col patches (K, N).
    scratch: Vec<Mat>,
    /// Per-layer batched GEMM input (K, B·N): sample `bi` occupies columns
    /// `[bi·N, (bi+1)·N)`.
    cols: Vec<Mat>,
    /// Per-layer batched int32 accumulator (F, B·N).
    acc: Vec<Mat>,
    /// Per-layer post-requant/relu activations (F·B·N).
    relu: Vec<Vec<i32>>,
    /// One sample's pre-pool activation gathered channel-major (max F·N).
    gather: Vec<i32>,
    /// Per-layer 2×2 argmax tape: sample `bi`'s indices occupy
    /// `[bi·out_len, (bi+1)·out_len)` (pooled conv layers only; empty
    /// otherwise).  Kept per layer — not scratch — so chunked training can
    /// replay any sample's backward from the batched forward.
    pool_idx: Vec<Vec<u8>>,
    /// Per-sample final-layer overflow counts (the Fig. 2 probe, batched).
    ovf: Vec<u32>,
    /// Ping-pong sample-major activation buffers (B · max layer len).
    x_a: Vec<i32>,
    x_b: Vec<i32>,
}

// Lint wall: same buffer-sizing arithmetic as `Workspace` (batch-scaled).
#[allow(clippy::arithmetic_side_effects)]
impl BatchBufs {
    fn new(spec: &NetSpec, b: usize) -> Self {
        let mut scratch = Vec::with_capacity(spec.layers.len());
        let mut cols = Vec::with_capacity(spec.layers.len());
        let mut acc = Vec::with_capacity(spec.layers.len());
        let mut relu = Vec::with_capacity(spec.layers.len());
        let mut pool_idx = Vec::with_capacity(spec.layers.len());
        let mut max_pre = 0usize;
        let mut max_len = spec.input_len();
        for l in &spec.layers {
            let (f, k) = l.weight_shape();
            let (n, pooled) = match *l {
                LayerSpec::Conv { in_h, in_w, pool, .. } => (in_h * in_w, pool),
                LayerSpec::Fc { .. } => (1, false),
            };
            scratch.push(Mat::zeros(k, n));
            cols.push(Mat::zeros(k, n * b));
            acc.push(Mat::zeros(f, n * b));
            relu.push(vec![0; f * n * b]);
            pool_idx.push(vec![0u8; if pooled { f * n * b / 4 } else { 0 }]);
            max_pre = max_pre.max(f * n);
            max_len = max_len.max(l.out_len());
        }
        BatchBufs {
            b,
            scratch,
            cols,
            acc,
            relu,
            pool_idx,
            ovf: vec![0; b],
            gather: vec![0; max_pre],
            x_a: vec![0; b * max_len],
            x_b: vec![0; b * max_len],
        }
    }
}

/// The integer network engine.
///
/// Backbone weights and the static scale table are held behind `Arc` so a
/// host-side `Fleet` of concurrent sessions shares one copy of the
/// read-only backbone.  NITI (which *does* update weights) transparently
/// copies-on-write via [`Arc::make_mut`] — a lone session mutates in place,
/// a fleet session forks its own diverging copy on the first update.
pub struct Engine {
    pub spec: NetSpec,
    pub scales: Arc<Scales>,
    pub weights: Arc<Vec<Mat>>,
    ws: Workspace,
    /// GEMM dispatch + its packing scratch (see [`Kernels`]): tiled by
    /// default, reserved up front from [`plan::BufferPlan::scratch_elems`]
    /// so steady-state kernels never allocate — and so the static memory
    /// audit's `plan == probe` equality covers the scratch too.
    kernels: Kernels,
    /// Batched-forward buffers (lazy; see [`BatchBufs`]).
    batch: Option<BatchBufs>,
    /// Optional runtime accumulator probe (see [`AccProbe`]); off by
    /// default — the observe loop never runs on the production path.
    probe: Option<AccProbe>,
    /// Chunked-training θ-crossing fallbacks: number of times
    /// [`Self::step_priot_chunk`] stopped early because a score update
    /// flipped an edge across θ (the remaining samples fall back to
    /// per-sample steps).  Deterministic `u64`, `obs` feature only.
    #[cfg(feature = "obs")]
    theta_fallbacks: u64,
}

/// Engine-level perf counters (the `obs` feature): the kernel counters
/// accumulated since the last take plus the θ-crossing fallback count.
/// Deterministic integers only — two identical runs produce identical
/// counters; wall-clock stays host-side.
#[cfg(feature = "obs")]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineCounters {
    /// Which kernel variant the engine dispatches to.
    pub kind: KernelKind,
    /// GEMM call/MAC/GEMV/scratch counters (see [`KernelCounters`]).
    pub kernels: KernelCounters,
    /// `step_priot_chunk` early stops due to a θ crossing.
    pub theta_fallbacks: u64,
}

/// Per-layer min/max of the raw i32 forward accumulator, observed at the
/// GEMM output before requantization — the runtime cross-check for the
/// static bounds `priot::audit` derives (`tests/audit.rs` asserts every
/// observed extreme lies inside its proven interval).
///
/// Deliberately arithmetic-free (min/max folds only): this type lives
/// inside the lint wall with no `#[allow]` — the deny verifies it.
#[derive(Clone, Debug)]
pub struct AccProbe {
    /// Per-layer smallest accumulator seen (`i32::MAX` until observed).
    pub min: Vec<i32>,
    /// Per-layer largest accumulator seen (`i32::MIN` until observed).
    pub max: Vec<i32>,
}

impl AccProbe {
    fn new(n_layers: usize) -> Self {
        Self { min: vec![i32::MAX; n_layers], max: vec![i32::MIN; n_layers] }
    }

    /// True once layer `li` has observed at least one accumulator value.
    pub fn observed(&self, li: usize) -> bool {
        self.min[li] <= self.max[li]
    }

    fn observe(&mut self, li: usize, acc: &[i32]) {
        let (mut lo, mut hi) = (self.min[li], self.max[li]);
        for &v in acc {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        self.min[li] = lo;
        self.max[li] = hi;
    }
}

fn check_shapes(spec: &NetSpec, weights: &[Mat], scales: &Scales) -> Result<()> {
    if weights.len() != spec.layers.len() {
        bail!("expected {} weight tensors, got {}", spec.layers.len(),
              weights.len());
    }
    if scales.layers.len() != spec.layers.len() {
        bail!("expected {} scale rows, got {}", spec.layers.len(),
              scales.layers.len());
    }
    for (li, (l, w)) in spec.layers.iter().zip(weights.iter()).enumerate() {
        let (r, c) = l.weight_shape();
        if w.rows != r || w.cols != c {
            bail!("layer {li}: weight shape ({},{}) != spec ({r},{c})",
                  w.rows, w.cols);
        }
    }
    Ok(())
}

// Lint wall: the audited integer hot path.  Every `+`/`*` below is i32 MAC
// accumulation or index arithmetic whose bounds `priot::audit` proves from
// the spec (per-row K·127·127 envelope + requant rounding bias ≤ i32::MAX);
// the runtime cross-check is `AccProbe` + the Fig. 2 overflow counters.
#[allow(clippy::arithmetic_side_effects)]
impl Engine {
    pub fn new(spec: NetSpec, weights: Vec<Mat>, scales: Scales) -> Result<Self> {
        Self::shared(spec, Arc::new(weights), Arc::new(scales))
    }

    /// Build against an already-shared backbone (the fleet path): no weight
    /// or scale data is copied, only the per-session workspace is allocated.
    pub fn shared(spec: NetSpec, weights: Arc<Vec<Mat>>, scales: Arc<Scales>)
                  -> Result<Self> {
        check_shapes(&spec, &weights, &scales)?;
        let ws = Workspace::new(&spec);
        let mut kernels = Kernels::tiled();
        let (ae, be) = plan::BufferPlan::of(&spec).scratch_elems(0);
        kernels.reserve(ae, be);
        Ok(Self {
            spec,
            scales,
            weights,
            ws,
            kernels,
            batch: None,
            probe: None,
            #[cfg(feature = "obs")]
            theta_fallbacks: 0,
        })
    }

    /// The GEMM dispatch object (and its scratch) this engine runs on.
    pub fn kernels(&self) -> &Kernels {
        &self.kernels
    }

    /// Read-and-reset the perf counters accumulated since the last take
    /// (kernel calls/MACs/GEMV hits/scratch high-water + θ fallbacks).
    #[cfg(feature = "obs")]
    pub fn take_counters(&mut self) -> EngineCounters {
        let out = EngineCounters {
            kind: self.kernels.kind(),
            kernels: self.kernels.take_counters(),
            theta_fallbacks: self.theta_fallbacks,
        };
        self.theta_fallbacks = 0;
        out
    }

    /// Start recording per-layer accumulator extremes (resets any prior
    /// probe).  Costs one min/max pass per GEMM output while enabled.
    pub fn probe_enable(&mut self) {
        self.probe = Some(AccProbe::new(self.spec.layers.len()));
    }

    /// Stop recording and return the observed extremes (if enabled).
    pub fn probe_take(&mut self) -> Option<AccProbe> {
        self.probe.take()
    }

    /// Build from the on-disk int8 tensors (artifacts).
    pub fn from_tensors(spec: NetSpec, tensors: &[TensorI8], scales: Scales)
                        -> Result<Self> {
        let weights = tensors
            .iter()
            .zip(spec.layers.iter())
            .map(|(t, l)| {
                let (r, c) = l.weight_shape();
                Mat::from_vec(r, c, t.to_i32())
            })
            .collect();
        Self::new(spec, weights, scales)
    }

    fn effective_weight(&mut self, li: usize, prune: Option<&PruneState>) {
        let w = &self.weights[li];
        let weff = &mut self.ws.layers[li].weff;
        match prune {
            None => weff.data.copy_from_slice(&w.data),
            Some(p) => {
                let (s, m) = (&p.scores[li], &p.masks[li]);
                for i in 0..w.data.len() {
                    // keep = 1 - m·(1 - (s >= θ)): unscored edges survive.
                    let keep = if m[i] != 0 && s[i] < p.theta { 0 } else { 1 };
                    weff.data[i] = w.data[i] * keep;
                }
            }
        }
    }

    /// Forward pass (records the tape in the workspace).
    ///
    /// Returns `(overflow, dyn_fwd_shifts)`; logits are left in
    /// `self.ws.layers.last().out`.
    pub fn forward(&mut self, img: &[i32], prune: Option<&PruneState>,
                   dynamic: bool) -> (u32, Vec<u32>) {
        debug_assert_eq!(img.len(), self.spec.input_len());
        let n_layers = self.spec.layers.len();
        let mut overflow = 0u32;
        let mut dyn_shifts = Vec::new();
        for li in 0..n_layers {
            // §Perf: skip the masked-weight copy entirely when nothing is
            // pruned (NITI paths) — the GEMM reads the weights in place.
            if prune.is_some() {
                self.effective_weight(li, prune);
            }
            let layer = self.spec.layers[li];
            let last = li == n_layers - 1;
            // Split borrows: previous layer's output is the input here.
            let (head, tail) = self.ws.layers.split_at_mut(li);
            let buf = &mut tail[0];
            let x: &[i32] = if li == 0 { img } else { &head[li - 1].out };
            match layer {
                LayerSpec::Conv { in_c, in_h, in_w, .. } => {
                    im2col(x, in_c, in_h, in_w, &mut buf.cols);
                }
                LayerSpec::Fc { .. } => {
                    buf.cols.data.copy_from_slice(x);
                }
            }
            let w_fwd: &Mat =
                if prune.is_some() { &buf.weff } else { &self.weights[li] };
            self.kernels.gemm_nn(w_fwd, &buf.cols, &mut buf.acc);
            if let Some(p) = self.probe.as_mut() {
                p.observe(li, &buf.acc.data);
            }
            let mut s = self.scales.layers[li].fwd;
            if dynamic {
                s = dynamic_shift_for(max_abs(&buf.acc.data));
                dyn_shifts.push(s);
            }
            // requant (+ relu) into relu_out; probe overflow on the last.
            let relu = match layer {
                LayerSpec::Conv { relu, .. } => relu,
                LayerSpec::Fc { relu, .. } => relu,
            };
            for (o, &a) in buf.relu_out.iter_mut().zip(buf.acc.data.iter()) {
                let y = rshift_round(a, s);
                if last && y.abs() > INT8_MAX {
                    overflow += 1;
                }
                let y = clamp8(y);
                *o = if relu { y.max(0) } else { y };
            }
            match layer {
                LayerSpec::Conv { in_c: _, in_h, in_w, out_c, pool, .. } if pool => {
                    maxpool2(&buf.relu_out, out_c, in_h, in_w, &mut buf.out,
                             &mut buf.pool_idx);
                }
                _ => buf.out.copy_from_slice(&buf.relu_out),
            }
        }
        (overflow, dyn_shifts)
    }

    pub fn logits(&self) -> &[i32] {
        &self.ws.layers.last().unwrap().out
    }

    /// Forward + argmax — the inference path.
    pub fn predict(&mut self, img: &[i32], prune: Option<&PruneState>) -> usize {
        self.forward(img, prune, false);
        argmax(self.logits())
    }

    /// Batched forward: `imgs` holds one sample per **row** (B, input_len);
    /// logits land one sample per row in `logits` (B, classes).
    /// Bit-identical per sample to [`Self::forward`] with static scales —
    /// the batch dimension only adds GEMM columns (see [`BatchBufs`]).
    /// Returns the Fig. 2 overflow count summed over the batch.
    pub fn forward_batch(&mut self, imgs: &Mat, prune: Option<&PruneState>,
                         logits: &mut Mat) -> u32 {
        let b = imgs.rows;
        assert_eq!(logits.rows, b, "forward_batch: logits rows != batch");
        assert_eq!(logits.cols, self.spec.num_classes(),
                   "forward_batch: logits cols != classes");
        if b == 0 {
            return 0;
        }
        self.forward_batch_core(imgs, prune);
        let bw = self.batch.as_ref().expect("batch bufs live after core");
        logits
            .data
            .copy_from_slice(&bw.x_a[..b * self.spec.num_classes()]);
        bw.ovf.iter().sum()
    }

    /// Shared body of [`Self::forward_batch`] / [`Self::step_priot_chunk`]:
    /// run the batched forward, leaving the final activations sample-major
    /// in `bw.x_a`, per-sample overflow counts in `bw.ovf`, and the full
    /// batched tape (`cols`/`relu`/`pool_idx`) in the batch buffers.
    fn forward_batch_core(&mut self, imgs: &Mat, prune: Option<&PruneState>) {
        let b = imgs.rows;
        debug_assert!(b > 0);
        assert_eq!(imgs.cols, self.spec.input_len(),
                   "forward_batch: sample length != model input");
        if self.batch.as_ref().map(|bw| bw.b) != Some(b) {
            self.batch = Some(BatchBufs::new(&self.spec, b));
            // Keep the kernel scratch at the planned worst case for this
            // batch size (grow-only; `plan == probe` pins the geometry).
            let (ae, be) = plan::BufferPlan::of(&self.spec).scratch_elems(b);
            self.kernels.reserve(ae, be);
        }
        let mut bw = self.batch.take().expect("batch bufs just ensured");
        let n_layers = self.spec.layers.len();
        bw.ovf.iter_mut().for_each(|v| *v = 0);
        bw.x_a[..imgs.data.len()].copy_from_slice(&imgs.data);
        let mut in_len = self.spec.input_len();
        for li in 0..n_layers {
            if prune.is_some() {
                self.effective_weight(li, prune);
            }
            let layer = self.spec.layers[li];
            let last = li == n_layers - 1;
            let (f, k) = layer.weight_shape();
            let n = match layer {
                LayerSpec::Conv { in_h, in_w, .. } => in_h * in_w,
                LayerSpec::Fc { .. } => 1,
            };
            let bn = n * b;
            // Assemble the batched GEMM input: per-sample im2col patches
            // (conv) or the input vector (fc), side by side column-wise.
            let cols = &mut bw.cols[li];
            match layer {
                LayerSpec::Conv { in_c, in_h, in_w, .. } => {
                    let scratch = &mut bw.scratch[li];
                    for bi in 0..b {
                        let x = &bw.x_a[bi * in_len..(bi + 1) * in_len];
                        im2col(x, in_c, in_h, in_w, scratch);
                        for ki in 0..k {
                            cols.data[ki * bn + bi * n..ki * bn + (bi + 1) * n]
                                .copy_from_slice(
                                    &scratch.data[ki * n..(ki + 1) * n],
                                );
                        }
                    }
                }
                LayerSpec::Fc { .. } => {
                    for bi in 0..b {
                        let x = &bw.x_a[bi * in_len..(bi + 1) * in_len];
                        for (ki, &v) in x.iter().enumerate() {
                            cols.data[ki * b + bi] = v;
                        }
                    }
                }
            }
            let w_fwd: &Mat = if prune.is_some() {
                &self.ws.layers[li].weff
            } else {
                &self.weights[li]
            };
            let acc = &mut bw.acc[li];
            self.kernels.gemm_nn(w_fwd, cols, acc);
            if let Some(p) = self.probe.as_mut() {
                p.observe(li, &acc.data);
            }
            let s = self.scales.layers[li].fwd;
            let relu_flag = match layer {
                LayerSpec::Conv { relu, .. } => relu,
                LayerSpec::Fc { relu, .. } => relu,
            };
            let relu_buf = &mut bw.relu[li];
            if last {
                // Overflow is attributed per sample: flat index
                // `fi·bn + bi·n + j` belongs to sample `(idx % bn) / n`.
                for (idx, (o, &a)) in relu_buf[..f * bn]
                    .iter_mut()
                    .zip(acc.data.iter())
                    .enumerate()
                {
                    let y = rshift_round(a, s);
                    if y.abs() > INT8_MAX {
                        bw.ovf[(idx % bn) / n] += 1;
                    }
                    let y = clamp8(y);
                    *o = if relu_flag { y.max(0) } else { y };
                }
            } else {
                for (o, &a) in
                    relu_buf[..f * bn].iter_mut().zip(acc.data.iter())
                {
                    let y = rshift_round(a, s);
                    let y = clamp8(y);
                    *o = if relu_flag { y.max(0) } else { y };
                }
            }
            // Scatter back to the sample-major layout (pooling per sample).
            let out_len = layer.out_len();
            match layer {
                LayerSpec::Conv { in_h, in_w, out_c, pool, .. } => {
                    for bi in 0..b {
                        let g = &mut bw.gather[..f * n];
                        for fi in 0..f {
                            g[fi * n..(fi + 1) * n].copy_from_slice(
                                &relu_buf[fi * bn + bi * n..fi * bn + (bi + 1) * n],
                            );
                        }
                        let dst = &mut bw.x_b[bi * out_len..(bi + 1) * out_len];
                        if pool {
                            let idx = &mut bw.pool_idx[li]
                                [bi * out_len..(bi + 1) * out_len];
                            maxpool2(g, out_c, in_h, in_w, dst, idx);
                        } else {
                            dst.copy_from_slice(g);
                        }
                    }
                }
                LayerSpec::Fc { out_f, .. } => {
                    for bi in 0..b {
                        let dst = &mut bw.x_b[bi * out_len..(bi + 1) * out_len];
                        for (fi, d) in dst.iter_mut().enumerate().take(out_f) {
                            *d = relu_buf[fi * b + bi];
                        }
                    }
                }
            }
            core::mem::swap(&mut bw.x_a, &mut bw.x_b);
            in_len = out_len;
        }
        self.batch = Some(bw);
    }

    /// Batched inference: one prediction per row of `imgs` — bit-identical
    /// to a per-row [`Self::predict`] loop.
    pub fn predict_batch(&mut self, imgs: &Mat, prune: Option<&PruneState>)
                         -> Vec<usize> {
        let classes = self.spec.num_classes();
        let mut logits = Mat::zeros(imgs.rows, classes);
        self.forward_batch(imgs, prune, &mut logits);
        (0..imgs.rows).map(|bi| argmax(logits.row(bi))).collect()
    }

    /// Backward pass from `dlogits` (already in `ws.dlogits`); fills each
    /// layer's raw int32 `grad` accumulator.  `dynamic` recomputes the
    /// δx shifts NITI-style.  `sparse_masks`: PRIOT-S fast path — compute
    /// δW only for scored edges (per-edge dot products instead of the dense
    /// GEMM; unscored entries are left stale but are never read, their
    /// updates being masked to zero).  This is the paper's Table II claim
    /// that PRIOT-S beats even static-NITI on step time ("small number of
    /// parameter gradients to be calculated").
    fn backward(&mut self, dynamic: bool) {
        self.backward_inner(dynamic, None)
    }

    fn backward_sparse(&mut self, masks: &[Vec<i32>]) {
        self.backward_inner(false, Some(masks))
    }

    fn backward_inner(&mut self, dynamic: bool,
                      sparse_masks: Option<&[Vec<i32>]>) {
        let n_layers = self.spec.layers.len();
        // dy starts as dlogits.
        let nc = self.spec.num_classes();
        self.ws.dy_a[..nc].copy_from_slice(&self.ws.dlogits);
        let mut cur_len = nc;
        for li in (0..n_layers).rev() {
            let layer = self.spec.layers[li];
            let (head, tail) = self.ws.layers.split_at_mut(li);
            let buf = &mut tail[0];
            let w = &self.weights[li]; // unmasked W in backward (paper mod)
            let sc = self.scales.layers[li];
            match layer {
                LayerSpec::Conv { in_c, in_h, in_w, out_c, relu, pool } => {
                    let hw = in_h * in_w;
                    if pool {
                        // dy (out_c, h/2, w/2) -> scatter to (out_c, h, w)
                        maxpool2_backward(&self.ws.dy_a[..cur_len], &buf.pool_idx,
                                          out_c, in_h, in_w, &mut self.ws.dy_b);
                        core::mem::swap(&mut self.ws.dy_a, &mut self.ws.dy_b);
                        cur_len = out_c * hw;
                    }
                    let dy = &mut self.ws.dy_a[..cur_len];
                    if relu {
                        for (d, &r) in dy.iter_mut().zip(buf.relu_out.iter()) {
                            if r <= 0 {
                                *d = 0;
                            }
                        }
                    }
                    let dy_mat = Mat::from_vec(out_c, hw, dy.to_vec());
                    match sparse_masks {
                        None => {
                            self.kernels.gemm_nt(&dy_mat, &buf.cols,
                                                 &mut buf.grad)
                        }
                        Some(masks) => {
                            sparse_grad(&dy_mat, &buf.cols, &masks[li],
                                        &mut buf.grad)
                        }
                    }
                    if li > 0 {
                        self.kernels.gemm_tn(w, &dy_mat, &mut buf.dcols);
                        col2im(&buf.dcols, in_c, in_h, in_w, &mut buf.dx32);
                        let s = if dynamic {
                            dynamic_shift_for(max_abs(&buf.dx32))
                        } else {
                            sc.bwd
                        };
                        let prev_out_len = head[li - 1].out.len();
                        debug_assert_eq!(prev_out_len, buf.dx32.len());
                        for (o, &v) in self.ws.dy_a[..buf.dx32.len()]
                            .iter_mut()
                            .zip(buf.dx32.iter())
                        {
                            *o = requant(v, s);
                        }
                        cur_len = buf.dx32.len();
                    }
                }
                LayerSpec::Fc { in_f, out_f, relu } => {
                    let dy = &mut self.ws.dy_a[..cur_len];
                    if relu {
                        for (d, &r) in dy.iter_mut().zip(buf.relu_out.iter()) {
                            if r <= 0 {
                                *d = 0;
                            }
                        }
                    }
                    // grad = outer(dy, x): (out_f, in_f)
                    match sparse_masks {
                        None => {
                            for i in 0..out_f {
                                let di = dy[i];
                                let row = buf.grad.row_mut(i);
                                if di == 0 {
                                    row.iter_mut().for_each(|v| *v = 0);
                                } else {
                                    for (g, &xv) in
                                        row.iter_mut().zip(buf.cols.data.iter())
                                    {
                                        *g = di * xv;
                                    }
                                }
                            }
                        }
                        Some(masks) => {
                            let m = &masks[li];
                            for i in 0..out_f {
                                let di = dy[i];
                                let row = buf.grad.row_mut(i);
                                let mrow = &m[i * in_f..(i + 1) * in_f];
                                // NB: scored entries must be written even
                                // when di == 0 — the grad buffer is reused
                                // across steps and stale values would leak
                                // into the score update (caught by the
                                // parity suite).
                                for k in 0..in_f {
                                    if mrow[k] != 0 {
                                        row[k] = di * buf.cols.data[k];
                                    }
                                }
                            }
                        }
                    }
                    if li > 0 {
                        // dx32 = Wᵀ·dy
                        buf.dx32.iter_mut().for_each(|v| *v = 0);
                        for i in 0..out_f {
                            let di = dy[i];
                            if di == 0 {
                                continue;
                            }
                            let wrow = w.row(i);
                            for (o, &wv) in buf.dx32.iter_mut().zip(wrow.iter()) {
                                *o += di * wv;
                            }
                        }
                        let s = if dynamic {
                            dynamic_shift_for(max_abs(&buf.dx32))
                        } else {
                            sc.bwd
                        };
                        for (o, &v) in self.ws.dy_a[..buf.dx32.len()]
                            .iter_mut()
                            .zip(buf.dx32.iter())
                        {
                            *o = requant(v, s);
                        }
                        cur_len = buf.dx32.len();
                    }
                }
            }
        }
    }

    /// One NITI training step (weight update, stochastically rounded).
    pub fn step_niti(&mut self, img: &[i32], label: usize, dynamic: bool,
                     step: u32) -> StepOut {
        let (overflow, _) = self.forward(img, None, dynamic);
        let logits = self.logits().to_vec();
        int_softmax_grad(&logits, label, &mut self.ws.dlogits);
        self.backward(dynamic);
        // Copy-on-write: clones the backbone only if another session still
        // shares it (see the `Engine` docs).
        let weights = Arc::make_mut(&mut self.weights);
        for li in 0..self.spec.layers.len() {
            let g = &self.ws.layers[li].grad;
            let mut s = self.scales.layers[li].grad;
            if dynamic {
                s = dynamic_shift_for(max_abs(&g.data));
            }
            let s = s + self.scales.lr_shift;
            let base = (li as u32) << 24;
            let w = &mut weights[li];
            for (i, (wv, &gv)) in
                w.data.iter_mut().zip(g.data.iter()).enumerate()
            {
                let upd = stochastic_requant(gv, s, step, base + i as u32);
                *wv = clamp8(*wv - upd);
            }
        }
        StepOut { logits, overflow }
    }

    /// One PRIOT / PRIOT-S training step (score update; weights frozen).
    ///
    /// `sr` enables NITI-style stochastic rounding on the score step
    /// (deterministic by default — ablation bench covers the difference).
    /// `sparse` activates the PRIOT-S fast path: δW and score updates are
    /// only computed for scored edges (bit-identical results, since
    /// unscored updates are zero by definition).
    #[allow(clippy::too_many_arguments)]
    pub fn step_priot(&mut self, img: &[i32], label: usize,
                      scores: &mut [Vec<i32>], masks: &[Vec<i32>], theta: i32,
                      step: u32, sr: bool, sparse: bool) -> StepOut {
        let (overflow, _) = {
            let prune = PruneState { scores, masks, theta };
            self.forward(img, Some(&prune), false)
        };
        let logits = self.logits().to_vec();
        int_softmax_grad(&logits, label, &mut self.ws.dlogits);
        if sparse {
            self.backward_sparse(masks);
        } else {
            self.backward(false);
        }
        self.update_scores(scores, masks, theta, step, sr);
        StepOut { logits, overflow }
    }

    /// Apply one sample's PRIOT score update from the gradients sitting in
    /// the workspace (the tail of [`Self::step_priot`], factored out so
    /// the chunked path shares it).  Returns `true` if any scored edge
    /// crossed θ — i.e. the mask pattern `m·(s < θ)` the forward pass
    /// reads actually changed, which is what invalidates a batched
    /// forward of later samples.
    fn update_scores(&self, scores: &mut [Vec<i32>], masks: &[Vec<i32>],
                     theta: i32, step: u32, sr: bool) -> bool {
        let mut flipped = false;
        for li in 0..self.spec.layers.len() {
            let g = &self.ws.layers[li].grad;
            let sc = self.scales.layers[li];
            let shift = sc.score + self.scales.score_lr_shift;
            let base = (li as u32) << 24;
            let w = &self.weights[li];
            let sl = &mut scores[li];
            let ml = &masks[li];
            for i in 0..g.data.len() {
                if ml[i] == 0 {
                    continue; // unscored edge: update is zero by definition
                }
                // §Perf: zero gradient ⇒ zero update in both rounding modes
                // (requant(0)=0; SR: (0+r)>>s = 0 since r < 2^s) — skip.
                // ReLU masks and sparse δy make this the common case.  The
                // SR hash is counter-based, so skipping consumes nothing.
                if g.data[i] == 0 {
                    continue;
                }
                let g8 = requant(g.data[i], sc.grad);
                let ds = w.data[i] * g8; // |.| ≤ 127² — safe
                let upd = if sr {
                    stochastic_requant(ds, shift, step, base + i as u32)
                } else {
                    requant(ds, shift)
                };
                let old = sl[i];
                let new = clamp8(old - upd);
                if (old < theta) != (new < theta) {
                    flipped = true;
                }
                sl[i] = new;
            }
        }
        flipped
    }

    /// Gather sample `bi`'s forward tape out of the batched buffers into
    /// the per-sample [`Workspace`], so the batch-1 backward runs on it
    /// unchanged.  The batched forward is bit-identical per sample, so
    /// the gathered tape is exactly what [`Self::forward`] would have
    /// recorded.  (Associated fn, not a method: the caller holds the
    /// [`BatchBufs`] outside `self` while iterating samples.)
    fn load_tape(spec: &NetSpec, ws: &mut Workspace, bw: &BatchBufs,
                 bi: usize) {
        let b = bw.b;
        for (li, l) in spec.layers.iter().enumerate() {
            let (f, k) = l.weight_shape();
            let n = match *l {
                LayerSpec::Conv { in_h, in_w, .. } => in_h * in_w,
                LayerSpec::Fc { .. } => 1,
            };
            let bn = n * b;
            let buf = &mut ws.layers[li];
            for ki in 0..k {
                buf.cols.row_mut(ki).copy_from_slice(
                    &bw.cols[li].data[ki * bn + bi * n..ki * bn + (bi + 1) * n],
                );
            }
            for fi in 0..f {
                buf.relu_out[fi * n..(fi + 1) * n].copy_from_slice(
                    &bw.relu[li][fi * bn + bi * n..fi * bn + (bi + 1) * n],
                );
            }
            if !buf.pool_idx.is_empty() {
                let ol = l.out_len();
                buf.pool_idx
                    .copy_from_slice(&bw.pool_idx[li][bi * ol..(bi + 1) * ol]);
            }
        }
    }

    /// Chunked PRIOT / PRIOT-S training: one batched forward over the
    /// whole chunk (`imgs`: one sample per row), then per-sample backward
    /// + score updates replaying each sample's tape from the batch
    /// buffers.  Per the paper's device protocol the *updates* stay
    /// strictly sequential batch-1 steps — only the forward passes are
    /// batched, which is sound because the forward reads scores solely
    /// through the mask pattern `m·(s < θ)`:
    ///
    /// * while updates never cross θ, sample `i+1`'s batched forward
    ///   (computed from the pre-chunk scores) equals what a fresh forward
    ///   after sample `i`'s update would produce — bit-identical to
    ///   [`Self::step_priot`] called in a loop;
    /// * the first update that *does* flip an edge invalidates the
    ///   remaining samples' batched forward, so the method stops and
    ///   returns how many samples it consumed (≥ 1); the caller falls
    ///   back to per-sample steps for the rest of the chunk.
    ///
    /// `step0` is the step counter for the first sample; sample `bi` uses
    /// `step0 + bi` (the SR hash consumes the same counters as the
    /// sequential loop).  One [`StepOut`] per consumed sample is appended
    /// to `outs`.
    #[allow(clippy::too_many_arguments)]
    pub fn step_priot_chunk(&mut self, imgs: &Mat, labels: &[usize],
                            scores: &mut [Vec<i32>], masks: &[Vec<i32>],
                            theta: i32, step0: u32, sr: bool, sparse: bool,
                            outs: &mut Vec<StepOut>) -> usize {
        let b = imgs.rows;
        assert_eq!(labels.len(), b, "step_priot_chunk: labels != batch rows");
        if b == 0 {
            return 0;
        }
        {
            let prune = PruneState { scores, masks, theta };
            self.forward_batch_core(imgs, Some(&prune));
        }
        let bw = self.batch.take().expect("batch bufs live after core");
        let classes = self.spec.num_classes();
        let mut consumed = b;
        for bi in 0..b {
            Self::load_tape(&self.spec, &mut self.ws, &bw, bi);
            let logits = bw.x_a[bi * classes..(bi + 1) * classes].to_vec();
            int_softmax_grad(&logits, labels[bi], &mut self.ws.dlogits);
            if sparse {
                self.backward_sparse(masks);
            } else {
                self.backward(false);
            }
            let flipped =
                self.update_scores(scores, masks, theta, step0 + bi as u32, sr);
            outs.push(StepOut { logits, overflow: bw.ovf[bi] });
            if flipped && bi + 1 < b {
                #[cfg(feature = "obs")]
                {
                    self.theta_fallbacks = self.theta_fallbacks.saturating_add(1);
                }
                consumed = bi + 1;
                break;
            }
        }
        self.batch = Some(bw);
        consumed
    }

    /// Calibration sweep (paper §IV-A): run dynamic fwd/bwd over the given
    /// samples, vote each observed shift into histograms, return the modal
    /// static scales (weights are not updated).  Mirrors
    /// `intnet.IntNet.calibrate` including the skip-zero-tensors rule.
    pub fn calibrate(&mut self, images: &[Vec<i32>], labels: &[usize])
                     -> Scales {
        use crate::quant::ShiftHistogram;
        let nl = self.spec.layers.len();
        let mut h_fwd = vec![ShiftHistogram::new(); nl];
        let mut h_bwd = vec![ShiftHistogram::new(); nl];
        let mut h_grad = vec![ShiftHistogram::new(); nl];
        let mut h_score = vec![ShiftHistogram::new(); nl];
        for (img, &label) in images.iter().zip(labels.iter()) {
            let (_, dyn_fwd) = self.forward(img, None, true);
            for (li, &s) in dyn_fwd.iter().enumerate() {
                h_fwd[li].record(s);
            }
            let logits = self.logits().to_vec();
            int_softmax_grad(&logits, label, &mut self.ws.dlogits);
            // static backward for grad/score votes (matches the oracle)
            self.backward(false);
            for li in 0..nl {
                let g = &self.ws.layers[li].grad;
                let m = max_abs(&g.data);
                if m > 0 {
                    let s = dynamic_shift_for(m);
                    h_grad[li].record(s);
                    let w = &self.weights[li];
                    let mut md = 0i32;
                    for i in 0..g.data.len() {
                        let g8 = requant(g.data[i], s);
                        md = md.max((w.data[i] * g8).abs());
                    }
                    if md > 0 {
                        h_score[li].record(dynamic_shift_for(md));
                    }
                }
            }
            // dynamic backward for bwd votes
            int_softmax_grad(&logits, label, &mut self.ws.dlogits);
            self.backward(true);
            for li in 1..nl {
                let m = max_abs(&self.ws.layers[li].dx32);
                if m > 0 {
                    h_bwd[li].record(dynamic_shift_for(m));
                }
            }
        }
        let mut out = (*self.scales).clone();
        for li in 0..nl {
            if h_fwd[li].total() > 0 {
                out.layers[li].fwd = h_fwd[li].mode();
            }
            if h_bwd[li].total() > 0 {
                out.layers[li].bwd = h_bwd[li].mode();
            }
            if h_grad[li].total() > 0 {
                out.layers[li].grad = h_grad[li].mode();
            }
            if h_score[li].total() > 0 {
                out.layers[li].score = h_score[li].mode();
            }
        }
        out
    }
}

/// PRIOT-S sparse weight-gradient: per-edge dot products for scored edges
/// only.  `dy` (F, N), `cols` (K, N), `mask`/`grad` (F, K).
// Lint wall: same audited MAC contract as the dense GEMMs (δy·x over N
// int8-range terms per edge — strictly tighter than the forward bound).
#[allow(clippy::arithmetic_side_effects)]
fn sparse_grad(dy: &Mat, cols: &Mat, mask: &[i32], grad: &mut Mat) {
    let (f, k, n) = (dy.rows, cols.rows, dy.cols);
    debug_assert_eq!(cols.cols, n);
    debug_assert_eq!(grad.rows * grad.cols, f * k);
    debug_assert_eq!(mask.len(), f * k);
    for fi in 0..f {
        let dyr = dy.row(fi);
        for ki in 0..k {
            if mask[fi * k + ki] == 0 {
                continue;
            }
            let colr = cols.row(ki);
            let mut acc = 0i32;
            for (&a, &b) in dyr.iter().zip(colr.iter()) {
                acc += a * b;
            }
            grad.data[fi * k + ki] = acc;
        }
    }
}

/// First-max argmax (ties to the lowest index, as everywhere else).
pub fn argmax(xs: &[i32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

// Lint wall: tests exercise arithmetic freely (oracle replicas etc.).
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests;
