//! The "picoengine": a pure-Rust, batch-1, integer-only training engine —
//! the device-side implementation of the paper (the authors' C++ on the
//! Raspberry Pi Pico), bit-identical to the numpy oracle
//! (`python/compile/intnet.py`) and to the AOT JAX graphs.
//!
//! All activations/weights/scores are int8-range values in `i32` working
//! buffers; every MAC accumulates in int32; requantization is the shared
//! round-half-up shift (`quant::rshift_round`), except NITI's update step
//! which uses counter-based stochastic rounding (`quant::stochastic_requant`).
//!
//! The hot path is allocation-free: all tape and gradient buffers live in
//! the [`Workspace`], sized once from the [`NetSpec`].
//!
//! ## Arithmetic lint wall
//!
//! This module is inside the `priot::audit` soundness perimeter: implicit
//! arithmetic is denied (`clippy::arithmetic_side_effects`), and every
//! block that intentionally does raw `+`/`*` carries a scoped, documented
//! `#[allow]`.  The point is that *new* arithmetic cannot sneak into the
//! integer hot path without either a review note or a static bound from
//! `priot::audit` — the i32 MAC accumulation here is exactly the contract
//! the auditor proves (`K·127·127` per row plus the rounding bias fits
//! i32, see `audit::Verdict`).

#![deny(clippy::arithmetic_side_effects)]

pub mod plan;

use alloc::sync::Arc;
use alloc::vec;
use alloc::vec::Vec;

use crate::bail;
use crate::error::Result;
use crate::quant::{
    clamp8, dynamic_shift_for, int_softmax_grad, max_abs, requant, rshift_round,
    stochastic_requant, Scales,
};
use crate::serial::TensorI8;
use crate::spec::{LayerSpec, NetSpec};
use crate::tensor::{
    col2im, gemm_nn, gemm_nt, gemm_tn, im2col, maxpool2, maxpool2_backward, Mat,
};
use crate::INT8_MAX;

/// Result of one forward or training step.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub logits: Vec<i32>,
    /// # of final-layer outputs exceeding the int8 range before clamping
    /// (the Fig. 2 probe).
    pub overflow: u32,
}

/// Per-layer tape + scratch buffers (preallocated; reused every step).
struct LayerBufs {
    /// Forward GEMM input: im2col patches (conv) or the input vector (fc),
    /// stored as (K, N) with N = H·W for conv, 1 for fc.
    cols: Mat,
    /// Raw int32 forward accumulator (F, N).
    acc: Mat,
    /// Post-relu, pre-pool activation (len F·N).
    relu_out: Vec<i32>,
    /// 2×2 argmax indices (conv+pool layers only).
    pool_idx: Vec<u8>,
    /// Layer output after pool (input of the next layer).
    out: Vec<i32>,
    /// Effective (masked) weight for the forward pass.
    weff: Mat,
    /// Weight-gradient accumulator δy·xᵀ (F, K).
    grad: Mat,
    /// δx int32 accumulator (len of layer input).
    dx32: Vec<i32>,
    /// δcols scratch for conv backward (K, N).
    dcols: Mat,
}

/// Workspace: per-layer buffers + the backward delta ping-pong buffers.
pub struct Workspace {
    layers: Vec<LayerBufs>,
    dy_a: Vec<i32>,
    dy_b: Vec<i32>,
    dlogits: Vec<i32>,
}

// Lint wall: buffer-sizing products over spec dims; an overflow here would
// fail the allocation loudly, never corrupt training arithmetic.
#[allow(clippy::arithmetic_side_effects)]
impl Workspace {
    pub fn new(spec: &NetSpec) -> Self {
        let mut layers = Vec::with_capacity(spec.layers.len());
        let mut max_len = spec.input_len();
        for l in &spec.layers {
            let (f, k) = l.weight_shape();
            let (n, pre_pool_len, pooled) = match *l {
                LayerSpec::Conv { in_h, in_w, out_c, pool, .. } => {
                    (in_h * in_w, out_c * in_h * in_w, pool)
                }
                LayerSpec::Fc { out_f, .. } => (1, out_f, false),
            };
            layers.push(LayerBufs {
                cols: Mat::zeros(k, n),
                acc: Mat::zeros(f, n),
                relu_out: vec![0; pre_pool_len],
                pool_idx: vec![0; if pooled { pre_pool_len / 4 } else { 0 }],
                out: vec![0; l.out_len()],
                weff: Mat::zeros(f, k),
                grad: Mat::zeros(f, k),
                dx32: vec![0; l.in_len()],
                dcols: Mat::zeros(k, n),
            });
            max_len = max_len.max(pre_pool_len).max(l.in_len());
        }
        Workspace {
            layers,
            dy_a: vec![0; max_len],
            dy_b: vec![0; max_len],
            dlogits: vec![0; spec.num_classes()],
        }
    }
}

/// Pruning state passed to forward: scores + PRIOT-S existence masks + θ.
pub struct PruneState<'a> {
    pub scores: &'a [Vec<i32>],
    pub masks: &'a [Vec<i32>],
    pub theta: i32,
}

/// Buffers for the batched inference path, allocated on first use and
/// rebuilt when the batch size changes.  Batch-B forward is the batch-1
/// forward with B samples laid side by side along the GEMM column axis:
/// per-column arithmetic is untouched, so results are bit-identical to B
/// calls of [`Engine::forward`] while the weight matrix streams through
/// the cache once per layer instead of once per sample (and the FC layers
/// hit the `gemm_nn` n>1 kernel instead of the GEMV path).
struct BatchBufs {
    b: usize,
    /// Per-layer scratch for one sample's im2col patches (K, N).
    scratch: Vec<Mat>,
    /// Per-layer batched GEMM input (K, B·N): sample `bi` occupies columns
    /// `[bi·N, (bi+1)·N)`.
    cols: Vec<Mat>,
    /// Per-layer batched int32 accumulator (F, B·N).
    acc: Vec<Mat>,
    /// Per-layer post-requant/relu activations (F·B·N).
    relu: Vec<Vec<i32>>,
    /// One sample's pre-pool activation gathered channel-major (max F·N).
    gather: Vec<i32>,
    /// Pool argmax scratch (inference records no tape).
    pool_idx: Vec<u8>,
    /// Ping-pong sample-major activation buffers (B · max layer len).
    x_a: Vec<i32>,
    x_b: Vec<i32>,
}

// Lint wall: same buffer-sizing arithmetic as `Workspace` (batch-scaled).
#[allow(clippy::arithmetic_side_effects)]
impl BatchBufs {
    fn new(spec: &NetSpec, b: usize) -> Self {
        let mut scratch = Vec::with_capacity(spec.layers.len());
        let mut cols = Vec::with_capacity(spec.layers.len());
        let mut acc = Vec::with_capacity(spec.layers.len());
        let mut relu = Vec::with_capacity(spec.layers.len());
        let mut max_pre = 0usize;
        let mut max_len = spec.input_len();
        for l in &spec.layers {
            let (f, k) = l.weight_shape();
            let n = match *l {
                LayerSpec::Conv { in_h, in_w, .. } => in_h * in_w,
                LayerSpec::Fc { .. } => 1,
            };
            scratch.push(Mat::zeros(k, n));
            cols.push(Mat::zeros(k, n * b));
            acc.push(Mat::zeros(f, n * b));
            relu.push(vec![0; f * n * b]);
            max_pre = max_pre.max(f * n);
            max_len = max_len.max(l.out_len());
        }
        BatchBufs {
            b,
            scratch,
            cols,
            acc,
            relu,
            gather: vec![0; max_pre],
            pool_idx: vec![0; max_pre / 4],
            x_a: vec![0; b * max_len],
            x_b: vec![0; b * max_len],
        }
    }
}

/// The integer network engine.
///
/// Backbone weights and the static scale table are held behind `Arc` so a
/// host-side `Fleet` of concurrent sessions shares one copy of the
/// read-only backbone.  NITI (which *does* update weights) transparently
/// copies-on-write via [`Arc::make_mut`] — a lone session mutates in place,
/// a fleet session forks its own diverging copy on the first update.
pub struct Engine {
    pub spec: NetSpec,
    pub scales: Arc<Scales>,
    pub weights: Arc<Vec<Mat>>,
    ws: Workspace,
    /// Batched-inference buffers (lazy; see [`BatchBufs`]).
    batch: Option<BatchBufs>,
    /// Optional runtime accumulator probe (see [`AccProbe`]); off by
    /// default — the observe loop never runs on the production path.
    probe: Option<AccProbe>,
}

/// Per-layer min/max of the raw i32 forward accumulator, observed at the
/// GEMM output before requantization — the runtime cross-check for the
/// static bounds `priot::audit` derives (`tests/audit.rs` asserts every
/// observed extreme lies inside its proven interval).
///
/// Deliberately arithmetic-free (min/max folds only): this type lives
/// inside the lint wall with no `#[allow]` — the deny verifies it.
#[derive(Clone, Debug)]
pub struct AccProbe {
    /// Per-layer smallest accumulator seen (`i32::MAX` until observed).
    pub min: Vec<i32>,
    /// Per-layer largest accumulator seen (`i32::MIN` until observed).
    pub max: Vec<i32>,
}

impl AccProbe {
    fn new(n_layers: usize) -> Self {
        Self { min: vec![i32::MAX; n_layers], max: vec![i32::MIN; n_layers] }
    }

    /// True once layer `li` has observed at least one accumulator value.
    pub fn observed(&self, li: usize) -> bool {
        self.min[li] <= self.max[li]
    }

    fn observe(&mut self, li: usize, acc: &[i32]) {
        let (mut lo, mut hi) = (self.min[li], self.max[li]);
        for &v in acc {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        self.min[li] = lo;
        self.max[li] = hi;
    }
}

fn check_shapes(spec: &NetSpec, weights: &[Mat], scales: &Scales) -> Result<()> {
    if weights.len() != spec.layers.len() {
        bail!("expected {} weight tensors, got {}", spec.layers.len(),
              weights.len());
    }
    if scales.layers.len() != spec.layers.len() {
        bail!("expected {} scale rows, got {}", spec.layers.len(),
              scales.layers.len());
    }
    for (li, (l, w)) in spec.layers.iter().zip(weights.iter()).enumerate() {
        let (r, c) = l.weight_shape();
        if w.rows != r || w.cols != c {
            bail!("layer {li}: weight shape ({},{}) != spec ({r},{c})",
                  w.rows, w.cols);
        }
    }
    Ok(())
}

// Lint wall: the audited integer hot path.  Every `+`/`*` below is i32 MAC
// accumulation or index arithmetic whose bounds `priot::audit` proves from
// the spec (per-row K·127·127 envelope + requant rounding bias ≤ i32::MAX);
// the runtime cross-check is `AccProbe` + the Fig. 2 overflow counters.
#[allow(clippy::arithmetic_side_effects)]
impl Engine {
    pub fn new(spec: NetSpec, weights: Vec<Mat>, scales: Scales) -> Result<Self> {
        Self::shared(spec, Arc::new(weights), Arc::new(scales))
    }

    /// Build against an already-shared backbone (the fleet path): no weight
    /// or scale data is copied, only the per-session workspace is allocated.
    pub fn shared(spec: NetSpec, weights: Arc<Vec<Mat>>, scales: Arc<Scales>)
                  -> Result<Self> {
        check_shapes(&spec, &weights, &scales)?;
        let ws = Workspace::new(&spec);
        Ok(Self { spec, scales, weights, ws, batch: None, probe: None })
    }

    /// Start recording per-layer accumulator extremes (resets any prior
    /// probe).  Costs one min/max pass per GEMM output while enabled.
    pub fn probe_enable(&mut self) {
        self.probe = Some(AccProbe::new(self.spec.layers.len()));
    }

    /// Stop recording and return the observed extremes (if enabled).
    pub fn probe_take(&mut self) -> Option<AccProbe> {
        self.probe.take()
    }

    /// Build from the on-disk int8 tensors (artifacts).
    pub fn from_tensors(spec: NetSpec, tensors: &[TensorI8], scales: Scales)
                        -> Result<Self> {
        let weights = tensors
            .iter()
            .zip(spec.layers.iter())
            .map(|(t, l)| {
                let (r, c) = l.weight_shape();
                Mat::from_vec(r, c, t.to_i32())
            })
            .collect();
        Self::new(spec, weights, scales)
    }

    fn effective_weight(&mut self, li: usize, prune: Option<&PruneState>) {
        let w = &self.weights[li];
        let weff = &mut self.ws.layers[li].weff;
        match prune {
            None => weff.data.copy_from_slice(&w.data),
            Some(p) => {
                let (s, m) = (&p.scores[li], &p.masks[li]);
                for i in 0..w.data.len() {
                    // keep = 1 - m·(1 - (s >= θ)): unscored edges survive.
                    let keep = if m[i] != 0 && s[i] < p.theta { 0 } else { 1 };
                    weff.data[i] = w.data[i] * keep;
                }
            }
        }
    }

    /// Forward pass (records the tape in the workspace).
    ///
    /// Returns `(overflow, dyn_fwd_shifts)`; logits are left in
    /// `self.ws.layers.last().out`.
    pub fn forward(&mut self, img: &[i32], prune: Option<&PruneState>,
                   dynamic: bool) -> (u32, Vec<u32>) {
        debug_assert_eq!(img.len(), self.spec.input_len());
        let n_layers = self.spec.layers.len();
        let mut overflow = 0u32;
        let mut dyn_shifts = Vec::new();
        for li in 0..n_layers {
            // §Perf: skip the masked-weight copy entirely when nothing is
            // pruned (NITI paths) — the GEMM reads the weights in place.
            if prune.is_some() {
                self.effective_weight(li, prune);
            }
            let layer = self.spec.layers[li];
            let last = li == n_layers - 1;
            // Split borrows: previous layer's output is the input here.
            let (head, tail) = self.ws.layers.split_at_mut(li);
            let buf = &mut tail[0];
            let x: &[i32] = if li == 0 { img } else { &head[li - 1].out };
            match layer {
                LayerSpec::Conv { in_c, in_h, in_w, .. } => {
                    im2col(x, in_c, in_h, in_w, &mut buf.cols);
                }
                LayerSpec::Fc { .. } => {
                    buf.cols.data.copy_from_slice(x);
                }
            }
            let w_fwd: &Mat =
                if prune.is_some() { &buf.weff } else { &self.weights[li] };
            gemm_nn(w_fwd, &buf.cols, &mut buf.acc);
            if let Some(p) = self.probe.as_mut() {
                p.observe(li, &buf.acc.data);
            }
            let mut s = self.scales.layers[li].fwd;
            if dynamic {
                s = dynamic_shift_for(max_abs(&buf.acc.data));
                dyn_shifts.push(s);
            }
            // requant (+ relu) into relu_out; probe overflow on the last.
            let relu = match layer {
                LayerSpec::Conv { relu, .. } => relu,
                LayerSpec::Fc { relu, .. } => relu,
            };
            for (o, &a) in buf.relu_out.iter_mut().zip(buf.acc.data.iter()) {
                let y = rshift_round(a, s);
                if last && y.abs() > INT8_MAX {
                    overflow += 1;
                }
                let y = clamp8(y);
                *o = if relu { y.max(0) } else { y };
            }
            match layer {
                LayerSpec::Conv { in_c: _, in_h, in_w, out_c, pool, .. } if pool => {
                    maxpool2(&buf.relu_out, out_c, in_h, in_w, &mut buf.out,
                             &mut buf.pool_idx);
                }
                _ => buf.out.copy_from_slice(&buf.relu_out),
            }
        }
        (overflow, dyn_shifts)
    }

    pub fn logits(&self) -> &[i32] {
        &self.ws.layers.last().unwrap().out
    }

    /// Forward + argmax — the inference path.
    pub fn predict(&mut self, img: &[i32], prune: Option<&PruneState>) -> usize {
        self.forward(img, prune, false);
        argmax(self.logits())
    }

    /// Batched inference forward: `imgs` holds one sample per **row**
    /// (B, input_len); logits land one sample per row in `logits`
    /// (B, classes).  Bit-identical per sample to [`Self::forward`] with
    /// static scales — the batch dimension only adds GEMM columns (see
    /// [`BatchBufs`]).  Returns the Fig. 2 overflow count summed over the
    /// batch.  Records no tape: inference only.
    pub fn forward_batch(&mut self, imgs: &Mat, prune: Option<&PruneState>,
                         logits: &mut Mat) -> u32 {
        let b = imgs.rows;
        assert_eq!(imgs.cols, self.spec.input_len(),
                   "forward_batch: sample length != model input");
        assert_eq!(logits.rows, b, "forward_batch: logits rows != batch");
        assert_eq!(logits.cols, self.spec.num_classes(),
                   "forward_batch: logits cols != classes");
        if b == 0 {
            return 0;
        }
        if self.batch.as_ref().map(|bw| bw.b) != Some(b) {
            self.batch = Some(BatchBufs::new(&self.spec, b));
        }
        let mut bw = self.batch.take().expect("batch bufs just ensured");
        let n_layers = self.spec.layers.len();
        let mut overflow = 0u32;
        bw.x_a[..imgs.data.len()].copy_from_slice(&imgs.data);
        let mut in_len = self.spec.input_len();
        for li in 0..n_layers {
            if prune.is_some() {
                self.effective_weight(li, prune);
            }
            let layer = self.spec.layers[li];
            let last = li == n_layers - 1;
            let (f, k) = layer.weight_shape();
            let n = match layer {
                LayerSpec::Conv { in_h, in_w, .. } => in_h * in_w,
                LayerSpec::Fc { .. } => 1,
            };
            let bn = n * b;
            // Assemble the batched GEMM input: per-sample im2col patches
            // (conv) or the input vector (fc), side by side column-wise.
            let cols = &mut bw.cols[li];
            match layer {
                LayerSpec::Conv { in_c, in_h, in_w, .. } => {
                    let scratch = &mut bw.scratch[li];
                    for bi in 0..b {
                        let x = &bw.x_a[bi * in_len..(bi + 1) * in_len];
                        im2col(x, in_c, in_h, in_w, scratch);
                        for ki in 0..k {
                            cols.data[ki * bn + bi * n..ki * bn + (bi + 1) * n]
                                .copy_from_slice(
                                    &scratch.data[ki * n..(ki + 1) * n],
                                );
                        }
                    }
                }
                LayerSpec::Fc { .. } => {
                    for bi in 0..b {
                        let x = &bw.x_a[bi * in_len..(bi + 1) * in_len];
                        for (ki, &v) in x.iter().enumerate() {
                            cols.data[ki * b + bi] = v;
                        }
                    }
                }
            }
            let w_fwd: &Mat = if prune.is_some() {
                &self.ws.layers[li].weff
            } else {
                &self.weights[li]
            };
            let acc = &mut bw.acc[li];
            gemm_nn(w_fwd, cols, acc);
            if let Some(p) = self.probe.as_mut() {
                p.observe(li, &acc.data);
            }
            let s = self.scales.layers[li].fwd;
            let relu_flag = match layer {
                LayerSpec::Conv { relu, .. } => relu,
                LayerSpec::Fc { relu, .. } => relu,
            };
            let relu_buf = &mut bw.relu[li];
            for (o, &a) in relu_buf[..f * bn].iter_mut().zip(acc.data.iter()) {
                let y = rshift_round(a, s);
                if last && y.abs() > INT8_MAX {
                    overflow += 1;
                }
                let y = clamp8(y);
                *o = if relu_flag { y.max(0) } else { y };
            }
            // Scatter back to the sample-major layout (pooling per sample).
            let out_len = layer.out_len();
            match layer {
                LayerSpec::Conv { in_h, in_w, out_c, pool, .. } => {
                    for bi in 0..b {
                        let g = &mut bw.gather[..f * n];
                        for fi in 0..f {
                            g[fi * n..(fi + 1) * n].copy_from_slice(
                                &relu_buf[fi * bn + bi * n..fi * bn + (bi + 1) * n],
                            );
                        }
                        let dst = &mut bw.x_b[bi * out_len..(bi + 1) * out_len];
                        if pool {
                            let idx = &mut bw.pool_idx[..out_len];
                            maxpool2(g, out_c, in_h, in_w, dst, idx);
                        } else {
                            dst.copy_from_slice(g);
                        }
                    }
                }
                LayerSpec::Fc { out_f, .. } => {
                    for bi in 0..b {
                        let dst = &mut bw.x_b[bi * out_len..(bi + 1) * out_len];
                        for (fi, d) in dst.iter_mut().enumerate().take(out_f) {
                            *d = relu_buf[fi * b + bi];
                        }
                    }
                }
            }
            core::mem::swap(&mut bw.x_a, &mut bw.x_b);
            in_len = out_len;
        }
        logits
            .data
            .copy_from_slice(&bw.x_a[..b * self.spec.num_classes()]);
        self.batch = Some(bw);
        overflow
    }

    /// Batched inference: one prediction per row of `imgs` — bit-identical
    /// to a per-row [`Self::predict`] loop.
    pub fn predict_batch(&mut self, imgs: &Mat, prune: Option<&PruneState>)
                         -> Vec<usize> {
        let classes = self.spec.num_classes();
        let mut logits = Mat::zeros(imgs.rows, classes);
        self.forward_batch(imgs, prune, &mut logits);
        (0..imgs.rows)
            .map(|bi| argmax(&logits.data[bi * classes..(bi + 1) * classes]))
            .collect()
    }

    /// Backward pass from `dlogits` (already in `ws.dlogits`); fills each
    /// layer's raw int32 `grad` accumulator.  `dynamic` recomputes the
    /// δx shifts NITI-style.  `sparse_masks`: PRIOT-S fast path — compute
    /// δW only for scored edges (per-edge dot products instead of the dense
    /// GEMM; unscored entries are left stale but are never read, their
    /// updates being masked to zero).  This is the paper's Table II claim
    /// that PRIOT-S beats even static-NITI on step time ("small number of
    /// parameter gradients to be calculated").
    fn backward(&mut self, dynamic: bool) {
        self.backward_inner(dynamic, None)
    }

    fn backward_sparse(&mut self, masks: &[Vec<i32>]) {
        self.backward_inner(false, Some(masks))
    }

    fn backward_inner(&mut self, dynamic: bool,
                      sparse_masks: Option<&[Vec<i32>]>) {
        let n_layers = self.spec.layers.len();
        // dy starts as dlogits.
        let nc = self.spec.num_classes();
        self.ws.dy_a[..nc].copy_from_slice(&self.ws.dlogits);
        let mut cur_len = nc;
        for li in (0..n_layers).rev() {
            let layer = self.spec.layers[li];
            let (head, tail) = self.ws.layers.split_at_mut(li);
            let buf = &mut tail[0];
            let w = &self.weights[li]; // unmasked W in backward (paper mod)
            let sc = self.scales.layers[li];
            match layer {
                LayerSpec::Conv { in_c, in_h, in_w, out_c, relu, pool } => {
                    let hw = in_h * in_w;
                    if pool {
                        // dy (out_c, h/2, w/2) -> scatter to (out_c, h, w)
                        maxpool2_backward(&self.ws.dy_a[..cur_len], &buf.pool_idx,
                                          out_c, in_h, in_w, &mut self.ws.dy_b);
                        core::mem::swap(&mut self.ws.dy_a, &mut self.ws.dy_b);
                        cur_len = out_c * hw;
                    }
                    let dy = &mut self.ws.dy_a[..cur_len];
                    if relu {
                        for (d, &r) in dy.iter_mut().zip(buf.relu_out.iter()) {
                            if r <= 0 {
                                *d = 0;
                            }
                        }
                    }
                    let dy_mat = Mat::from_vec(out_c, hw, dy.to_vec());
                    match sparse_masks {
                        None => gemm_nt(&dy_mat, &buf.cols, &mut buf.grad),
                        Some(masks) => {
                            sparse_grad(&dy_mat, &buf.cols, &masks[li],
                                        &mut buf.grad)
                        }
                    }
                    if li > 0 {
                        gemm_tn(w, &dy_mat, &mut buf.dcols);
                        col2im(&buf.dcols, in_c, in_h, in_w, &mut buf.dx32);
                        let s = if dynamic {
                            dynamic_shift_for(max_abs(&buf.dx32))
                        } else {
                            sc.bwd
                        };
                        let prev_out_len = head[li - 1].out.len();
                        debug_assert_eq!(prev_out_len, buf.dx32.len());
                        for (o, &v) in self.ws.dy_a[..buf.dx32.len()]
                            .iter_mut()
                            .zip(buf.dx32.iter())
                        {
                            *o = requant(v, s);
                        }
                        cur_len = buf.dx32.len();
                    }
                }
                LayerSpec::Fc { in_f, out_f, relu } => {
                    let dy = &mut self.ws.dy_a[..cur_len];
                    if relu {
                        for (d, &r) in dy.iter_mut().zip(buf.relu_out.iter()) {
                            if r <= 0 {
                                *d = 0;
                            }
                        }
                    }
                    // grad = outer(dy, x): (out_f, in_f)
                    match sparse_masks {
                        None => {
                            for i in 0..out_f {
                                let di = dy[i];
                                let row =
                                    &mut buf.grad.data[i * in_f..(i + 1) * in_f];
                                if di == 0 {
                                    row.iter_mut().for_each(|v| *v = 0);
                                } else {
                                    for (g, &xv) in
                                        row.iter_mut().zip(buf.cols.data.iter())
                                    {
                                        *g = di * xv;
                                    }
                                }
                            }
                        }
                        Some(masks) => {
                            let m = &masks[li];
                            for i in 0..out_f {
                                let di = dy[i];
                                let row =
                                    &mut buf.grad.data[i * in_f..(i + 1) * in_f];
                                let mrow = &m[i * in_f..(i + 1) * in_f];
                                // NB: scored entries must be written even
                                // when di == 0 — the grad buffer is reused
                                // across steps and stale values would leak
                                // into the score update (caught by the
                                // parity suite).
                                for k in 0..in_f {
                                    if mrow[k] != 0 {
                                        row[k] = di * buf.cols.data[k];
                                    }
                                }
                            }
                        }
                    }
                    if li > 0 {
                        // dx32 = Wᵀ·dy
                        buf.dx32.iter_mut().for_each(|v| *v = 0);
                        for i in 0..out_f {
                            let di = dy[i];
                            if di == 0 {
                                continue;
                            }
                            let wrow = &w.data[i * in_f..(i + 1) * in_f];
                            for (o, &wv) in buf.dx32.iter_mut().zip(wrow.iter()) {
                                *o += di * wv;
                            }
                        }
                        let s = if dynamic {
                            dynamic_shift_for(max_abs(&buf.dx32))
                        } else {
                            sc.bwd
                        };
                        for (o, &v) in self.ws.dy_a[..buf.dx32.len()]
                            .iter_mut()
                            .zip(buf.dx32.iter())
                        {
                            *o = requant(v, s);
                        }
                        cur_len = buf.dx32.len();
                    }
                }
            }
        }
    }

    /// One NITI training step (weight update, stochastically rounded).
    pub fn step_niti(&mut self, img: &[i32], label: usize, dynamic: bool,
                     step: u32) -> StepOut {
        let (overflow, _) = self.forward(img, None, dynamic);
        let logits = self.logits().to_vec();
        int_softmax_grad(&logits, label, &mut self.ws.dlogits);
        self.backward(dynamic);
        // Copy-on-write: clones the backbone only if another session still
        // shares it (see the `Engine` docs).
        let weights = Arc::make_mut(&mut self.weights);
        for li in 0..self.spec.layers.len() {
            let g = &self.ws.layers[li].grad;
            let mut s = self.scales.layers[li].grad;
            if dynamic {
                s = dynamic_shift_for(max_abs(&g.data));
            }
            let s = s + self.scales.lr_shift;
            let base = (li as u32) << 24;
            let w = &mut weights[li];
            for (i, (wv, &gv)) in
                w.data.iter_mut().zip(g.data.iter()).enumerate()
            {
                let upd = stochastic_requant(gv, s, step, base + i as u32);
                *wv = clamp8(*wv - upd);
            }
        }
        StepOut { logits, overflow }
    }

    /// One PRIOT / PRIOT-S training step (score update; weights frozen).
    ///
    /// `sr` enables NITI-style stochastic rounding on the score step
    /// (deterministic by default — ablation bench covers the difference).
    /// `sparse` activates the PRIOT-S fast path: δW and score updates are
    /// only computed for scored edges (bit-identical results, since
    /// unscored updates are zero by definition).
    #[allow(clippy::too_many_arguments)]
    pub fn step_priot(&mut self, img: &[i32], label: usize,
                      scores: &mut [Vec<i32>], masks: &[Vec<i32>], theta: i32,
                      step: u32, sr: bool, sparse: bool) -> StepOut {
        let (overflow, _) = {
            let prune = PruneState { scores, masks, theta };
            self.forward(img, Some(&prune), false)
        };
        let logits = self.logits().to_vec();
        int_softmax_grad(&logits, label, &mut self.ws.dlogits);
        if sparse {
            self.backward_sparse(masks);
        } else {
            self.backward(false);
        }
        for li in 0..self.spec.layers.len() {
            let g = &self.ws.layers[li].grad;
            let sc = self.scales.layers[li];
            let shift = sc.score + self.scales.score_lr_shift;
            let base = (li as u32) << 24;
            let w = &self.weights[li];
            let sl = &mut scores[li];
            let ml = &masks[li];
            for i in 0..g.data.len() {
                if ml[i] == 0 {
                    continue; // unscored edge: update is zero by definition
                }
                // §Perf: zero gradient ⇒ zero update in both rounding modes
                // (requant(0)=0; SR: (0+r)>>s = 0 since r < 2^s) — skip.
                // ReLU masks and sparse δy make this the common case.  The
                // SR hash is counter-based, so skipping consumes nothing.
                if g.data[i] == 0 {
                    continue;
                }
                let g8 = requant(g.data[i], sc.grad);
                let ds = w.data[i] * g8; // |.| ≤ 127² — safe
                let upd = if sr {
                    stochastic_requant(ds, shift, step, base + i as u32)
                } else {
                    requant(ds, shift)
                };
                sl[i] = clamp8(sl[i] - upd);
            }
        }
        StepOut { logits, overflow }
    }

    /// Calibration sweep (paper §IV-A): run dynamic fwd/bwd over the given
    /// samples, vote each observed shift into histograms, return the modal
    /// static scales (weights are not updated).  Mirrors
    /// `intnet.IntNet.calibrate` including the skip-zero-tensors rule.
    pub fn calibrate(&mut self, images: &[Vec<i32>], labels: &[usize])
                     -> Scales {
        use crate::quant::ShiftHistogram;
        let nl = self.spec.layers.len();
        let mut h_fwd = vec![ShiftHistogram::new(); nl];
        let mut h_bwd = vec![ShiftHistogram::new(); nl];
        let mut h_grad = vec![ShiftHistogram::new(); nl];
        let mut h_score = vec![ShiftHistogram::new(); nl];
        for (img, &label) in images.iter().zip(labels.iter()) {
            let (_, dyn_fwd) = self.forward(img, None, true);
            for (li, &s) in dyn_fwd.iter().enumerate() {
                h_fwd[li].record(s);
            }
            let logits = self.logits().to_vec();
            int_softmax_grad(&logits, label, &mut self.ws.dlogits);
            // static backward for grad/score votes (matches the oracle)
            self.backward(false);
            for li in 0..nl {
                let g = &self.ws.layers[li].grad;
                let m = max_abs(&g.data);
                if m > 0 {
                    let s = dynamic_shift_for(m);
                    h_grad[li].record(s);
                    let w = &self.weights[li];
                    let mut md = 0i32;
                    for i in 0..g.data.len() {
                        let g8 = requant(g.data[i], s);
                        md = md.max((w.data[i] * g8).abs());
                    }
                    if md > 0 {
                        h_score[li].record(dynamic_shift_for(md));
                    }
                }
            }
            // dynamic backward for bwd votes
            int_softmax_grad(&logits, label, &mut self.ws.dlogits);
            self.backward(true);
            for li in 1..nl {
                let m = max_abs(&self.ws.layers[li].dx32);
                if m > 0 {
                    h_bwd[li].record(dynamic_shift_for(m));
                }
            }
        }
        let mut out = (*self.scales).clone();
        for li in 0..nl {
            if h_fwd[li].total() > 0 {
                out.layers[li].fwd = h_fwd[li].mode();
            }
            if h_bwd[li].total() > 0 {
                out.layers[li].bwd = h_bwd[li].mode();
            }
            if h_grad[li].total() > 0 {
                out.layers[li].grad = h_grad[li].mode();
            }
            if h_score[li].total() > 0 {
                out.layers[li].score = h_score[li].mode();
            }
        }
        out
    }
}

/// PRIOT-S sparse weight-gradient: per-edge dot products for scored edges
/// only.  `dy` (F, N), `cols` (K, N), `mask`/`grad` (F, K).
// Lint wall: same audited MAC contract as the dense GEMMs (δy·x over N
// int8-range terms per edge — strictly tighter than the forward bound).
#[allow(clippy::arithmetic_side_effects)]
fn sparse_grad(dy: &Mat, cols: &Mat, mask: &[i32], grad: &mut Mat) {
    let (f, k, n) = (dy.rows, cols.rows, dy.cols);
    debug_assert_eq!(cols.cols, n);
    debug_assert_eq!(grad.rows * grad.cols, f * k);
    debug_assert_eq!(mask.len(), f * k);
    for fi in 0..f {
        let dyr = &dy.data[fi * n..(fi + 1) * n];
        for ki in 0..k {
            if mask[fi * k + ki] == 0 {
                continue;
            }
            let colr = &cols.data[ki * n..(ki + 1) * n];
            let mut acc = 0i32;
            for (&a, &b) in dyr.iter().zip(colr.iter()) {
                acc += a * b;
            }
            grad.data[fi * k + ki] = acc;
        }
    }
}

/// First-max argmax (ties to the lowest index, as everywhere else).
pub fn argmax(xs: &[i32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

// Lint wall: tests exercise arithmetic freely (oracle replicas etc.).
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests;
