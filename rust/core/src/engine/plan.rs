//! The engine's buffer geometry as *data* — the static half of the
//! memory-footprint audit.
//!
//! [`BufferPlan::of`] reads a [`NetSpec`] and reproduces, symbolically,
//! every allocation decision [`super::Workspace::new`] and the batched
//! `BatchBufs::new` make: per-layer GEMM/tape/scratch shapes, the
//! backward delta ping-pong length, and the batch-path arena sizes.  Two
//! renderings hang off the one geometry:
//!
//! * **Host bytes** ([`BufferPlan::host_workspace_bytes`] /
//!   [`BufferPlan::host_batch_bytes`] / [`BufferPlan::host_weights_bytes`])
//!   — the engine's actual allocations on this host, where every working
//!   value is an `i32` and no buffer is reused across layers.  These are
//!   *exact*, not bounds: [`Engine::mem_probe`] measures the live `Vec`
//!   lengths and the test suite asserts byte equality, so the plan can
//!   never drift from the engine it describes.
//! * **Device bytes** — rendered by `priot_host::audit::mem`, which takes
//!   the same [`LayerPlan`] geometry and re-prices it at device widths
//!   (int8 activations/weights, i32 accumulators) with liveness-based
//!   buffer reuse.  The host-side equality proof is what grounds the
//!   device-side bound: both renderings read the identical shapes.
//!
//! The plan lives in `engine` (not `spec`) on purpose: the shapes below
//! are properties of *this engine's* buffer strategy (im2col patches,
//! tape-per-layer, delta ping-pong), not of the network alone, and the
//! private `Workspace`/`BatchBufs` fields are visible here so the probe
//! can count real allocations instead of trusting a copy of the math.

// Scoped re-allow of the module lint wall (`super` carries
// `#![deny(clippy::arithmetic_side_effects)]`): everything below is
// buffer-sizing arithmetic over spec dimensions — the same justification
// as `Workspace::new` — where an overflow would panic in a size
// computation, never corrupt training arithmetic.
#![allow(clippy::arithmetic_side_effects)]

use alloc::vec::Vec;

use super::Engine;
use crate::spec::{LayerSpec, NetSpec};
use crate::tensor::kernels::{packed_a_len, packed_b_len};

/// Bytes per `i32` working element (every host-side activation, weight,
/// score, accumulator, and delta buffer).
pub const HOST_ELEM_BYTES: usize = core::mem::size_of::<i32>();

/// One layer's buffer geometry: the dimensions every engine allocation
/// for this layer is a product of.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerPlan {
    pub index: usize,
    /// Convolution (im2col + GEMM) vs fully-connected.
    pub conv: bool,
    pub relu: bool,
    pub pooled: bool,
    /// Weight rows (output channels / output features).
    pub f: usize,
    /// Weight cols (im2col patch length `in_c·9`, or `in_f`).
    pub k: usize,
    /// Forward GEMM columns per sample (`H·W` for conv, 1 for fc).
    pub n: usize,
    pub in_len: usize,
    pub out_len: usize,
    /// Pre-pool activation length `f·n` (= `out_len` when unpooled).
    pub pre_pool: usize,
}

impl LayerPlan {
    /// Weight tensor element count (`f·k`).
    pub fn params(&self) -> usize {
        self.f * self.k
    }
}

/// The engine's complete buffer plan for one [`NetSpec`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BufferPlan {
    pub layers: Vec<LayerPlan>,
    pub input_len: usize,
    pub classes: usize,
    /// Backward delta ping-pong length: `max(input_len, all pre_pool,
    /// all in_len)` — exactly `Workspace::new`'s `max_len`.
    pub max_delta: usize,
    /// Batch-path per-sample ping-pong unit: `max(input_len, all
    /// out_len)` — exactly `BatchBufs::new`'s `max_len`.
    pub batch_unit: usize,
    /// Largest pre-pool activation `max(f·n)` (batch gather / pool-index
    /// scratch).
    pub max_pre: usize,
}

impl BufferPlan {
    /// Derive the plan from the spec — the same traversal as
    /// `Workspace::new` / `BatchBufs::new`, recorded instead of
    /// allocated.
    pub fn of(spec: &NetSpec) -> Self {
        let mut layers = Vec::with_capacity(spec.layers.len());
        let mut max_delta = spec.input_len();
        let mut batch_unit = spec.input_len();
        let mut max_pre = 0usize;
        for (index, l) in spec.layers.iter().enumerate() {
            let (f, k) = l.weight_shape();
            let (conv, relu, n, pre_pool, pooled) = match *l {
                LayerSpec::Conv { in_h, in_w, out_c, relu, pool, .. } => {
                    (true, relu, in_h * in_w, out_c * in_h * in_w, pool)
                }
                LayerSpec::Fc { out_f, relu, .. } => {
                    (false, relu, 1, out_f, false)
                }
            };
            layers.push(LayerPlan {
                index,
                conv,
                relu,
                pooled,
                f,
                k,
                n,
                in_len: l.in_len(),
                out_len: l.out_len(),
                pre_pool,
            });
            max_delta = max_delta.max(pre_pool).max(l.in_len());
            batch_unit = batch_unit.max(l.out_len());
            max_pre = max_pre.max(f * n);
        }
        BufferPlan {
            layers,
            input_len: spec.input_len(),
            classes: spec.num_classes(),
            max_delta,
            batch_unit,
            max_pre,
        }
    }

    /// Exact bytes of the shared backbone weight tensors on this host
    /// (`i32` elements; one copy, `Arc`-shared across sessions until a
    /// NITI update forks it).
    pub fn host_weights_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum::<usize>()
            * HOST_ELEM_BYTES
    }

    /// Exact bytes `Workspace::new` allocates for this spec: per layer
    /// `cols + acc + relu_out + out + weff + grad + dx32 + dcols` (i32)
    /// plus the `u8` pool indices, plus the delta ping-pong pair and
    /// `dlogits`.  No reuse — the host engine trades memory for the
    /// tape-per-layer layout.
    pub fn host_workspace_bytes(&self) -> usize {
        let mut elems = 0usize;
        let mut idx_bytes = 0usize;
        for l in &self.layers {
            elems += l.k * l.n // cols
                + l.f * l.n // acc
                + l.pre_pool // relu_out
                + l.out_len // out
                + l.params() // weff
                + l.params() // grad
                + l.in_len // dx32
                + l.k * l.n; // dcols
            if l.pooled {
                idx_bytes += l.pre_pool / 4; // pool_idx (u8)
            }
        }
        elems += 2 * self.max_delta + self.classes; // dy_a/dy_b + dlogits
        elems * HOST_ELEM_BYTES + idx_bytes
    }

    /// Exact bytes `BatchBufs::new(spec, b)` allocates: per layer
    /// `scratch + cols·b + acc·b + relu·b` (i32) plus the per-layer `u8`
    /// pool-index tape, plus the gather scratch, the per-sample overflow
    /// counters, and the sample-major ping-pong pair.  Zero for `b == 0`
    /// (the engine never builds batch buffers it doesn't use).
    pub fn host_batch_bytes(&self, b: usize) -> usize {
        if b == 0 {
            return 0;
        }
        let mut elems = 0usize;
        let mut idx_bytes = 0usize;
        for l in &self.layers {
            elems += l.k * l.n // scratch
                + l.k * l.n * b // cols
                + l.f * l.n * b // acc
                + l.f * l.n * b; // relu
            if l.pooled {
                idx_bytes += l.pre_pool / 4 * b; // pool_idx tape (u8)
            }
        }
        elems += self.max_pre; // gather
        elems += b; // ovf (u32)
        elems += 2 * b * self.batch_unit; // x_a/x_b
        elems * HOST_ELEM_BYTES + idx_bytes
    }

    /// Exact worst-case packed-panel element counts `(apack, bpack)` of
    /// the tiled GEMM scratch ([`crate::tensor::GemmScratch`]) for this
    /// spec at batch size `b` — the maxima over every GEMM the engine
    /// dispatches *tiled*.  This mirrors the `Kernels` dispatch rules
    /// exactly: `nn`/`tn` fall back to the scalar GEMV (no scratch) when
    /// the right operand has one column, `nt` always packs.
    ///
    /// `b == 0` prices the batch-1 training shapes alone (what
    /// `Engine::shared` reserves up front); `b > 0` additionally folds in
    /// the batched forward shapes (what the engine reserves when it builds
    /// `BatchBufs`).  Monotone in `b`, matching the grow-only scratch.
    pub fn scratch_elems(&self, b: usize) -> (usize, usize) {
        let (mut a_max, mut b_max) = (0usize, 0usize);
        let mut take = |a: usize, bb: usize| {
            a_max = a_max.max(a);
            b_max = b_max.max(bb);
        };
        for l in &self.layers {
            if l.conv {
                if l.n > 1 {
                    // training forward: nn (f,k)·(k,n)
                    take(packed_a_len(l.f, l.k), packed_b_len(l.n, l.k));
                }
                // backward δW: nt (f,n)·(k,n)ᵀ — packs even at n == 1
                take(packed_a_len(l.f, l.n), packed_b_len(l.k, l.n));
                if l.index > 0 && l.n > 1 {
                    // backward δx: tn (f,k)ᵀ·(f,n)
                    take(packed_a_len(l.k, l.f), packed_b_len(l.n, l.f));
                }
            }
            if b > 0 && l.n * b > 1 {
                // batched forward: nn (f,k)·(k,n·b)
                take(packed_a_len(l.f, l.k), packed_b_len(l.n * b, l.k));
            }
        }
        (a_max, b_max)
    }

    /// Byte rendering of [`Self::scratch_elems`] (i32 panels).
    pub fn host_scratch_bytes(&self, b: usize) -> usize {
        let (a, bb) = self.scratch_elems(b);
        (a + bb) * HOST_ELEM_BYTES
    }
}

/// Measured allocation footprint of a live [`Engine`] — the runtime pin
/// for [`BufferPlan`]'s host rendering.  Byte counts come from the
/// actual `Vec` lengths, so `plan == probe` is an equality the test
/// suite can assert, not an inequality taken on faith.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemProbe {
    /// Backbone weight tensors (i32) — shared via `Arc`, counted once.
    pub weights_bytes: usize,
    /// The per-session `Workspace` (tape + gradients + deltas).
    pub workspace_bytes: usize,
    /// The tiled-GEMM packing scratch (live `GemmScratch` elements).
    pub scratch_bytes: usize,
    /// Batched-forward buffers, 0 until a batched forward has run.
    pub batch_bytes: usize,
    /// The batch size the batch buffers are currently sized for.
    pub batch_b: Option<usize>,
}

impl Engine {
    /// Count the engine's real allocations, by measuring live buffer
    /// lengths (never by re-deriving them from the spec).  The
    /// memory-audit property test asserts this equals
    /// [`BufferPlan`]'s host rendering exactly, across methods, drift
    /// angles, and the batched-eval path.
    pub fn mem_probe(&self) -> MemProbe {
        let weights_bytes = self
            .weights
            .iter()
            .map(|w| w.data.len())
            .sum::<usize>()
            * HOST_ELEM_BYTES;
        let mut ws_elems = 0usize;
        let mut ws_idx = 0usize;
        for b in &self.ws.layers {
            ws_elems += b.cols.data.len()
                + b.acc.data.len()
                + b.relu_out.len()
                + b.out.len()
                + b.weff.data.len()
                + b.grad.data.len()
                + b.dx32.len()
                + b.dcols.data.len();
            ws_idx += b.pool_idx.len();
        }
        ws_elems +=
            self.ws.dy_a.len() + self.ws.dy_b.len() + self.ws.dlogits.len();
        let (batch_bytes, batch_b) = match &self.batch {
            None => (0, None),
            Some(bw) => {
                let mut elems = 0usize;
                let mut idx_bytes = 0usize;
                for li in 0..bw.cols.len() {
                    elems += bw.scratch[li].data.len()
                        + bw.cols[li].data.len()
                        + bw.acc[li].data.len()
                        + bw.relu[li].len();
                    idx_bytes += bw.pool_idx[li].len();
                }
                elems += bw.gather.len()
                    + bw.ovf.len()
                    + bw.x_a.len()
                    + bw.x_b.len();
                (elems * HOST_ELEM_BYTES + idx_bytes, Some(bw.b))
            }
        };
        MemProbe {
            weights_bytes,
            workspace_bytes: ws_elems * HOST_ELEM_BYTES + ws_idx,
            scratch_bytes: self.kernels.scratch_elems() * HOST_ELEM_BYTES,
            batch_bytes,
            batch_b,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Scales;
    use crate::tensor::Mat;

    fn engine_for(spec: NetSpec) -> Engine {
        let weights = spec
            .layers
            .iter()
            .map(|l| {
                let (r, c) = l.weight_shape();
                Mat::zeros(r, c)
            })
            .collect();
        let scales = Scales::default_for(spec.layers.len());
        Engine::new(spec, weights, scales).unwrap()
    }

    #[test]
    fn tinycnn_plan_geometry() {
        let plan = BufferPlan::of(&NetSpec::tinycnn());
        let dims: Vec<(usize, usize, usize, usize, usize, usize)> = plan
            .layers
            .iter()
            .map(|l| (l.f, l.k, l.n, l.pre_pool, l.in_len, l.out_len))
            .collect();
        assert_eq!(dims, vec![
            (8, 9, 784, 6272, 784, 1568),
            (16, 72, 196, 3136, 1568, 784),
            (64, 784, 1, 64, 784, 64),
            (10, 64, 1, 10, 64, 10),
        ]);
        assert_eq!(plan.input_len, 784);
        assert_eq!(plan.classes, 10);
        assert_eq!(plan.max_delta, 6272);
        assert_eq!(plan.batch_unit, 1568);
        assert_eq!(plan.max_pre, 6272);
        // Hand-computed exact totals (pinned so a silent engine buffer
        // change must update the plan *and* this test together).
        assert_eq!(plan.host_weights_bytes(), 52_040 * 4);
        assert_eq!(plan.host_workspace_bytes(), 743_376);
        assert_eq!(plan.host_batch_bytes(0), 0);
        assert_eq!(plan.host_batch_bytes(8), 1_543_712);
        // Tiled-GEMM scratch: batch-1 training maxima come from conv
        // backward (`nt` apack 8·784, fwd `nn` bpack 200·72); the batched
        // forward grows both sides (fc1 apack 64·784, conv2 bpack
        // (196·b→NR)·72).
        assert_eq!(plan.host_scratch_bytes(0), 82_688);
        assert_eq!(plan.host_scratch_bytes(1), 82_688);
        assert_eq!(plan.host_scratch_bytes(4), 426_496);
        assert_eq!(plan.host_scratch_bytes(8), 652_288);
    }

    #[test]
    fn probe_equals_plan_for_fresh_and_batched_engine() {
        for name in ["tinycnn", "vgg11w0.25"] {
            let spec = NetSpec::by_name(name).unwrap();
            let plan = BufferPlan::of(&spec);
            let mut engine = engine_for(spec.clone());
            let probe = engine.mem_probe();
            assert_eq!(probe.weights_bytes, plan.host_weights_bytes(),
                       "{name} weights");
            assert_eq!(probe.workspace_bytes, plan.host_workspace_bytes(),
                       "{name} workspace");
            assert_eq!(probe.scratch_bytes, plan.host_scratch_bytes(0),
                       "{name} scratch (training reserve)");
            assert_eq!(probe.batch_bytes, 0, "{name}: no batch ran yet");
            // Drive the batched path and re-measure.
            for b in [1usize, 4] {
                let imgs = Mat::zeros(b, spec.input_len());
                let mut logits = Mat::zeros(b, spec.num_classes());
                engine.forward_batch(&imgs, None, &mut logits);
                let probe = engine.mem_probe();
                assert_eq!(probe.batch_b, Some(b), "{name} b={b}");
                assert_eq!(probe.batch_bytes, plan.host_batch_bytes(b),
                           "{name} b={b}");
                assert_eq!(probe.scratch_bytes, plan.host_scratch_bytes(b),
                           "{name} b={b} scratch");
            }
        }
    }
}
