//! Engine unit tests.  Cross-implementation bit-parity with the AOT (JAX)
//! path lives in `rust/cli/tests/parity.rs`; these tests pin the engine's local
//! invariants and hand-computable cases.

use super::*;
use crate::prng::{init_scores, select_mask_random, XorShift32, XorShift64};
use crate::quant::Scales;
use crate::spec::NetSpec;
use crate::tensor::Mat;

fn tiny_engine(seed: u64) -> Engine {
    let spec = NetSpec::tinycnn();
    let mut rng = XorShift64::new(seed);
    let weights = spec
        .layers
        .iter()
        .map(|l| {
            let (r, c) = l.weight_shape();
            Mat::from_vec(r, c, (0..r * c).map(|_| rng.int_in(-127, 127)).collect())
        })
        .collect();
    let mut scales = Scales::default_for(spec.layers.len());
    scales.lr_shift = 11;
    scales.score_lr_shift = 7;
    Engine::new(spec, weights, scales).unwrap()
}

fn rand_img(rng: &mut XorShift64, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.int_in(0, 127)).collect()
}

fn ones_masks(spec: &NetSpec) -> Vec<Vec<i32>> {
    spec.layers.iter().map(|l| vec![1i32; l.num_params()]).collect()
}

fn rand_scores(spec: &NetSpec, seed: u32) -> Vec<Vec<i32>> {
    let mut rng = XorShift32::new(seed);
    spec.layers
        .iter()
        .map(|l| init_scores(&mut rng, l.num_params())
             .into_iter().map(|v| v as i32).collect())
        .collect()
}

#[test]
fn single_fc_layer_forward_by_hand() {
    // net: one FC 3→2, no relu; W = [[1,2,3],[-4,5,-6]], fwd shift 1.
    let spec = NetSpec {
        name: "fc1".into(),
        input_chw: (1, 1, 3),
        layers: vec![crate::spec::LayerSpec::Fc { in_f: 3, out_f: 2, relu: false }],
    };
    let w = Mat::from_vec(2, 3, vec![1, 2, 3, -4, 5, -6]);
    let mut scales = Scales::default_for(1);
    scales.layers[0].fwd = 1;
    let mut e = Engine::new(spec, vec![w], scales).unwrap();
    let (ovf, _) = e.forward(&[10, 20, 30], None, false);
    // acc = [10+40+90, -40+100-180] = [140, -120]
    // rshift_round(140,1)=70 ; rshift_round(-120,1)=-60
    assert_eq!(e.logits(), &[70, -60]);
    assert_eq!(ovf, 0);
}

#[test]
fn overflow_probe_counts_saturation() {
    let spec = NetSpec {
        name: "fc1".into(),
        input_chw: (1, 1, 2),
        layers: vec![crate::spec::LayerSpec::Fc { in_f: 2, out_f: 2, relu: false }],
    };
    let w = Mat::from_vec(2, 2, vec![127, 127, 1, 0]);
    let mut scales = Scales::default_for(1);
    scales.layers[0].fwd = 0;
    let mut e = Engine::new(spec, vec![w], scales).unwrap();
    let (ovf, _) = e.forward(&[127, 127], None, false);
    // row0 acc = 127*127*2 = 32258 -> overflows; row1 acc = 127 -> fine.
    assert_eq!(ovf, 1);
    assert_eq!(e.logits()[0], 127, "clamped");
    assert_eq!(e.logits()[1], 127);
}

#[test]
fn forward_deterministic_and_tape_stable() {
    let mut e = tiny_engine(1);
    let mut rng = XorShift64::new(2);
    let img = rand_img(&mut rng, e.spec.input_len());
    e.forward(&img, None, false);
    let l1 = e.logits().to_vec();
    e.forward(&img, None, false);
    assert_eq!(e.logits(), &l1[..], "same input, same logits");
}

#[test]
fn pruning_with_all_ones_masks_and_low_theta_is_identity() {
    let mut e = tiny_engine(3);
    let mut rng = XorShift64::new(4);
    let img = rand_img(&mut rng, e.spec.input_len());
    e.forward(&img, None, false);
    let plain = e.logits().to_vec();
    let scores = rand_scores(&e.spec, 5);
    let masks = ones_masks(&e.spec);
    let prune = PruneState { scores: &scores, masks: &masks, theta: -128 };
    e.forward(&img, Some(&prune), false);
    assert_eq!(e.logits(), &plain[..], "theta=-128 keeps every edge");
}

#[test]
fn unscored_edges_never_pruned() {
    // masks all zero -> no edge has a score -> no pruning at any theta.
    let mut e = tiny_engine(6);
    let mut rng = XorShift64::new(7);
    let img = rand_img(&mut rng, e.spec.input_len());
    e.forward(&img, None, false);
    let plain = e.logits().to_vec();
    let scores: Vec<Vec<i32>> = e.spec.layers.iter()
        .map(|l| vec![-127i32; l.num_params()]).collect();
    let masks: Vec<Vec<i32>> = e.spec.layers.iter()
        .map(|l| vec![0i32; l.num_params()]).collect();
    let prune = PruneState { scores: &scores, masks: &masks, theta: 127 };
    e.forward(&img, Some(&prune), false);
    assert_eq!(e.logits(), &plain[..]);
}

#[test]
fn high_theta_prunes_everything() {
    let mut e = tiny_engine(8);
    let mut rng = XorShift64::new(9);
    let img = rand_img(&mut rng, e.spec.input_len());
    let scores: Vec<Vec<i32>> = e.spec.layers.iter()
        .map(|l| vec![0i32; l.num_params()]).collect();
    let masks = ones_masks(&e.spec);
    let prune = PruneState { scores: &scores, masks: &masks, theta: 1 };
    e.forward(&img, Some(&prune), false);
    assert!(e.logits().iter().all(|&v| v == 0), "all-pruned net outputs 0");
}

#[test]
fn priot_step_freezes_weights_and_moves_scores() {
    let mut e = tiny_engine(10);
    let w_before: Vec<Vec<i32>> =
        e.weights.iter().map(|m| m.data.clone()).collect();
    let mut scores = rand_scores(&e.spec, 11);
    let s_before: Vec<Vec<i32>> = scores.clone();
    let masks = ones_masks(&e.spec);
    let mut rng = XorShift64::new(12);
    let mut moved = false;
    for step in 0..5 {
        let img = rand_img(&mut rng, e.spec.input_len());
        let label = rng.below(10);
        e.step_priot(&img, label, &mut scores, &masks, -64, step, false, false);
    }
    for (li, m) in e.weights.iter().enumerate() {
        assert_eq!(m.data, w_before[li], "weights must stay frozen");
    }
    for (li, s) in scores.iter().enumerate() {
        if s != &s_before[li] {
            moved = true;
        }
        assert!(s.iter().all(|&v| (-127..=127).contains(&v)));
    }
    assert!(moved, "scores should change over 5 steps");
}

#[test]
fn priot_s_masked_scores_never_move() {
    let mut e = tiny_engine(13);
    let mut rng32 = XorShift32::new(14);
    let masks: Vec<Vec<i32>> = e.spec.layers.iter()
        .map(|l| select_mask_random(&mut rng32, l.num_params(), 0.1)
            .into_iter().map(|v| v as i32).collect())
        .collect();
    let mut scores = rand_scores(&e.spec, 15);
    let s_before = scores.clone();
    let mut rng = XorShift64::new(16);
    for step in 0..5 {
        let img = rand_img(&mut rng, e.spec.input_len());
        let label = rng.below(10);
        e.step_priot(&img, label, &mut scores, &masks, 0, step, false, true);
    }
    for li in 0..scores.len() {
        for i in 0..scores[li].len() {
            if masks[li][i] == 0 {
                assert_eq!(scores[li][i], s_before[li][i],
                           "unscored edge's score must not move");
            }
        }
    }
}

#[test]
fn niti_step_updates_weights_in_range() {
    let mut e = tiny_engine(17);
    let w_before: Vec<Vec<i32>> =
        e.weights.iter().map(|m| m.data.clone()).collect();
    let mut rng = XorShift64::new(18);
    for step in 0..5 {
        let img = rand_img(&mut rng, e.spec.input_len());
        let label = rng.below(10);
        e.step_niti(&img, label, false, step);
    }
    let mut changed = false;
    for (li, m) in e.weights.iter().enumerate() {
        if m.data != w_before[li] {
            changed = true;
        }
        assert!(m.data.iter().all(|&v| (-127..=127).contains(&v)));
    }
    assert!(changed, "weights should change");
}

#[test]
fn dynamic_vs_static_forward_differ_only_in_scale() {
    // With dynamic scaling the logits are a (possibly different) requantized
    // view of the same accumulators — argmax usually agrees on confident
    // inputs; here we only pin that dynamic returns per-layer shifts.
    let mut e = tiny_engine(19);
    let mut rng = XorShift64::new(20);
    let img = rand_img(&mut rng, e.spec.input_len());
    let (_, dyn_shifts) = e.forward(&img, None, true);
    assert_eq!(dyn_shifts.len(), e.spec.layers.len());
}

#[test]
fn calibrate_returns_plausible_shifts() {
    let mut e = tiny_engine(21);
    let mut rng = XorShift64::new(22);
    let images: Vec<Vec<i32>> =
        (0..8).map(|_| rand_img(&mut rng, e.spec.input_len())).collect();
    let labels: Vec<usize> = (0..8).map(|_| rng.below(10)).collect();
    let s = e.calibrate(&images, &labels);
    for l in &s.layers {
        assert!(l.fwd < 24 && l.bwd < 24 && l.grad < 24 && l.score < 24);
    }
}

#[test]
fn fc_weight_gradient_is_outer_product() {
    // Single FC layer 3→2 (no relu, last layer): after one PRIOT step with
    // known logits the score update must equal
    // requant(W ⊙ requant(outer(δ, x), g), s+lr), δ from the int softmax.
    use crate::quant::{int_softmax_grad, requant};
    let spec = NetSpec {
        name: "fc1".into(),
        input_chw: (1, 1, 3),
        layers: vec![crate::spec::LayerSpec::Fc { in_f: 3, out_f: 2, relu: false }],
    };
    let w = Mat::from_vec(2, 3, vec![10, -20, 30, -40, 50, -60]);
    let mut scales = Scales::default_for(1);
    scales.layers[0].fwd = 2;
    scales.layers[0].grad = 3;
    scales.layers[0].score = 4;
    scales.score_lr_shift = 2;
    let mut e = Engine::new(spec, vec![w.clone()], scales.clone()).unwrap();
    let x = [5i32, 10, 20];
    let mut scores = vec![vec![0i32; 6]];
    let masks = vec![vec![1i32; 6]];
    // θ=-128: nothing pruned, so forward is plain W·x.
    e.step_priot(&x, 1, &mut scores, &masks, -128, 0, false, false);
    // expected: logits = requant(W·x, 2)
    let acc = [10 * 5 - 20 * 10 + 30 * 20, -40 * 5 + 50 * 10 - 60 * 20];
    let logits: Vec<i32> = acc.iter().map(|&a| requant(a, 2)).collect();
    let mut d = vec![0i32; 2];
    int_softmax_grad(&logits, 1, &mut d);
    for i in 0..2 {
        for j in 0..3 {
            let g = d[i] * x[j];
            let g8 = requant(g, 3);
            let upd = requant(w.at(i, j) * g8, 4 + 2);
            assert_eq!(scores[0][i * 3 + j], crate::quant::clamp8(0 - upd),
                       "edge ({i},{j})");
        }
    }
}

#[test]
fn relu_blocks_gradient_flow() {
    // A layer whose output is fully negative (relu → 0 everywhere) must
    // produce zero weight-gradient for the layer below it.
    let spec = NetSpec {
        name: "fc2".into(),
        input_chw: (1, 1, 4),
        layers: vec![
            crate::spec::LayerSpec::Fc { in_f: 4, out_f: 3, relu: true },
            crate::spec::LayerSpec::Fc { in_f: 3, out_f: 2, relu: false },
        ],
    };
    // all-negative first layer weights with positive input ⇒ relu kills all
    let w1 = Mat::from_vec(3, 4, vec![-5; 12]);
    let w2 = Mat::from_vec(2, 3, vec![7, -3, 2, -1, 4, -6]);
    let mut e = Engine::new(spec, vec![w1.clone(), w2],
                            Scales::default_for(2)).unwrap();
    let mut scores = vec![vec![0i32; 12], vec![0i32; 6]];
    let masks = vec![vec![1i32; 12], vec![1i32; 6]];
    e.step_priot(&[10, 20, 30, 40], 0, &mut scores, &masks, -128, 0, false,
                 false);
    assert!(scores[0].iter().all(|&s| s == 0),
            "no gradient may flow through a dead relu");
    assert_eq!(e.weights[0].data, w1.data);
}

#[test]
fn sparse_and_dense_priot_s_agree() {
    // The PRIOT-S fast path must be bit-identical to the dense path over
    // multiple steps (regression for the stale-gradient bug the parity
    // suite caught).
    let mut e1 = tiny_engine(40);
    let mut e2 = tiny_engine(40);
    let mut rng32 = XorShift32::new(41);
    let masks: Vec<Vec<i32>> = e1.spec.layers.iter()
        .map(|l| select_mask_random(&mut rng32, l.num_params(), 0.15)
            .into_iter().map(|v| v as i32).collect())
        .collect();
    let mut s1 = rand_scores(&e1.spec, 42);
    let mut s2 = s1.clone();
    let mut rng = XorShift64::new(43);
    for step in 0..6 {
        let img = rand_img(&mut rng, e1.spec.input_len());
        let label = rng.below(10);
        let a = e1.step_priot(&img, label, &mut s1, &masks, 0, step, false, false);
        let b = e2.step_priot(&img, label, &mut s2, &masks, 0, step, false, true);
        assert_eq!(a.logits, b.logits, "step {step}");
    }
    assert_eq!(s1, s2, "dense and sparse PRIOT-S state diverged");
}

#[test]
fn argmax_first_max() {
    assert_eq!(argmax(&[1, 3, 3, 2]), 1);
    assert_eq!(argmax(&[-5]), 0);
    assert_eq!(argmax(&[0, 0, 0]), 0);
}

#[test]
fn forward_batch_bit_identical_to_single_sample() {
    // The batch dimension is extra GEMM columns only: logits, predictions,
    // and the overflow probe must match B single-sample forwards exactly,
    // with and without pruning.
    let mut e = tiny_engine(50);
    let spec = e.spec.clone();
    let scores = rand_scores(&spec, 51);
    let masks = ones_masks(&spec);
    let mut rng = XorShift64::new(52);
    for b in [1usize, 3, 8] {
        let imgs = Mat::from_vec(
            b,
            spec.input_len(),
            (0..b * spec.input_len()).map(|_| rng.int_in(0, 127)).collect(),
        );
        for with_prune in [false, true] {
            let prune = PruneState { scores: &scores, masks: &masks, theta: -8 };
            let prune = with_prune.then_some(&prune);
            // Reference: one forward per sample.
            let mut want_logits = Vec::new();
            let mut want_overflow = 0u32;
            for bi in 0..b {
                let (ovf, _) = e.forward(imgs.row(bi), prune, false);
                want_overflow += ovf;
                want_logits.extend_from_slice(e.logits());
            }
            let mut logits = Mat::zeros(b, spec.num_classes());
            let overflow = e.forward_batch(&imgs, prune, &mut logits);
            assert_eq!(logits.data, want_logits,
                       "b={b} prune={with_prune}: logits diverged");
            assert_eq!(overflow, want_overflow,
                       "b={b} prune={with_prune}: overflow probe diverged");
            let preds = e.predict_batch(&imgs, prune);
            let want_preds: Vec<usize> = (0..b)
                .map(|bi| argmax(&want_logits[bi * spec.num_classes()
                                             ..(bi + 1) * spec.num_classes()]))
                .collect();
            assert_eq!(preds, want_preds);
        }
    }
}

#[test]
fn forward_batch_survives_batch_size_changes() {
    // The lazy batch workspace rebuilds when B changes (the remainder
    // chunk of an evaluation sweep); shrinking and growing must both work.
    let mut e = tiny_engine(53);
    let spec = e.spec.clone();
    let mut rng = XorShift64::new(54);
    let mut one = |b: usize| {
        let imgs = Mat::from_vec(
            b,
            spec.input_len(),
            (0..b * spec.input_len()).map(|_| rng.int_in(0, 127)).collect(),
        );
        let preds = e.predict_batch(&imgs, None);
        let want: Vec<usize> =
            (0..b).map(|bi| e.predict(imgs.row(bi), None)).collect();
        assert_eq!(preds, want, "b={b}");
    };
    for b in [4usize, 7, 2, 7, 1] {
        one(b);
    }
}

/// Drive `total` PRIOT steps twice — sequentially via `step_priot`, and
/// chunked via `step_priot_chunk` with the caller-side per-sample fallback
/// after a θ-crossing (exactly what the host executor does) — and assert
/// bit-identical logits, overflow probes, and final scores.
fn assert_chunked_matches_sequential(sparse: bool, sr: bool, theta: i32,
                                     seed: u64) {
    let mut es = tiny_engine(seed);
    let mut ec = tiny_engine(seed);
    let spec = es.spec.clone();
    let masks: Vec<Vec<i32>> = if sparse {
        let mut rng32 = XorShift32::new(seed as u32 ^ 0x9e37);
        spec.layers.iter()
            .map(|l| select_mask_random(&mut rng32, l.num_params(), 0.15)
                .into_iter().map(|v| v as i32).collect())
            .collect()
    } else {
        ones_masks(&spec)
    };
    let mut s_seq = rand_scores(&spec, seed as u32);
    let mut s_chk = s_seq.clone();
    let mut rng = XorShift64::new(seed ^ 0xabcd);
    let total = 11usize;
    let imgs: Vec<Vec<i32>> =
        (0..total).map(|_| rand_img(&mut rng, spec.input_len())).collect();
    let labels: Vec<usize> = (0..total).map(|_| rng.below(10)).collect();

    let mut want = Vec::new();
    for i in 0..total {
        want.push(es.step_priot(&imgs[i], labels[i], &mut s_seq, &masks,
                                theta, i as u32, sr, sparse));
    }

    let mut got: Vec<StepOut> = Vec::new();
    let chunk = 4usize;
    let mut i = 0usize;
    while i < total {
        let b = chunk.min(total - i);
        let mut m = Mat::zeros(b, spec.input_len());
        for bi in 0..b {
            m.row_mut(bi).copy_from_slice(&imgs[i + bi]);
        }
        let consumed = ec.step_priot_chunk(&m, &labels[i..i + b], &mut s_chk,
                                           &masks, theta, i as u32, sr,
                                           sparse, &mut got);
        assert!((1..=b).contains(&consumed), "consumed {consumed} of {b}");
        i += consumed;
        for _ in consumed..b {
            got.push(ec.step_priot(&imgs[i], labels[i], &mut s_chk, &masks,
                                   theta, i as u32, sr, sparse));
            i += 1;
        }
    }
    assert_eq!(got.len(), total);
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.logits, w.logits, "sample {i}: logits diverged");
        assert_eq!(g.overflow, w.overflow, "sample {i}: overflow diverged");
    }
    assert_eq!(s_chk, s_seq, "final scores diverged");
}

#[test]
fn priot_chunked_training_bit_identical_to_sequential() {
    // θ=-64 (the paper default): crossings are rare, chunks mostly run to
    // completion — the batched-forward path does the work.
    assert_chunked_matches_sequential(false, false, -64, 60);
    assert_chunked_matches_sequential(false, true, -64, 61);
}

#[test]
fn priot_chunked_training_survives_theta_crossings() {
    // θ=0 over random int8 scores: updates cross θ constantly, so chunks
    // stop early and the per-sample fallback finishes them — still exact.
    assert_chunked_matches_sequential(false, false, 0, 62);
    assert_chunked_matches_sequential(false, true, 0, 63);
}

#[test]
fn priot_s_chunked_training_bit_identical_to_sequential() {
    assert_chunked_matches_sequential(true, false, 0, 64);
    assert_chunked_matches_sequential(true, true, 0, 65);
}
