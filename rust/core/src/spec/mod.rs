//! Model architecture specs — mirrors `python/compile/intnet.py`
//! (`ConvSpec`/`FcSpec`/`NetSpec`) including the exact channel plans, so the
//! engine, the memory accountant and the AOT artifacts all agree on shapes.

use alloc::format;
use alloc::string::String;
use alloc::vec;
use alloc::vec::Vec;

/// One parameterized layer. Convolutions are 3×3 / pad 1 / stride 1 with an
/// optional 2×2 max-pool; geometry is recorded at spec-build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    Conv {
        in_c: usize,
        in_h: usize,
        in_w: usize,
        out_c: usize,
        relu: bool,
        pool: bool,
    },
    Fc {
        in_f: usize,
        out_f: usize,
        relu: bool,
    },
}

impl LayerSpec {
    /// Weight matrix shape `(rows, cols)`: conv `(F, C*9)`, fc `(out, in)`.
    pub fn weight_shape(&self) -> (usize, usize) {
        match *self {
            LayerSpec::Conv { in_c, out_c, .. } => (out_c, in_c * 9),
            LayerSpec::Fc { in_f, out_f, .. } => (out_f, in_f),
        }
    }

    pub fn num_params(&self) -> usize {
        let (r, c) = self.weight_shape();
        r * c
    }

    /// Flattened output length (post pool for conv layers).
    pub fn out_len(&self) -> usize {
        match *self {
            LayerSpec::Conv { in_h, in_w, out_c, pool, .. } => {
                if pool {
                    out_c * (in_h / 2) * (in_w / 2)
                } else {
                    out_c * in_h * in_w
                }
            }
            LayerSpec::Fc { out_f, .. } => out_f,
        }
    }

    /// Flattened input length.
    pub fn in_len(&self) -> usize {
        match *self {
            LayerSpec::Conv { in_c, in_h, in_w, .. } => in_c * in_h * in_w,
            LayerSpec::Fc { in_f, .. } => in_f,
        }
    }

    /// MACs for the forward GEMM of this layer.
    pub fn fwd_macs(&self) -> usize {
        match *self {
            LayerSpec::Conv { in_c, in_h, in_w, out_c, .. } => {
                out_c * in_c * 9 * in_h * in_w
            }
            LayerSpec::Fc { in_f, out_f, .. } => in_f * out_f,
        }
    }
}

/// A full model: an ordered list of layers plus the input geometry.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub name: String,
    pub input_chw: (usize, usize, usize),
    pub layers: Vec<LayerSpec>,
}

impl NetSpec {
    /// The paper's tiny CNN: conv(1→8)·pool → conv(8→16)·pool → fc 784→64
    /// → fc 64→10, for 28×28×1 inputs.
    pub fn tinycnn() -> Self {
        NetSpec {
            name: "tinycnn".into(),
            input_chw: (1, 28, 28),
            layers: vec![
                LayerSpec::Conv { in_c: 1, in_h: 28, in_w: 28, out_c: 8, relu: true, pool: true },
                LayerSpec::Conv { in_c: 8, in_h: 14, in_w: 14, out_c: 16, relu: true, pool: true },
                LayerSpec::Fc { in_f: 16 * 7 * 7, out_f: 64, relu: true },
                LayerSpec::Fc { in_f: 64, out_f: 10, relu: false },
            ],
        }
    }

    /// VGG11 (8 conv + 3 FC) for 32×32×3, width-scaled — channel plan
    /// 64,128,256,256,512,512,512,512 with pools after convs 1,2,4,6,8,
    /// then FC 512w→512w→10 (mirrors `intnet.vgg11_spec`).
    // layering-allow: config-time width scaling (spec construction only)
    pub fn vgg11(width: f64) -> Self {
        let c = |n: usize| -> usize {
            // layering-allow: config-time channel-width rounding
            (crate::round_half_away(n as f64 * width) as usize).max(4)
        };
        let chans = [c(64), c(128), c(256), c(256), c(512), c(512), c(512), c(512)];
        let pools = [true, true, false, true, false, true, false, true];
        let mut layers = Vec::new();
        let (mut in_c, mut h) = (3usize, 32usize);
        for (i, &out_c) in chans.iter().enumerate() {
            layers.push(LayerSpec::Conv {
                in_c,
                in_h: h,
                in_w: h,
                out_c,
                relu: true,
                pool: pools[i],
            });
            if pools[i] {
                h /= 2;
            }
            in_c = out_c;
        }
        let feat = chans[7] * h * h;
        layers.push(LayerSpec::Fc { in_f: feat, out_f: c(512), relu: true });
        layers.push(LayerSpec::Fc { in_f: c(512), out_f: c(512), relu: true });
        layers.push(LayerSpec::Fc { in_f: c(512), out_f: 10, relu: false });
        // Match the python name formatting ("%g"): trim trailing zeros.
        let mut ws = format!("{width}");
        if ws.contains('.') {
            while ws.ends_with('0') {
                ws.pop();
            }
            if ws.ends_with('.') {
                ws.pop();
            }
        }
        NetSpec { name: format!("vgg11w{ws}"), input_chw: (3, 32, 32), layers }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "tinycnn" => Some(Self::tinycnn()),
            _ if name.starts_with("vgg11w") => {
                // layering-allow: config-time model-name width parse
                name["vgg11w".len()..].parse::<f64>().ok().map(Self::vgg11)
            }
            _ => None,
        }
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_params()).sum()
    }

    pub fn input_len(&self) -> usize {
        self.input_chw.0 * self.input_chw.1 * self.input_chw.2
    }

    pub fn num_classes(&self) -> usize {
        self.layers.last().map(|l| l.out_len()).unwrap_or(0)
    }

    /// Total forward MACs for one sample.
    pub fn fwd_macs(&self) -> usize {
        self.layers.iter().map(|l| l.fwd_macs()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tinycnn_geometry() {
        let s = NetSpec::tinycnn();
        assert_eq!(s.layers.len(), 4);
        assert_eq!(s.layers[0].weight_shape(), (8, 9));
        assert_eq!(s.layers[1].weight_shape(), (16, 72));
        assert_eq!(s.layers[2].weight_shape(), (64, 784));
        assert_eq!(s.layers[3].weight_shape(), (10, 64));
        assert_eq!(s.num_params(), 8 * 9 + 16 * 72 + 64 * 784 + 640);
        assert_eq!(s.layers[1].out_len(), 16 * 7 * 7);
        assert_eq!(s.num_classes(), 10);
    }

    #[test]
    fn layer_chaining_is_consistent() {
        for spec in [NetSpec::tinycnn(), NetSpec::vgg11(0.25), NetSpec::vgg11(1.0)] {
            let mut cur = spec.input_len();
            for l in &spec.layers {
                assert_eq!(l.in_len(), cur, "{}: layer input mismatch", spec.name);
                cur = l.out_len();
            }
            assert_eq!(cur, 10);
        }
    }

    #[test]
    fn vgg11_full_width_params() {
        // 8 conv + 3 fc; full width lands in the ~9M range like real VGG11.
        let s = NetSpec::vgg11(1.0);
        assert_eq!(s.layers.len(), 11);
        let p = s.num_params();
        assert!((8_000_000..12_000_000).contains(&p), "params {p}");
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(NetSpec::by_name("tinycnn").unwrap().name, "tinycnn");
        let v = NetSpec::by_name("vgg11w0.25").unwrap();
        assert_eq!(v.name, "vgg11w0.25");
        assert!(NetSpec::by_name("nope").is_none());
    }
}
