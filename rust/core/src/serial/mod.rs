//! Binary interchange with the Python build path (mirrors
//! `python/compile/serialize.py` / `dataset.py`), plus checkpointing of
//! scores/weights produced on-device.
//!
//! All integers little-endian.
//!
//! * Weights ("PRWT" = 0x50525754): u32 magic, u32 version, u32 n_tensors,
//!   then per tensor u32 ndim, u32 dims[ndim], i8 data row-major.
//! * Dataset ("PRDS" = 0x50524453): u32 magic, u32 version, u32 n, c, h, w,
//!   then n·c·h·w u8 pixels, then n u8 labels.
//!
//! This module owns the in-memory *types* and the layout constants; the
//! file readers/writers (`load_weights` / `save_weights` / `load_dataset`)
//! live in `priot_host::serial` — the core crate is `no_std` and does no
//! IO.  A device port streams the same layouts over whatever transport it
//! has (flash, UART) and lands in these types.

use alloc::vec::Vec;

pub const WEIGHTS_MAGIC: u32 = 0x5052_5754;
pub const DATASET_MAGIC: u32 = 0x5052_4453;

/// An int8 tensor with explicit dims (as stored on disk).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorI8 {
    pub dims: Vec<usize>,
    pub data: Vec<i8>,
}

impl TensorI8 {
    /// Narrow i32 working values to the on-disk int8 representation,
    /// **saturating** at the int8 range.  Checkpoint values are produced by
    /// `clamp8` and already live in `[-127, 127]`, but a plain `as i8` cast
    /// would silently wrap anything that slipped outside (e.g. state
    /// injected by a foreign checkpoint) — saturate instead.
    pub fn from_i32_saturating(dims: Vec<usize>, data: &[i32]) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self {
            dims,
            data: data
                .iter()
                .map(|&x| x.clamp(i8::MIN as i32, i8::MAX as i32) as i8)
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Widen to the i32 working representation.
    pub fn to_i32(&self) -> Vec<i32> {
        self.data.iter().map(|&v| v as i32).collect()
    }
}

/// Overflow-checked product of header dims — a corrupt header must yield a
/// clean error, never a wrapped size that allocates garbage.  Public so the
/// host readers and the store codec share one guard.
pub fn checked_size(dims: &[usize]) -> Option<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}

/// An image-classification dataset as stored on disk (u8 pixels 0..255).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dataset {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub images: Vec<u8>, // n*c*h*w
    pub labels: Vec<u8>, // n
}

impl Dataset {
    pub fn image_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Raw u8 pixels of sample `i`.
    pub fn image(&self, i: usize) -> &[u8] {
        let len = self.image_len();
        &self.images[i * len..(i + 1) * len]
    }

    pub fn label(&self, i: usize) -> usize {
        self.labels[i] as usize
    }

    /// Device-side activation mapping: u8 0..255 pixels → int8 0..127
    /// (`p >> 1`), widened into the caller's i32 buffer.
    pub fn image_i32(&self, i: usize, out: &mut [i32]) {
        u8_to_i32_pixels(self.image(i), out);
    }
}

/// The device-side pixel mapping (u8 0..255 → int8 0..127 via `p >> 1`),
/// shared by [`Dataset::image_i32`] and the serve front-end's raw-image
/// `Predict` requests so the two paths cannot drift.
pub fn u8_to_i32_pixels(src: &[u8], out: &mut [i32]) {
    debug_assert_eq!(src.len(), out.len());
    for (o, &p) in out.iter_mut().zip(src.iter()) {
        *o = (p >> 1) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_i32_saturating_clamps_out_of_range() {
        let t = TensorI8::from_i32_saturating(
            vec![2, 3], &[0, 127, -127, 300, -300, 128]);
        assert_eq!(t.data, vec![0, 127, -127, 127, -128, 127],
                   "out-of-range i32 values must saturate, not wrap");
    }

    #[test]
    fn checked_size_guards_overflow() {
        assert_eq!(checked_size(&[2, 3, 4]), Some(24));
        assert_eq!(checked_size(&[]), Some(1));
        assert_eq!(checked_size(&[usize::MAX, 2]), None);
    }

    #[test]
    fn image_i32_halves_pixels() {
        let d = Dataset {
            n: 1,
            c: 1,
            h: 2,
            w: 2,
            images: vec![0, 1, 254, 255],
            labels: vec![3],
        };
        let mut buf = [0i32; 4];
        d.image_i32(0, &mut buf);
        assert_eq!(buf, [0, 0, 127, 127]);
        assert_eq!(d.label(0), 3);
    }
}
