//! Minimal CLI argument parser (no `clap` in the offline image).
//!
//! Grammar: `priot <subcommand> [--key value]... [--flag]... [positional]...`
//! `--key=value` is also accepted.  Every `--key value` pair is folded into
//! the [`crate::config::Config`] namespace so CLI flags override config-file
//! values uniformly.

use anyhow::{bail, Result};

use crate::config::Config;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.push((k.to_string(), v.to_string()));
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.push((body.to_string(), v));
                } else {
                    out.flags.push(body.to_string());
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                bail!("short options not supported: {arg} (use --long form)");
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn parse_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn option(&self, name: &str) -> Option<&str> {
        self.options
            .iter()
            .rev() // last occurrence wins
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Fold `--key value` options into a config (CLI overrides file).
    pub fn apply_to(&self, cfg: &mut Config) {
        for (k, v) in &self.options {
            cfg.set(k, v);
        }
        for f in &self.flags {
            cfg.set(f, "true");
        }
    }

    /// Build a config from `--config <file>` (if given) + CLI overrides.
    pub fn to_config(&self) -> Result<Config> {
        let mut cfg = match self.option("config") {
            Some(path) => Config::load(std::path::Path::new(path))?,
            None => Config::default(),
        };
        self.apply_to(&mut cfg);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|v| v.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse(&[
            "train", "extra", "--method", "priot", "--epochs=30", "--verbose",
        ]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.option("method"), Some("priot"));
        assert_eq!(a.option("epochs"), Some("30"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
        // NOTE the grammar ambiguity: "--flag value" binds value to flag;
        // bare flags must come last or use --flag=true.
        let b = parse(&["x", "--verbose", "word"]);
        assert_eq!(b.option("verbose"), Some("word"));
    }

    #[test]
    fn negative_values_are_values() {
        // "--theta -64": the next token starts with '-' but not '--',
        // so it is taken as the value.
        let a = parse(&["train", "--theta", "-64"]);
        // -64 starts with '-': our grammar treats it as value only for
        // --key=value form; check both behaviors are consistent:
        let b = parse(&["train", "--theta=-64"]);
        assert_eq!(b.option("theta"), Some("-64"));
        // the space form must not have swallowed "-64" as a short flag
        assert!(a.option("theta").is_some() || a.has_flag("theta"));
    }

    #[test]
    fn last_option_wins_and_overrides_config() {
        let a = parse(&["run", "--seed", "1", "--seed", "2"]);
        assert_eq!(a.option("seed"), Some("2"));
        let mut cfg = Config::default();
        cfg.set("seed", "0");
        a.apply_to(&mut cfg);
        assert_eq!(cfg.get("seed"), Some("2"));
    }

    #[test]
    fn rejects_short_options() {
        assert!(Args::parse(["-x".to_string()]).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["cmd", "--a", "1", "--", "--not-an-option"]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
