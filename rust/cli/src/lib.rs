//! # PRIOT — pruning-based integer-only transfer learning
//!
//! A three-layer reproduction of *PRIOT: Pruning-Based Integer-Only Transfer
//! Learning for Embedded Systems* (IEEE ESL 2025):
//!
//! * **Layer 1/2** (build-time Python): Pallas integer-GEMM kernels composed
//!   into JAX training-step graphs, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3** (this workspace): the on-device-learning stack — the pure
//!   Rust integer training engine ("picoengine"), the Raspberry Pi Pico
//!   cost/memory simulator, and the experiment harness that regenerates
//!   every table and figure in the paper.
//!
//! ## Workspace architecture
//!
//! The Rust stack is a cargo workspace of three crates with one-way
//! dependencies, plus the offline `xla-stub`:
//!
//! ```text
//!   priot (this crate: CLI binary, facade, tests/benches/examples)
//!     └── priot-host   std layer: datasets, sessions/fleets, wire
//!     │                protocol, serving, durable stores, audit, reports
//!     └── priot-core   #![no_std] + alloc: tensors, integer GEMMs,
//!                      quantization, the engine, method plugins, PRNGs,
//!                      specs — the code a Pico port would carry
//! ```
//!
//! The layering contract: **method plugins depend only on the core**
//! (numerics, no IO), **transports/stores/threads live in the host**.
//! `priot-core` compiles freestanding (`cargo check -p priot-core
//! --no-default-features` is a blocking CI gate; a `thumbv6m-none-eabi`
//! build for the Pico's Cortex-M0+ is the recorded next step), and its
//! message-only error type implements `core::error::Error`, so host code
//! composes core results with `anyhow` via plain `?`.  This crate
//! re-exports the host module tree one-to-one, so `priot::engine::…`,
//! `priot::session::…` etc. keep working unchanged.
//!
//! ## The Session/Fleet API
//!
//! All training runs are constructed through [`session`]:
//!
//! ```no_run
//! use priot::session::Session;
//! use priot::methods::PriotS;
//! use priot::config::Selection;
//!
//! let mut session = Session::builder()
//!     .artifacts("artifacts")
//!     .model("tinycnn")
//!     .method(PriotS::new(0.1, Selection::WeightBased))
//!     .seed(7)
//!     .epochs(10)
//!     .build()?;
//! // session.train(&train, &test) / .predict(..) / .save(..) / .restore(..)
//! # anyhow::Ok(())
//! ```
//!
//! * [`session::Backbone`] — the deployed read-only model, loaded once and
//!   shared across sessions via `Arc` (no per-session weight copies).
//! * [`session::Session`] — one adapting device: a training method bound
//!   to an execution backend.  Dataset-facing entry points validate
//!   geometry up front and return clean errors; evaluation can run
//!   batched ([`session::Session::evaluate_batch`]) — bit-identical to
//!   per-sample, faster.
//! * [`session::Fleet`] — many concurrent sessions over one backbone,
//!   scheduled at **epoch granularity** across the worker pool: the
//!   Table I seed sweep, the `priot fleet` multi-device simulation, and
//!   the `fleet` throughput bench all build on it.
//! * [`serve`] (= [`session::serve`]) — the long-lived fleet service: a
//!   registry of per-device sessions behind the [`proto`] wire boundary.
//!   Requests are scheduled per device by [`proto::Priority`]
//!   (predict > evaluate > train, preemptible at epoch boundaries) under
//!   a bounded per-device inflight window.  Driven by the `priot serve`
//!   CLI (in-process trace replay or `--listen` TCP) and `priot client`
//!   (trace replay against a remote server); benchmarked by the `serve`
//!   bench (requests/sec over both transports + batched-eval speedup +
//!   LRU churn under eviction pressure).
//!
//! ## Durable per-device state
//!
//! [`store`] is the persistence layer under the serving stack: PRIOT's
//! integer state (scores, masks, static scales) snapshots **bit-exactly**
//! ([`session::Session::snapshot`] / [`session::Session::rehydrate`] —
//! a rehydrated session's trajectories are byte-identical), so a
//! [`store::StateStore`] ([`store::MemStore`] in memory,
//! [`store::DiskStore`] dir-per-device with atomic write-rename) makes
//! fleets durable: `ServeBuilder::state_dir(..)` writes every device's
//! snapshot through on each completed state-mutating request, a
//! restarted `priot serve --state-dir ...` resumes every device where
//! it left off (re-sent registers resume instead of erroring), and
//! `resident_cap(N)` turns the registry into an LRU of live sessions
//! over the store — idle devices evict, any request rehydrates them
//! losslessly.  Dataset payloads are deduplicated into content-addressed
//! blobs; orphaned blobs are mark-sweep collected at startup and
//! shutdown ([`store::StateStore::gc_blobs`]).
//!
//! ## The wire protocol
//!
//! [`proto`] is the versioned host↔fleet protocol: plain-data
//! [`proto::Request`]/[`proto::Response`] messages, a length-delimited
//! binary codec with `serial`-style checked-length decoding, a
//! [`proto::Transport`] trait ([`proto::ChannelTransport`] in-process,
//! [`proto::TcpTransport`] over sockets — same bytes, bit-identical
//! responses), and the typed [`proto::FleetClient`]
//! (`register`/`train`/`predict`/`evaluate`/`drift`, sync + pipelined) —
//! the only public way to talk to a
//! [`session::FleetServer`]:
//!
//! ```no_run
//! use priot::proto::{FleetClient, MethodSpec};
//! use priot::session::{Backbone, FleetServer};
//!
//! let backbone = Backbone::load("artifacts".as_ref(), "tinycnn")?;
//! let mut server = FleetServer::builder(backbone).build();
//! let addr = server.listen("127.0.0.1:0")?;
//! let mut client = FleetClient::connect(addr)?;
//! # let (train, test): (std::sync::Arc<priot::serial::Dataset>,
//! #                     std::sync::Arc<priot::serial::Dataset>) = todo!();
//! client.register("dev-00", 1, MethodSpec::priot(), train, test)?;
//! client.train("dev-00", 2)?;
//! client.evaluate("dev-00")?;
//! drop(client);
//! println!("{}", server.join()?.summary());
//! # anyhow::Ok(())
//! ```
//!
//! ## Static soundness audit
//!
//! [`audit`] is the ahead-of-time counterpart of the Fig. 2 runtime
//! overflow counters: an interval-analysis pass that propagates worst-case
//! and weight-exact accumulator bounds through every conv/FC GEMM, requant
//! shift, ReLU, and pooling stage of the quantized network — method-aware
//! (prune masks tighten the bound, NITI weight drift widens it) — and
//! proves per layer that i32 accumulation cannot overflow, or reports the
//! exact missing headroom ([`audit::Verdict`]).  Surfaced as the
//! `priot audit` CLI (table + JSON, nonzero exit on unsound configs — the
//! CI gate), as a Register-time policy
//! (`ServeBuilder::audit(AuditPolicy::Reject)` refuses statically unsound
//! method specs, e.g. a corrupt scale table), and as an arithmetic lint
//! wall over the `engine`/`tensor::gemm`/`quant` hot paths.  The runtime
//! cross-check is [`engine::AccProbe`]: observed per-layer accumulator
//! extremes, asserted within the static bounds by `rust/cli/tests/audit.rs`.
//!
//! ## Data is generated in-process
//!
//! [`datagen`] is the native port of the Python procedural generators
//! (RotDigits / RotPatterns): any `(task, n, seed, angle)` tuple is
//! synthesized **byte-identically** to `python/compile/dataset.py`
//! (pinned by checked-in golden hashes — `rust/cli/tests/datagen.rs`).
//! [`data::DataSource`] resolves experiment configs and symbolic trace
//! angles through it: artifact files when present, generation otherwise.
//! That makes the whole Rust path hermetic — the full test suite, serve
//! drift traces at arbitrary angles (`drift dev0 60`), and the benches
//! all run from a bare checkout with no `make artifacts`.
//!
//! ## Methods are plugins
//!
//! Training methods implement [`methods::MethodPlugin`]
//! (init/step/predict/checkpoint hooks).  Built-ins: [`methods::Niti`],
//! [`methods::Priot`], [`methods::PriotS`].  Adding a method touches
//! neither the engine nor the coordinator — plugins live in `priot-core`
//! and depend only on the core.
//!
//! ## Backends
//!
//! Two interchangeable executors drive a plugin: the pure-Rust [`engine`]
//! and (behind the `pjrt` cargo feature) PJRT execution of the AOT
//! artifacts (`runtime`).  Integration tests assert they agree
//! **bit-for-bit** — the entire stack is deterministic integer arithmetic.
//!
//! Entry points: the `priot` binary (`rust/cli/src/main.rs`), the examples
//! in `examples/`, and the benches in `rust/cli/benches/` (one per paper
//! table/figure, plus `fleet` for session throughput).

pub mod cli;

pub use priot_host::{
    audit, config, coordinator, data, datagen, engine, methods, metrics,
    obs, pico, prng, proto, ptest, quant, report, serial, session, spec,
    store, tensor,
};
#[cfg(feature = "pjrt")]
pub use priot_host::runtime;

pub use priot_host::serve;
pub use priot_host::INT8_MAX;
